"""Coalesced hot-path throughput: frames/sec for 64-byte frames at batching
factors 1/8/64 over the shm and socket fabrics, plus put/get bandwidth.

This is the benchmark behind the zero-copy/batching PR: factor 1 is the
per-message path (one publication — ring counter store or syscall — per
frame, one copy per pop), the batched factors ride ``send_many``/
``recv_many`` (N frames per publication, leased zero-copy views on shm).

Results are written to ``BENCH_hotpath.json`` at the repo root together with
the seed-revision baselines, so the perf trajectory is tracked across PRs.
"""

from __future__ import annotations

import json
import threading
import time
from pathlib import Path

from repro.comm.shm import ShmFabric
from repro.comm.socket import SocketFabric

_REPO_ROOT = Path(__file__).resolve().parents[1]
_JSON_PATH = _REPO_ROOT / "BENCH_hotpath.json"

FRAME_NBYTES = 64
FACTORS = (1, 8, 64)

#: seed-revision numbers (PR 0), measured in this container with
#: ``benchmarks/putget.py`` (mean over reps) before the zero-copy/batching
#: rework — the denominator of the tracked speedups.
SEED_PUTGET_US = {
    "put_64KB": 201.4,
    "get_64KB": 200.3,
    "put_4MB": 1929.1,
    "get_4MB": 2293.9,
    "put_64MB": 102704.9,
    "get_64MB": 122410.9,
}

#: the same seed revision re-measured with per-call medians on an idle
#: machine (straggler-robust; see putget.run_median) — the conservative
#: baseline for the speedup claims.
SEED_PUTGET_MEDIAN_US = {
    "put_64KB": 126.8,
    "get_64KB": 93.3,
    "put_4MB": 1089.7,
    "get_4MB": 969.6,
    "put_64MB": 78974.8,
    "get_64MB": 113933.0,
}


def _make_fabric(kind: str):
    if kind == "shm":
        return ShmFabric(2, capacity=1 << 22)
    return SocketFabric(2)


def _frames_per_sec(kind: str, factor: int, n_frames: int) -> float:
    """Producer -> consumer throughput of ``n_frames`` 64-byte frames."""
    fab = _make_fabric(kind)
    a, b = fab.endpoint(0), fab.endpoint(1)
    frame = b"\x5a" * FRAME_NBYTES
    done = threading.Event()

    def consume() -> None:
        got = 0
        while got < n_frames:
            if factor == 1:
                if b.recv(timeout=10) is not None:
                    got += 1
            else:
                got += len(b.recv_many(max_frames=factor, timeout=10))
                b.release()
        done.set()

    consumer = threading.Thread(target=consume, daemon=True)
    consumer.start()
    t0 = time.perf_counter()
    if factor == 1:
        for _ in range(n_frames):
            a.send(1, frame)
    else:
        batch = [frame] * factor
        for _ in range(n_frames // factor):
            a.send_many(1, batch)
    if not done.wait(timeout=120):
        fab.close()
        raise RuntimeError(f"{kind} consumer stalled at factor {factor}")
    dt = time.perf_counter() - t0
    consumer.join(timeout=5)
    fab.close()
    return n_frames / dt


def run(smoke: bool = False,
        serialise_rows=None) -> list[tuple[str, float, str]]:
    """``serialise_rows=`` lets the harness pass the serialisation section's
    already-collected rows (benchmarks/run.py runs that section itself);
    standalone invocations leave it None and measure here."""
    rows: list[tuple[str, float, str]] = []
    fps: dict[str, dict[str, float]] = {}
    sizes = (
        (("shm", 4 * 1024), ("socket", 1024)) if smoke
        else (("shm", 128 * 1024), ("socket", 32 * 1024))
    )
    for kind, n_frames in sizes:
        fps[kind] = {}
        for factor in FACTORS:
            rate = _frames_per_sec(kind, factor, n_frames)
            fps[kind][str(factor)] = rate
            rows.append(
                (f"batching/{kind}_x{factor}", 1e6 / rate, f"{rate:,.0f} frames/s")
            )

    # put/get bandwidth rides along so BENCH_hotpath.json tracks the whole
    # hot path (the acceptance metrics of the zero-copy PR)
    from benchmarks import putget

    putget_us: dict[str, float] = {}
    for name, us, note in putget.run(smoke=smoke):
        short = name.split("/", 1)[1]
        putget_us[short] = round(us, 1)
        rows.append((f"batching/{name}", us, note))

    putget_median_us = putget.run_median(smoke=smoke)
    for name, us in putget_median_us.items():
        rows.append((f"batching/putget/{name}_median", us, ""))

    # small-RPC fast path (compiled WirePlan / FLAG_FUSED) — the request-path
    # half of the hot path; section built by benchmarks/rpc_fastpath.py
    from benchmarks import rpc_fastpath, serialisation

    rpc_us = rpc_fastpath.measure(smoke=smoke)
    for k, v in rpc_us["rtt_us"].items():
        if v is not None:
            rows.append((f"batching/rpc/rtt_{k}", v, ""))
    for k, v in rpc_us["stream_us"].items():
        rows.append((f"batching/rpc/stream_{k}", v, ""))
    for k, v in rpc_us["fused_calls_per_s"].items():
        rows.append((f"batching/rpc/calls_per_s_{k}", v, "calls/s"))

    # serialisation medians ride along so the codec trend is persisted too
    # (they were printed but never recorded before this section existed)
    if serialise_rows is None:
        serialise_rows = serialisation.run(smoke=smoke)
    serialise_us = {
        name.split("/", 1)[1]: round(us, 3) for name, us, _ in serialise_rows
    }

    shm_speedup = fps["shm"]["64"] / fps["shm"]["1"]
    socket_speedup = fps["socket"]["64"] / fps["socket"]["1"]
    putget_speedup = {
        k: round(SEED_PUTGET_US[k] / v, 2)
        for k, v in putget_us.items()
        if k in SEED_PUTGET_US and v
    }
    putget_median_speedup = {
        k: round(SEED_PUTGET_MEDIAN_US[k] / v, 2)
        for k, v in putget_median_us.items()
        if k in SEED_PUTGET_MEDIAN_US and v
    }
    report = {
        "schema": "hotpath-v3",
        "smoke": smoke,
        "frame_nbytes": FRAME_NBYTES,
        "frames_per_sec": {
            kind: {f: round(v, 1) for f, v in per.items()}
            for kind, per in fps.items()
        },
        "batching_speedup_x64": {
            "shm": round(shm_speedup, 2),
            "socket": round(socket_speedup, 2),
        },
        "putget_us": putget_us,
        "putget_median_us": putget_median_us,
        "seed_putget_us": SEED_PUTGET_US,
        "seed_putget_median_us": SEED_PUTGET_MEDIAN_US,
        "putget_speedup_vs_seed": putget_speedup,
        "putget_median_speedup_vs_seed": putget_median_speedup,
        "rpc_us": rpc_us,
        "serialise_us": serialise_us,
        "acceptance": {
            "shm_x64_ge_3x": shm_speedup >= 3.0,
            "putget_4MB_plus_ge_1p5x": all(
                putget_speedup.get(k, 0) >= 1.5
                for k in ("put_4MB", "get_4MB", "put_64MB", "get_64MB")
            ),
            # WirePlan PR: small static RPC >= 2x the pre-plan dynamic path
            # (throughput view; the latency view is floor-bound — both are
            # recorded under rpc_us), fused >= 1.5x over unfused static
            "rpc_static_stream_ge_2x_seed_dynamic": (
                rpc_us["speedup"]["static_stream_vs_seed_dynamic"] >= 2.0
            ),
            "rpc_fused_ge_1p5x_static": (
                rpc_us["speedup"]["fused_stream_vs_static"] >= 1.5
            ),
            # doorbell/shape-cache/relay-fusion PR targets (hotpath-v3):
            # recorded HONESTLY — the absolute ones are core-count-bound
            # (a single-core runner pays >= 2 context switches per RTT), so
            # CI gates on the relative ratios + a generous absolute ceiling
            # (benchmarks/trend_gate.py CEILINGS), not on these booleans
            "rpc_static_rtt_lt_10us": (
                rpc_us["rtt_us"]["static"]
                < rpc_us["targets"]["static_rtt_us_lt"]
            ),
            "rpc_fused_ge_1M_calls_per_s": (
                rpc_us["fused_calls_per_s"]["oneway_link_pair"]
                >= rpc_us["targets"]["fused_calls_per_s_ge"]
            ),
            "rpc_dynamic_repeat_within_1p3x_static": (
                rpc_us["rtt_us"]["dynamic"]
                <= rpc_us["targets"]["dynamic_repeat_rtt_max_ratio"]
                * rpc_us["rtt_us"]["static"]
            ),
        },
    }
    _JSON_PATH.write_text(json.dumps(report, indent=2) + "\n")
    rows.append(("batching/shm_x64_speedup", shm_speedup, f"-> {_JSON_PATH.name}"))
    rows.append(("batching/socket_x64_speedup", socket_speedup, ""))
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val:.3f},{note}")
