"""Serialisation cost: HAM static pack (bitwise) vs dynamic TLV vs pickle.

The paper's fast path is the static closure pack — argument specs are part
of the message type, so the wire carries raw bytes only.  This benchmark
quantifies what that buys over self-describing encodings.
"""

from __future__ import annotations

import pickle

import numpy as np

from repro.core import migratable as mig

from benchmarks._stats import median_us


def _median_us(fn, n=2000, warmup=100) -> float:
    return median_us(fn, n, warmup)


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rows = []
    sizes = (
        ((64, "64B"),) if smoke
        else ((64, "64B"), (64 * 1024, "64KB"), (4 * 1024 * 1024, "4MB"))
    )
    n = 20 if smoke else 2000
    for size, label in sizes:
        arr = np.random.default_rng(0).standard_normal(size // 8)
        args = (arr, 3, 2.5)
        specs = tuple(mig.spec_of(a) for a in args)
        rows.append((
            f"serialise/static_pack_{label}",
            _median_us(lambda: mig.pack_static(args, specs), n),
            f"{size}B payload",
        ))
        rows.append((
            f"serialise/dynamic_pack_{label}",
            _median_us(lambda: mig.pack_dynamic(list(args)), n),
            "self-describing TLV",
        ))
        rows.append((
            f"serialise/pickle_{label}",
            _median_us(lambda: pickle.dumps(args), n),
            "vendor-analogue",
        ))
        payload = mig.pack_static(args, specs)
        rows.append((
            f"serialise/static_unpack_{label}",
            _median_us(lambda: mig.unpack_static(payload, specs), n),
            "zero-copy views",
        ))
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val:.2f},{note}")
