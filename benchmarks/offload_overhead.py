"""Paper Fig. 3 analogue: offload cost of an EMPTY function.

Measured as round-trip time per offload, median over many calls:

* ``ham_local``   — HAM over in-process queues (intra-node floor)
* ``ham_shm``     — HAM over shared-memory rings, forked worker process
* ``ham_socket``  — HAM over loopback TCP, worker process
* ``naive_local`` / ``naive_socket`` — the vendor-analogue RPC
  (name resolution + pickle per call) over the SAME transports

The paper reports 28.6× (vs Intel LEO) and 13.1× (vs NEC VEO); our
validation criterion is a large HAM-vs-naive ratio on identical transport.
"""

from __future__ import annotations

import sys

import repro.offload.demo_handlers  # noqa: F401  (registers demo/empty*)
from repro.comm.local import LocalFabric
from repro.comm.shm import ShmFabric
from repro.comm.socket import SocketFabric
from repro.core.closure import f2f
from repro.core.registry import default_registry
from repro.offload.api import OffloadDomain
from repro.offload.worker import (
    reap,
    spawn_shm_workers,
    spawn_socket_worker_subprocess,
)

from benchmarks import naive_rpc
from benchmarks._stats import median_us


def _median_us(fn, n, warmup=50) -> float:
    return median_us(fn, n, warmup)


def _ensure_init():
    reg = default_registry()
    if not reg.initialised:
        reg.init()


def bench_ham_local(n=2000) -> float:
    _ensure_init()
    dom = OffloadDomain.local(2, inline_host=False)
    call = f2f("demo/empty_static")
    us = _median_us(lambda: dom.sync(1, call), n)
    dom.shutdown()
    return us


def bench_ham_local_inline(n=2000) -> float:
    """Inline host (caller-thread polling): the true latency floor."""
    _ensure_init()
    fabric = LocalFabric(2)
    from repro.core.registry import default_registry as dr
    from repro.offload.runtime import NodeRuntime

    worker = NodeRuntime(1, fabric.endpoint(1), dr().table).start()
    host = NodeRuntime(0, fabric.endpoint(0), dr().table, inline=True)
    call = f2f("demo/empty_static")
    us = _median_us(lambda: host.send_sync(1, call), n)
    worker.stop()
    return us


def bench_ham_shm(n=1000) -> float:
    _ensure_init()
    fabric = ShmFabric(2)
    # setup_modules auto-derived from the host registry: whatever modules
    # registered handlers here get imported by the worker too (same-source)
    procs = spawn_shm_workers(fabric, [1])
    try:
        dom = OffloadDomain(fabric, inline_host=True)
        call = f2f("demo/empty_static")
        us = _median_us(lambda: dom.sync(1, call), n)
        dom.shutdown()
    finally:
        reap(procs)
    return us


def bench_ham_socket(n=1000) -> float:
    _ensure_init()
    fabric = SocketFabric(2)
    fabric.endpoint(0)
    proc = spawn_socket_worker_subprocess(1, 2, fabric.base_port)
    try:
        dom = OffloadDomain(fabric, inline_host=True)
        dom.ping(1, timeout=30.0)  # wait for interpreter start
        call = f2f("demo/empty_static")
        us = _median_us(lambda: dom.sync(1, call), n)
        dom.shutdown()
    finally:
        # reap even on failure: an orphaned worker would hold the CI step's
        # output pipe open and hang the job
        reap([proc])
    return us


def bench_naive_local(n=2000) -> float:
    fabric = LocalFabric(2)
    server = naive_rpc.NaiveRpcServer(fabric.endpoint(1)).start()
    client = naive_rpc.NaiveRpcClient(fabric.endpoint(0), 1)
    us = _median_us(lambda: client.call(naive_rpc.empty), n)
    client.stop_server()
    server.stop()
    return us


def bench_naive_socket(n=500) -> float:
    fabric = SocketFabric(2)
    ep1 = fabric.endpoint(1)
    ep0 = fabric.endpoint(0)
    server = naive_rpc.NaiveRpcServer(ep1).start()
    client = naive_rpc.NaiveRpcClient(ep0, 1)
    us = _median_us(lambda: client.call(naive_rpc.empty), n)
    client.stop_server()
    server.stop()
    fabric.close()
    return us


def bench_payload_pair(nbytes=1 << 20, n=300):
    """1MB-argument call: HAM typed path vs pickle RPC, same transport."""
    import numpy as np

    _ensure_init()
    arr = np.random.default_rng(0).standard_normal(nbytes // 8)
    fabric = LocalFabric(2)
    from repro.core.registry import default_registry as dr
    from repro.offload.runtime import NodeRuntime

    worker = NodeRuntime(1, fabric.endpoint(1), dr().table).start()
    host = NodeRuntime(0, fabric.endpoint(0), dr().table, inline=True)
    call = f2f("demo/add", arr, arr)
    ham_us = _median_us(lambda: host.send_sync(1, call), n, warmup=30)
    worker.stop()

    fab2 = LocalFabric(2)
    server = naive_rpc.NaiveRpcServer(fab2.endpoint(1)).start()
    client = naive_rpc.NaiveRpcClient(fab2.endpoint(0), 1)
    naive_us = _median_us(lambda: client.call(naive_rpc.add, arr, arr), n,
                          warmup=30)
    client.stop_server()
    server.stop()
    return ham_us, naive_us


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    # smoke: one-repeat-class sizes so CI can execute every code path fast
    n_fast = 40 if smoke else 2000
    n_proc = 20 if smoke else 1000
    # every HAM row names WHICH wire path it measured (static WirePlan vs
    # dynamic TLV) so Fig.-3-style comparisons are unambiguous: demo/
    # empty_static rides the static path (plan-packed, zero-byte payload
    # AND zero-byte static reply), demo/add rides the dynamic TLV path
    rows = []
    local_inline = bench_ham_local_inline(n_fast)
    rows.append(("offload/ham_local_inline", local_inline,
                 "empty fn RTT [HAM static path]"))
    rows.append(("offload/ham_local", bench_ham_local(n_fast),
                 "empty fn RTT [HAM static path]"))
    rows.append(("offload/ham_shm", bench_ham_shm(n_proc),
                 "forked worker [HAM static path]"))
    rows.append(("offload/ham_socket", bench_ham_socket(n_proc),
                 "fresh interpreter [HAM static path]"))
    naive_local = bench_naive_local(n_fast)
    rows.append(("offload/naive_local", naive_local, "pickle+name lookup"))
    naive_socket = bench_naive_socket(20 if smoke else 500)
    rows.append(("offload/naive_socket", naive_socket, "pickle+name lookup"))
    rows.append(
        ("offload/RATIO_naive_over_ham_empty", naive_local / local_inline,
         "naive/static same-transport control (see dispatch/* for the "
         "vendor-class gap; rpc/* adds the static-vs-dynamic split)")
    )
    ham_mb, naive_mb = bench_payload_pair(
        nbytes=1 << 16 if smoke else 1 << 20, n=10 if smoke else 300
    )
    rows.append(("offload/ham_1MB_args", ham_mb,
                 "typed bitwise payload [HAM dynamic path]"))
    rows.append(("offload/naive_1MB_args", naive_mb, "pickled payload"))
    rows.append(("offload/RATIO_naive_over_ham_1MB", naive_mb / ham_mb,
                 "naive/dynamic"))
    return rows


if __name__ == "__main__":
    for name, val, note in run(smoke="--smoke" in sys.argv):
        print(f"{name},{val:.2f},{note}")
