"""Registry init + lookup scaling (paper §5.2: "minimal runtime complexity").

* init cost vs handler count (the sort — O(N log N), run once per process)
* key_of / handler_at — the per-message O(1) claims of Fig. 6
"""

from __future__ import annotations

import statistics
import time

from repro.core.registry import HandlerRegistry


def _mk_registry(n: int) -> HandlerRegistry:
    reg = HandlerRegistry()
    for i in range(n):
        reg.register((lambda i=i: i), name=f"bench/handler_{i:06d}")
    return reg


def bench_init(n: int) -> float:
    reg = _mk_registry(n)
    t0 = time.perf_counter_ns()
    reg.init()
    return (time.perf_counter_ns() - t0) / 1e3


def bench_lookup(n: int, calls=20000) -> tuple[float, float]:
    reg = _mk_registry(n)
    table = reg.init()
    name = f"bench/handler_{n // 2:06d}"
    key = table.key_of(name)
    t0 = time.perf_counter_ns()
    for _ in range(calls):
        table.key_of(name)
    t_key = (time.perf_counter_ns() - t0) / 1e3 / calls
    t0 = time.perf_counter_ns()
    for _ in range(calls):
        table.handler_at(key)
    t_handler = (time.perf_counter_ns() - t0) / 1e3 / calls
    return t_key, t_handler


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rows = []
    sizes = (100, 1000) if smoke else (100, 1000, 10000)
    for n in sizes:
        rows.append((f"registry/init_{n}", bench_init(n), "sort+key assignment"))
    big = sizes[-1]
    tk, th = bench_lookup(big, calls=200 if smoke else 20000)
    rows.append(("registry/key_of", tk, f"type->key, {big} handlers"))
    rows.append(("registry/handler_at", th, f"key->handler, {big} handlers"))
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val:.3f},{note}")
