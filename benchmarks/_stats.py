"""Shared statistics helpers for the benchmark suite.

Every section used to carry its own ``_median_us`` copy (identical up to
the default repeat counts) and its own percentile arithmetic; they live
here once so a methodology change — warmup policy, percentile convention —
lands in one place and applies to every published ``BENCH_*.json`` number.

Percentiles use the **nearest-rank** convention: p99 of 100 samples is the
99th-largest observation, never an interpolated value that no request
actually experienced.  SLO math must be pessimistic about tails, and
interpolation between the two worst samples understates them.
"""

from __future__ import annotations

import math
import statistics
import time
from typing import Callable, Iterable, Sequence

__all__ = ["median", "median_us", "percentile", "percentiles"]


def median(xs: Iterable[float]) -> float:
    return statistics.median(xs)


def percentile(xs: Sequence[float], p: float) -> float:
    """Nearest-rank percentile of ``xs`` (``p`` in [0, 100])."""
    if not xs:
        raise ValueError("percentile of an empty sample")
    if not 0 <= p <= 100:
        raise ValueError(f"percentile {p} outside [0, 100]")
    s = sorted(xs)
    rank = max(1, math.ceil(p / 100.0 * len(s)))
    return s[rank - 1]


def percentiles(xs: Sequence[float],
                ps: Sequence[float] = (50, 99)) -> dict[str, float]:
    """``{"p50": ..., "p99": ...}`` over one sorted pass of ``xs``."""
    s = sorted(xs)
    out = {}
    for p in ps:
        label = f"p{p:g}"
        out[label] = percentile(s, p)
    return out


def median_us(fn: Callable[[], object], n: int, warmup: int) -> float:
    """Median wall time of ``fn()`` in microseconds over ``n`` timed calls
    after ``warmup`` untimed ones — the suite's standard microbenchmark
    primitive (per-call medians are robust against scheduler/GC
    stragglers; means are not)."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(n):
        t0 = time.perf_counter_ns()
        fn()
        ts.append((time.perf_counter_ns() - t0) / 1e3)
    return statistics.median(ts)
