"""Vendor-analogue RPC baseline (the LEO/VEO stand-in for Fig. 3).

What vendor offload stacks pay per call, reproduced honestly:

* **name-based function resolution** per call (string lookup, the moral
  equivalent of symbol resolution / COI function registration round-trips),
* **generic serialisation** of the call (pickle — self-describing, types
  encoded on the wire), and
* **fresh framing/buffers** per call.

HAM's thesis (paper §4.3) is that a deterministic key map + bitwise
payloads removes all three.  Both sides here run over the *same* fabrics
as HAM, so the measured gap is mechanism, not transport.

Comparison hygiene: HAM itself has TWO wire paths — the compiled-plan
static path (``FLAG_STATIC``, spec known to both sides) and the dynamic
TLV fallback — and they differ by several x on small calls.  Every
benchmark row that compares against this baseline therefore says which
HAM path it measured (see ``offload_overhead.py`` notes and the
``path_labels`` in ``BENCH_hotpath.json``'s ``rpc_us`` section); an
unlabeled "HAM vs naive" number would be ambiguous by that same margin.
"""

from __future__ import annotations

import importlib
import pickle
import threading


class NaiveRpcServer:
    """Executes (module, qualname, args) requests; replies pickled results."""

    def __init__(self, endpoint):
        self.endpoint = endpoint
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def _resolve(self, module: str, qualname: str):
        obj = importlib.import_module(module)
        for part in qualname.split("."):
            obj = getattr(obj, part)
        return obj

    def serve_once(self, timeout=1.0) -> bool:
        frame = self.endpoint.recv(timeout=timeout)
        if frame is None:
            return False
        module, qualname, args, msg_id, src = pickle.loads(frame)
        if module == "__stop__":
            self._stop.set()
            return True
        fn = self._resolve(module, qualname)
        result = fn(*args)
        self.endpoint.send(src, pickle.dumps((msg_id, result)))
        return True

    def run(self) -> None:
        while not self._stop.is_set():
            self.serve_once()

    def start(self) -> "NaiveRpcServer":
        self._thread = threading.Thread(target=self.run, daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2)


class NaiveRpcClient:
    def __init__(self, endpoint, server_node: int):
        self.endpoint = endpoint
        self.server_node = server_node
        self._msg_id = 0

    def call(self, fn, *args):
        self._msg_id += 1
        frame = pickle.dumps(
            (fn.__module__, fn.__qualname__, args, self._msg_id,
             self.endpoint.node_id)
        )
        self.endpoint.send(self.server_node, frame)
        while True:
            reply = self.endpoint.recv(timeout=10.0)
            if reply is None:
                raise TimeoutError("naive rpc reply timed out")
            msg_id, result = pickle.loads(reply)
            if msg_id == self._msg_id:
                return result

    def stop_server(self) -> None:
        self.endpoint.send(self.server_node,
                           pickle.dumps(("__stop__", "", (), 0, 0)))


# a module-level target the server can resolve by name
def empty() -> None:
    pass


def add(a, b):
    return a + b
