"""Small-RPC fast path: compiled WirePlan vs dynamic TLV vs fused frames.

The Fig.-3 regime this PR attacks: calls with <=256 B of static arguments
over shared memory, where per-message marshalling and per-frame publication
dominate.  The SAME handler function is measured on every path, so the gap
is mechanism, not handler work:

* ``static``  — ``demo/echo_small_static``: compiled-plan request
  (``FLAG_STATIC``) + plan-packed static reply,
* ``dynamic`` — ``demo/echo_small_dyn``: self-describing TLV both ways
  (what every call paid before the WirePlan PR),
* ``fused``   — the static call shipped in ``FLAG_FUSED`` multi-call
  frames (``NodeRuntime.send_fused``) with fused replies,
* ``naive_pickle`` — the vendor-analogue RPC (name resolution + pickle)
  over the *same* shm transport, for the Fig.-3 cross-stack comparison.

Two cost views are recorded:

* ``rtt_us``    — strict one-at-a-time round-trip medians (latency view;
  on small payloads this is transport-floor-bound, so the codec gap shows
  but compresses),
* ``stream_us`` — per-call cost with a 64-call window (throughput view —
  the Fig. 3 "cost per offload" under load, where marshalling dominates).

Results feed ``BENCH_hotpath.json`` (``rpc_us`` section, written by
``benchmarks/batching.py``) and the ratios are gated by
``benchmarks/trend_gate.py``.
"""

from __future__ import annotations

import statistics
import time

import repro.offload.demo_handlers  # noqa: F401 — registers demo/echo_small_*
from repro.core.closure import f2f
from repro.core.registry import default_registry

#: pre-WirePlan numbers for the same echo_small call shapes, measured at the
#: PR-3 revision in this container (shm fabric, forked worker, idle machine)
#: — the denominator of the "vs the old dynamic path" speedups, following
#: the SEED_PUTGET convention in benchmarks/batching.py.
SEED_RPC_US = {
    "static_rtt": 51.8,
    "dynamic_rtt": 55.0,
    "static_stream": 43.9,
    "dynamic_stream": 54.1,
}

_STREAM_WINDOW = 64
_FUSED_BATCH = 16


def _median_us(fn, n, warmup) -> float:
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(n):
        t0 = time.perf_counter_ns()
        fn()
        ts.append((time.perf_counter_ns() - t0) / 1e3)
    return statistics.median(ts)


def _shm_available() -> bool:
    import os

    return (
        hasattr(os, "fork")
        and os.path.isdir("/dev/shm")
        and os.access("/dev/shm", os.W_OK)
    )


def _naive_rtt_us(n: int, warmup: int) -> float | None:
    """Pickle-RPC round trip over its own shm fabric (forked server)."""
    import multiprocessing

    from benchmarks.naive_rpc import NaiveRpcClient, empty
    from repro.comm.shm import ShmFabric

    fab = ShmFabric(2)

    def serve(prefix, num_nodes):
        from benchmarks.naive_rpc import NaiveRpcServer
        from repro.comm.shm import ShmEndpoint

        ep = ShmEndpoint(prefix, 1, num_nodes, peers=[0, 1])
        try:
            NaiveRpcServer(ep).run()
        finally:
            ep.close()

    ctx = multiprocessing.get_context("fork")
    proc = ctx.Process(target=serve, args=(fab.prefix, 2), daemon=True)
    proc.start()
    try:
        client = NaiveRpcClient(fab.endpoint(0), 1)
        us = _median_us(lambda: client.call(empty), n, warmup)
        client.stop_server()
    finally:
        from repro.offload.worker import reap

        reap([proc], timeout=5.0)
        fab.close()
    return us


def measure(smoke: bool = False) -> dict:
    """Run every path; returns the ``rpc_us`` report section."""
    reg = default_registry()
    if not reg.initialised:
        reg.init()
    n_rtt, warm_rtt = (300, 50) if smoke else (2000, 300)
    stream_n, stream_reps = (256, 3) if smoke else (1024, 9)

    from repro.offload.api import OffloadDomain
    from repro.offload.demo_handlers import _ECHO_ARGS
    from repro.offload.worker import reap

    transport = "shm-fork" if _shm_available() else "local-threads"
    if transport == "shm-fork":
        from repro.comm.shm import ShmFabric
        from repro.offload.worker import spawn_shm_workers

        fabric = ShmFabric(2)
        procs = spawn_shm_workers(fabric, [1])
        dom = OffloadDomain(fabric, inline_host=True)
    else:  # no /dev/shm (sandboxes, macOS CI): threads keep the bench alive
        procs = []
        dom = OffloadDomain.local(2, inline_host=True)
    dom.ping(1, timeout=30.0)

    call_static = f2f("demo/echo_small_static", *_ECHO_ARGS)
    call_dyn = f2f("demo/echo_small_dyn", *_ECHO_ARGS)
    host = dom.host
    expect = host.send_sync(1, call_static)
    assert host.send_sync(1, call_dyn) == expect

    def stream(send_one, n=stream_n, window=_STREAM_WINDOW):
        futs = []
        for _ in range(n):
            futs.append(send_one())
            if len(futs) >= window:
                host._inline_wait(futs.pop(0), 30)
        for f in futs:
            host._inline_wait(f, 30)

    def stream_fused(n=stream_n, batch=_FUSED_BATCH, window=4):
        pend = []
        for _ in range(n // batch):
            pend.append(host.send_fused(1, [call_static] * batch))
            if len(pend) >= window:
                for f in pend.pop(0):
                    host._inline_wait(f, 30)
        for b in pend:
            for f in b:
                host._inline_wait(f, 30)

    def stream_us(fn) -> float:
        fn()  # warm
        ts = []
        for _ in range(stream_reps):
            t0 = time.perf_counter()
            fn()
            ts.append(time.perf_counter() - t0)
        return statistics.median(ts) / stream_n * 1e6

    try:
        rtt_static = _median_us(lambda: host.send_sync(1, call_static),
                                n_rtt, warm_rtt)
        rtt_dynamic = _median_us(lambda: host.send_sync(1, call_dyn),
                                 n_rtt, warm_rtt)
        st_static = stream_us(lambda: stream(
            lambda: host.send_async(1, call_static)))
        st_dynamic = stream_us(lambda: stream(
            lambda: host.send_async(1, call_dyn)))
        st_fused = stream_us(stream_fused)
    finally:
        dom.shutdown()
        if procs:
            reap(procs)

    naive = None
    if transport == "shm-fork":
        naive = _naive_rtt_us(max(n_rtt // 4, 50), max(warm_rtt // 4, 10))

    payload_nbytes = sum(s.nbytes for s in call_static.record.arg_specs)
    r = lambda v: round(v, 2)  # noqa: E731
    report = {
        "transport": transport,
        "payload_nbytes": payload_nbytes,
        "stream_window": _STREAM_WINDOW,
        "fused_batch": _FUSED_BATCH,
        "rtt_us": {
            "static": r(rtt_static),
            "dynamic": r(rtt_dynamic),
            "naive_pickle": None if naive is None else r(naive),
        },
        "stream_us": {
            "static": r(st_static),
            "dynamic": r(st_dynamic),
            "fused": r(st_fused),
        },
        "seed_us": SEED_RPC_US,
        "speedup": {
            "static_rtt_vs_dynamic": r(rtt_dynamic / rtt_static),
            "static_rtt_vs_seed_dynamic": r(SEED_RPC_US["dynamic_rtt"]
                                            / rtt_static),
            "static_stream_vs_dynamic": r(st_dynamic / st_static),
            "static_stream_vs_seed_dynamic": r(SEED_RPC_US["dynamic_stream"]
                                               / st_static),
            "fused_stream_vs_static": r(st_static / st_fused),
        },
        # Fig.-3 disambiguation: which HAM path each number measured
        "path_labels": {
            "static": "WirePlan FLAG_STATIC request + plan-packed reply",
            "dynamic": "self-describing TLV request + reply (pre-plan path)",
            "fused": "FLAG_FUSED multi-call frames, batch="
                     f"{_FUSED_BATCH}, fused replies",
            "naive_pickle": "name-resolution + pickle RPC, same shm fabric",
        },
    }
    if naive:
        report["speedup"]["naive_over_ham_static_rtt"] = r(naive / rtt_static)
    return report


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rep = measure(smoke=smoke)
    rows = []
    for k, v in rep["rtt_us"].items():
        if v is not None:
            rows.append((f"rpc/rtt_{k}", v, rep["path_labels"].get(k, "")))
    for k, v in rep["stream_us"].items():
        rows.append((f"rpc/stream_{k}", v,
                     f"window {rep['stream_window']}"))
    for k, v in rep["speedup"].items():
        rows.append((f"rpc/speedup_{k}", v, "ratio"))
    return rows


if __name__ == "__main__":
    import sys

    for name, val, note in run(smoke="--smoke" in sys.argv):
        print(f"{name},{val:.2f},{note}")
