"""Small-RPC fast path: compiled WirePlan vs dynamic TLV vs fused frames.

The Fig.-3 regime this PR attacks: calls with <=256 B of static arguments
over shared memory, where per-message marshalling and per-frame publication
dominate.  The SAME handler function is measured on every path, so the gap
is mechanism, not handler work:

* ``static``  — ``demo/echo_small_static``: compiled-plan request
  (``FLAG_STATIC``) + plan-packed static reply,
* ``dynamic`` — ``demo/echo_small_dyn``: a dynamic handler called with a
  REPEATING argument shape — after the first call this rides the
  shape-keyed cached WirePlan (``FLAG_SHAPED``, see ``core/wireplan``),
* ``dynamic_tlv`` — the SAME dynamic call with the shape cache disabled
  (``HAM_SHAPE_CACHE=0`` in a second forked domain): self-describing TLV
  both ways, what every dynamic call paid before the shape cache,
* ``fused``   — the static call shipped in ``FLAG_FUSED`` multi-call
  frames (``NodeRuntime.send_fused``) with fused replies,
* ``naive_pickle`` — the vendor-analogue RPC (name resolution + pickle)
  over the *same* shm transport, for the Fig.-3 cross-stack comparison.

Cost views recorded:

* ``rtt_us``    — strict one-at-a-time round-trip medians (latency view;
  on small payloads this is transport-floor-bound, so the codec gap shows
  but compresses),
* ``stream_us`` — per-call cost with a 64-call window (throughput view —
  the Fig. 3 "cost per offload" under load, where marshalling dominates),
* ``fused_calls_per_s`` — fire-and-forget throughput:

  - ``oneway_link_pair`` — ``demo/empty_static`` oneways in max-size
    fused frames over one host->worker link (the ">= 1M calls/s per link
    pair" target of the doorbell/fusion PR),
  - ``relay_fused`` / ``relay_unfused`` — 3-node chain (host -> via ->
    dst) of ``_ham/forward`` oneways; the fused leg lets the relay fold
    forwarded inner frames into its egress batches (``FLAG_SEG_SRC``
    segments), the unfused leg disables egress fusion cluster-wide via
    ``HAM_FUSE_EGRESS=0``.  The ratio is the relay-aware-fusion win.

Results feed ``BENCH_hotpath.json`` (``rpc_us`` section, written by
``benchmarks/batching.py``, schema ``hotpath-v3``); the ratios plus the
absolute static-RTT ceiling are gated by ``benchmarks/trend_gate.py``.
"""

from __future__ import annotations

import time

import repro.offload.demo_handlers  # noqa: F401 — registers demo/echo_small_*
from repro.core.closure import f2f
from repro.core.registry import default_registry

from benchmarks._stats import median, median_us

#: pre-WirePlan numbers for the same echo_small call shapes, measured at the
#: PR-3 revision in this container (shm fabric, forked worker, idle machine)
#: — the denominator of the "vs the old dynamic path" speedups, following
#: the SEED_PUTGET convention in benchmarks/batching.py.
SEED_RPC_US = {
    "static_rtt": 51.8,
    "dynamic_rtt": 55.0,
    "static_stream": 43.9,
    "dynamic_stream": 54.1,
}

_STREAM_WINDOW = 64
_FUSED_BATCH = 16

#: paper/ISSUE targets the acceptance section reports against — recorded
#: honestly; a single-core container cannot make the absolute ones (every
#: RTT pays >= 2 context switches, ~70 us wake->resume on this box)
TARGET_STATIC_RTT_US = 10.0
TARGET_FUSED_CALLS_PER_S = 1_000_000
TARGET_DYN_REPEAT_MAX_RATIO = 1.3


def _median_us(fn, n, warmup) -> float:
    return median_us(fn, n, warmup)


def _shm_available() -> bool:
    import os

    return (
        hasattr(os, "fork")
        and os.path.isdir("/dev/shm")
        and os.access("/dev/shm", os.W_OK)
    )


def _naive_rtt_us(n: int, warmup: int) -> float | None:
    """Pickle-RPC round trip over its own shm fabric (forked server)."""
    import multiprocessing

    from benchmarks.naive_rpc import NaiveRpcClient, empty
    from repro.comm.shm import ShmFabric

    fab = ShmFabric(2)

    def serve(prefix, num_nodes):
        from benchmarks.naive_rpc import NaiveRpcServer
        from repro.comm.shm import ShmEndpoint

        ep = ShmEndpoint(prefix, 1, num_nodes, peers=[0, 1])
        try:
            NaiveRpcServer(ep).run()
        finally:
            ep.close()

    ctx = multiprocessing.get_context("fork")
    proc = ctx.Process(target=serve, args=(fab.prefix, 2), daemon=True)
    proc.start()
    try:
        client = NaiveRpcClient(fab.endpoint(0), 1)
        us = _median_us(lambda: client.call(empty), n, warmup)
        client.stop_server()
    finally:
        from repro.offload.worker import reap

        reap([proc], timeout=5.0)
        fab.close()
    return us


def _spawn_domain(num_nodes: int, workers, env: dict | None = None):
    """Fabric + workers + inline host.  ``env`` overrides are set before
    the fork so children inherit them (``NodeRuntime`` reads
    ``HAM_SHAPE_CACHE`` / ``HAM_FUSE_EGRESS`` at construction), then
    restored — the comparison legs below are one env var each."""
    import os

    from repro.offload.api import OffloadDomain

    saved = {k: os.environ.get(k) for k in (env or {})}
    os.environ.update(env or {})
    try:
        if _shm_available():
            from repro.comm.shm import ShmFabric
            from repro.offload.worker import spawn_shm_workers

            fabric = ShmFabric(num_nodes)
            procs = spawn_shm_workers(fabric, workers)
            dom = OffloadDomain(fabric, inline_host=True)
            transport = "shm-fork"
        else:  # no /dev/shm (sandboxes, macOS CI): threads keep it alive
            procs = []
            dom = OffloadDomain.local(num_nodes, inline_host=True)
            transport = "local-threads"
    finally:
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v
    for w in workers:
        dom.ping(w, timeout=30.0)
    return dom, procs, transport


def _teardown(dom, procs) -> None:
    from repro.offload.worker import reap

    dom.shutdown()
    if procs:
        reap(procs)


def _fused_oneway_rate(dom, host, n_batches: int, reps: int) -> float:
    """``demo/empty_static`` oneways (msg_id 0, no reply) in max-size
    FLAG_FUSED frames over one link.  The trailing ping is the completion
    barrier: rings are FIFO, so its reply proves every preceding segment
    was drained and dispatched."""
    from repro.offload.runtime import FUSE_MAX_SEGMENTS

    calls = [(f2f("demo/empty_static"), 0)] * FUSE_MAX_SEGMENTS
    rates = []
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(n_batches):
            host._send_fused_request(1, calls)
        dom.ping(1, timeout=60.0)
        rates.append(n_batches * FUSE_MAX_SEGMENTS
                     / (time.perf_counter() - t0))
    return median(rates)


def _relay_rate(n_calls: int, reps: int, env: dict | None) -> float | None:
    """host -> via(1) -> dst(2) forward-oneway throughput (calls/s).

    The host submits ``_ham/forward`` calls in explicitly fused frames on
    BOTH legs (``_send_fused_request`` ignores the egress toggle), so the
    producer side is identical and the legs differ only in what the RELAY
    does with the inner frames it re-emits mid-drain: with fusion on they
    fold into FLAG_SEG_SRC fused segments, with ``HAM_FUSE_EGRESS=0`` each
    is re-sent standalone (per-frame publication + per-frame dispatch at
    the target).  Completion barrier: a relayed ping over the same path —
    FIFO per hop, so its reply proves every preceding forward was relayed
    *and* executed at the target.
    """
    from repro.core.message import FLAG_STATIC, encode_frame
    from repro.offload.runtime import FUSE_MAX_SEGMENTS

    dom, procs, _ = _spawn_domain(3, [1, 2], env=env)
    try:
        host = dom.host
        key = host.table.key_of("demo/empty_static")
        inner = bytes(encode_frame(key, b"", src_node=dom.host_node,
                                   msg_id=0, flags=FLAG_STATIC))
        batch = [(f2f("_ham/forward", 2, inner), 0)] * FUSE_MAX_SEGMENTS
        ping = f2f("_ham/ping", 0)
        n_batches = max(n_calls // FUSE_MAX_SEGMENTS, 1)

        def burst(nb: int) -> None:
            for _ in range(nb):
                host._send_fused_request(1, batch)
            host._inline_wait(dom.relay(1, 2, ping), 60)

        burst(max(n_batches // 4, 1))  # warm
        rates = []
        for _ in range(reps):
            t0 = time.perf_counter()
            burst(n_batches)
            rates.append(n_batches * FUSE_MAX_SEGMENTS
                         / (time.perf_counter() - t0))
        return median(rates)
    finally:
        _teardown(dom, procs)


def measure(smoke: bool = False) -> dict:
    """Run every path; returns the ``rpc_us`` report section."""
    reg = default_registry()
    if not reg.initialised:
        reg.init()
    n_rtt, warm_rtt = (300, 50) if smoke else (2000, 300)
    stream_n, stream_reps = (256, 3) if smoke else (1024, 9)
    fused_batches, fused_reps = (24, 3) if smoke else (96, 5)
    relay_calls, relay_reps = (512, 2) if smoke else (2048, 3)

    from repro.offload.demo_handlers import _ECHO_ARGS

    dom, procs, transport = _spawn_domain(2, [1])

    call_static = f2f("demo/echo_small_static", *_ECHO_ARGS)
    call_dyn = f2f("demo/echo_small_dyn", *_ECHO_ARGS)
    host = dom.host
    expect = host.send_sync(1, call_static)
    assert host.send_sync(1, call_dyn) == expect

    def stream(send_one, n=stream_n, window=_STREAM_WINDOW):
        futs = []
        for _ in range(n):
            futs.append(send_one())
            if len(futs) >= window:
                host._inline_wait(futs.pop(0), 30)
        for f in futs:
            host._inline_wait(f, 30)

    def stream_fused(n=stream_n, batch=_FUSED_BATCH, window=4):
        pend = []
        for _ in range(n // batch):
            pend.append(host.send_fused(1, [call_static] * batch))
            if len(pend) >= window:
                for f in pend.pop(0):
                    host._inline_wait(f, 30)
        for b in pend:
            for f in b:
                host._inline_wait(f, 30)

    def stream_us(fn) -> float:
        fn()  # warm
        ts = []
        for _ in range(stream_reps):
            t0 = time.perf_counter()
            fn()
            ts.append(time.perf_counter() - t0)
        return median(ts) / stream_n * 1e6

    try:
        rtt_static = _median_us(lambda: host.send_sync(1, call_static),
                                n_rtt, warm_rtt)
        rtt_dynamic = _median_us(lambda: host.send_sync(1, call_dyn),
                                 n_rtt, warm_rtt)
        st_static = stream_us(lambda: stream(
            lambda: host.send_async(1, call_static)))
        st_dynamic = stream_us(lambda: stream(
            lambda: host.send_async(1, call_dyn)))
        st_fused = stream_us(stream_fused)
        fused_oneway = _fused_oneway_rate(dom, host, fused_batches,
                                          fused_reps)
        shape_stats = (host._shape_cache.stats()
                       if host._shape_cache is not None else None)
    finally:
        _teardown(dom, procs)

    # same dynamic call, shape cache OFF (forked children inherit the env):
    # what every repeat-shape dynamic call paid before FLAG_SHAPED
    dom, procs, _ = _spawn_domain(2, [1], env={"HAM_SHAPE_CACHE": "0"})
    try:
        host = dom.host  # the stream helpers read ``host`` at call time
        assert host._shape_cache is None
        assert host.send_sync(1, call_dyn) == expect
        rtt_dyn_tlv = _median_us(lambda: host.send_sync(1, call_dyn),
                                 max(n_rtt // 2, 100), max(warm_rtt // 2, 20))
        st_dyn_tlv = stream_us(lambda: stream(
            lambda: host.send_async(1, call_dyn)))
    finally:
        _teardown(dom, procs)

    relay_fused = _relay_rate(relay_calls, relay_reps, env=None)
    relay_unfused = _relay_rate(relay_calls, relay_reps,
                                env={"HAM_FUSE_EGRESS": "0"})

    naive = None
    if transport == "shm-fork":
        naive = _naive_rtt_us(max(n_rtt // 4, 50), max(warm_rtt // 4, 10))

    payload_nbytes = sum(s.nbytes for s in call_static.record.arg_specs)
    r = lambda v: round(v, 2)  # noqa: E731
    report = {
        "transport": transport,
        "payload_nbytes": payload_nbytes,
        "stream_window": _STREAM_WINDOW,
        "fused_batch": _FUSED_BATCH,
        "rtt_us": {
            "static": r(rtt_static),
            "dynamic": r(rtt_dynamic),
            "dynamic_tlv": r(rtt_dyn_tlv),
            "naive_pickle": None if naive is None else r(naive),
        },
        "stream_us": {
            "static": r(st_static),
            "dynamic": r(st_dynamic),
            "dynamic_tlv": r(st_dyn_tlv),
            "fused": r(st_fused),
        },
        "fused_calls_per_s": {
            "oneway_link_pair": round(fused_oneway),
            "relay_fused": round(relay_fused),
            "relay_unfused": round(relay_unfused),
        },
        "shape_cache": shape_stats,
        "seed_us": SEED_RPC_US,
        "speedup": {
            "static_rtt_vs_dynamic": r(rtt_dynamic / rtt_static),
            "static_rtt_vs_seed_dynamic": r(SEED_RPC_US["dynamic_rtt"]
                                            / rtt_static),
            "static_stream_vs_dynamic": r(st_dynamic / st_static),
            "static_stream_vs_seed_dynamic": r(SEED_RPC_US["dynamic_stream"]
                                               / st_static),
            "fused_stream_vs_static": r(st_static / st_fused),
            # >= 1/1.3 ~ 0.77 means the repeat-shape dynamic call is within
            # the 1.3x-of-static target (higher is better, gate-friendly)
            "dynamic_repeat_shape_rtt_vs_static": r(rtt_static / rtt_dynamic),
            "dynamic_shaped_rtt_vs_tlv": r(rtt_dyn_tlv / rtt_dynamic),
            "dynamic_shaped_stream_vs_tlv": r(st_dyn_tlv / st_dynamic),
            "relay_fused_vs_unfused": r(relay_fused / relay_unfused),
        },
        "targets": {
            "static_rtt_us_lt": TARGET_STATIC_RTT_US,
            "fused_calls_per_s_ge": TARGET_FUSED_CALLS_PER_S,
            "dynamic_repeat_rtt_max_ratio": TARGET_DYN_REPEAT_MAX_RATIO,
        },
        # Fig.-3 disambiguation: which HAM path each number measured
        "path_labels": {
            "static": "WirePlan FLAG_STATIC request + plan-packed reply",
            "dynamic": "repeat-shape dynamic: shape-keyed cached WirePlan "
                       "(FLAG_SHAPED) after first call",
            "dynamic_tlv": "same dynamic call, HAM_SHAPE_CACHE=0: "
                           "self-describing TLV both ways",
            "fused": "FLAG_FUSED multi-call frames, batch="
                     f"{_FUSED_BATCH}, fused replies",
            "naive_pickle": "name-resolution + pickle RPC, same shm fabric",
            "oneway_link_pair": "empty_static oneways, max fused frames, "
                                "host->worker link, FIFO-ping barrier",
            "relay_fused": "host->via->dst _ham/forward oneways, relay "
                           "egress fused (FLAG_SEG_SRC segments)",
            "relay_unfused": "same chain, HAM_FUSE_EGRESS=0 (standalone "
                             "re-sends at the relay)",
        },
    }
    if naive:
        report["speedup"]["naive_over_ham_static_rtt"] = r(naive / rtt_static)
    return report


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rep = measure(smoke=smoke)
    rows = []
    for k, v in rep["rtt_us"].items():
        if v is not None:
            rows.append((f"rpc/rtt_{k}", v, rep["path_labels"].get(k, "")))
    for k, v in rep["stream_us"].items():
        rows.append((f"rpc/stream_{k}", v,
                     f"window {rep['stream_window']}"))
    for k, v in rep["fused_calls_per_s"].items():
        rows.append((f"rpc/calls_per_s_{k}", v,
                     rep["path_labels"].get(k, "")))
    for k, v in rep["speedup"].items():
        rows.append((f"rpc/speedup_{k}", v, "ratio"))
    return rows


if __name__ == "__main__":
    import sys

    for name, val, note in run(smoke="--smoke" in sys.argv):
        print(f"{name},{val:.2f},{note}")
