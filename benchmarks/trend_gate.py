"""Benchmark trend gate: fail CI when recorded speedups regress.

Compares the *speedup* metrics of freshly produced ``BENCH_cluster.json`` /
``BENCH_hotpath.json`` against the committed baselines.  Speedups are
ratios (pipelined/serial, optimised/seed), which makes them roughly
machine-independent — unlike absolute calls/sec, they are comparable
between a committed full run and a CI smoke run, so the smoke job can gate
on them: a speedup collapse means a coalescing/pipelining path stopped
working, not that the runner was slow.

Usage (the CI bench-smoke job)::

    cp BENCH_cluster.json BENCH_hotpath.json baseline/   # committed values
    python -m benchmarks.run --smoke                     # rewrites BENCH_*
    python -m benchmarks.trend_gate --baseline-dir baseline

Exit status 1 when any tracked metric falls below
``(1 - tolerance) * baseline`` (default tolerance 0.30, i.e. a >30%
regression), or when a baseline metric is missing from the fresh run (a
dropped/renamed metric must not silently shrink gate coverage).  Metrics
not yet in the baseline are reported and skipped — schema growth must not
break older baselines.

Smoke-run comparability: most tracked metrics are ratios and survive the
smoke job's tiny sizes, but a few are *size-dependent* — the x64 batching
speedup needs enough frames to amortise, and smoke only runs the smallest
put/get size.  When the fresh report says ``"smoke": true``, paths listed
in ``SMOKE_SIZE_DEPENDENT`` are skipped and baseline leaves absent from
the fresh run are skipped rather than failed (smoke runs fewer sizes by
design).  Full runs keep the strict dropped-metric check.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

_REPO_ROOT = Path(__file__).resolve().parents[1]

#: (file, [path, ...]) — dotted paths of the ratio metrics under gate.
#: Dict leaves compare key-by-key.
TRACKED = {
    "BENCH_cluster.json": [
        "sweep.round_robin.4.speedup",
        "sweep.least_outstanding.4.speedup",
        "resize.speedup_4w_over_2w",
    ],
    "BENCH_hotpath.json": [
        "batching_speedup_x64",
        "putget_median_speedup_vs_seed",
        # WirePlan/fusion PR: static-vs-dynamic and fused-vs-static ratios
        # (in-run ratios — machine-independent like the others)
        "rpc_us.speedup.static_rtt_vs_dynamic",
        "rpc_us.speedup.static_stream_vs_dynamic",
        "rpc_us.speedup.fused_stream_vs_static",
    ],
}


#: metrics whose value depends on the run's sizes, not just the code path —
#: meaningless to compare between a full baseline and a smoke fresh run
SMOKE_SIZE_DEPENDENT = {
    "BENCH_hotpath.json": ["batching_speedup_x64"],
}


def _dig(doc, dotted: str):
    node = doc
    for part in dotted.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node


def _leaves(dotted: str, value):
    """Flatten a metric to (path, float) leaves (dict => one per key)."""
    if isinstance(value, dict):
        for k, v in value.items():
            yield from _leaves(f"{dotted}.{k}", v)
    elif isinstance(value, (int, float)):
        yield dotted, float(value)


def compare(baseline: dict, fresh: dict, paths, tolerance: float,
            smoke_skip=()):
    """Yield ``(path, base, new, ok)`` for every tracked leaf.

    ``ok`` is True/False for a compared leaf, or None for a skip: a leaf
    missing in the baseline (new metric), a smoke-size-dependent path in a
    smoke run, or a smoke run that did not produce a baseline leaf (smoke
    runs fewer sizes by design).  A baseline leaf missing from a *full*
    fresh run yields ``ok=False`` with ``new=None`` — a dropped/renamed
    metric must not silently shrink gate coverage."""
    fresh_is_smoke = bool(fresh.get("smoke"))
    for dotted in paths:
        if fresh_is_smoke and dotted in smoke_skip:
            yield dotted, None, None, None
            continue
        base_leaves = dict(_leaves(dotted, _dig(baseline, dotted)))
        new_leaves = dict(_leaves(dotted, _dig(fresh, dotted)))
        if not base_leaves:
            yield dotted, None, new_leaves or None, None
            continue
        for path, base in sorted(base_leaves.items()):
            new = new_leaves.get(path)
            if new is None:
                # smoke runs produce a size subset: skip, don't fail
                yield path, base, None, (None if fresh_is_smoke else False)
                continue
            yield path, base, new, new >= (1.0 - tolerance) * base


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline-dir", type=Path, required=True,
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--fresh-dir", type=Path, default=_REPO_ROOT,
                    help="directory with freshly produced BENCH_*.json")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="allowed fractional regression (default 0.30)")
    opts = ap.parse_args(argv)

    failures = 0
    checked = 0
    for fname, paths in TRACKED.items():
        base_path = opts.baseline_dir / fname
        fresh_path = opts.fresh_dir / fname
        if not base_path.exists() or not fresh_path.exists():
            print(f"SKIP {fname}: missing "
                  f"{'baseline' if not base_path.exists() else 'fresh'} file")
            continue
        baseline = json.loads(base_path.read_text())
        fresh = json.loads(fresh_path.read_text())
        for path, base, new, ok in compare(baseline, fresh, paths,
                                           opts.tolerance,
                                           SMOKE_SIZE_DEPENDENT.get(fname, ())):
            if ok is None:
                if base is None:
                    # not in the baseline yet (new metric) or size-dependent
                    # under smoke: skip until comparable
                    print(f"SKIP {fname}:{path} (not comparable: new metric "
                          "or smoke-size-dependent)")
                else:
                    print(f"SKIP {fname}:{path} (size absent from smoke run)")
                continue
            if new is None:
                # in the baseline but GONE from a FULL fresh run: a dropped
                # or renamed metric must not silently shrink coverage
                print(f"REGRESSION  {fname}:{path}  baseline={base:.2f}"
                      "  fresh=MISSING")
                checked += 1
                failures += 1
                continue
            checked += 1
            floor = (1.0 - opts.tolerance) * base
            status = "ok" if ok else "REGRESSION"
            print(f"{status:>10}  {fname}:{path}  baseline={base:.2f}  "
                  f"fresh={new:.2f}  floor={floor:.2f}")
            if not ok:
                failures += 1
    if checked == 0:
        print("trend gate: nothing compared — refusing to pass vacuously")
        return 1
    if failures:
        print(f"trend gate: {failures}/{checked} tracked speedups regressed "
              f">{opts.tolerance:.0%}")
        return 1
    print(f"trend gate: {checked} tracked speedups within "
          f"{opts.tolerance:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
