"""Benchmark trend gate: fail CI when recorded speedups regress.

Compares the *speedup* metrics of freshly produced ``BENCH_cluster.json`` /
``BENCH_hotpath.json`` against the committed baselines.  Speedups are
ratios (pipelined/serial, optimised/seed), which makes them roughly
machine-independent — unlike absolute calls/sec, they are comparable
between a committed full run and a CI smoke run, so the smoke job can gate
on them: a speedup collapse means a coalescing/pipelining path stopped
working, not that the runner was slow.

Usage (the CI bench-smoke job)::

    cp BENCH_cluster.json BENCH_hotpath.json baseline/   # committed values
    python -m benchmarks.run --smoke                     # rewrites BENCH_*
    python -m benchmarks.trend_gate --baseline-dir baseline

Exit status 1 when any tracked metric falls below
``(1 - tolerance) * baseline`` (default tolerance 0.30, i.e. a >30%
regression), or when a baseline metric is missing from the fresh run (a
dropped/renamed metric must not silently shrink gate coverage).  Metrics
not yet in the baseline are reported and skipped — schema growth must not
break older baselines.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

_REPO_ROOT = Path(__file__).resolve().parents[1]

#: (file, [path, ...]) — dotted paths of the ratio metrics under gate.
#: Dict leaves compare key-by-key.
TRACKED = {
    "BENCH_cluster.json": [
        "sweep.round_robin.4.speedup",
        "sweep.least_outstanding.4.speedup",
        "resize.speedup_4w_over_2w",
    ],
    "BENCH_hotpath.json": [
        "batching_speedup_x64",
        "putget_median_speedup_vs_seed",
    ],
}


def _dig(doc, dotted: str):
    node = doc
    for part in dotted.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node


def _leaves(dotted: str, value):
    """Flatten a metric to (path, float) leaves (dict => one per key)."""
    if isinstance(value, dict):
        for k, v in value.items():
            yield from _leaves(f"{dotted}.{k}", v)
    elif isinstance(value, (int, float)):
        yield dotted, float(value)


def compare(baseline: dict, fresh: dict, paths, tolerance: float):
    """Yield (path, base, new, ok|None) for every tracked leaf; ``ok`` is
    None when the leaf is missing on either side (skipped, not failed).
    A tracked path absent from the *baseline* is surfaced too — a silent
    drop would shrink gate coverage on a metric rename with CI green."""
    for dotted in paths:
        base_leaves = dict(_leaves(dotted, _dig(baseline, dotted)))
        new_leaves = dict(_leaves(dotted, _dig(fresh, dotted)))
        if not base_leaves:
            yield dotted, None, new_leaves or None, None
            continue
        for path, base in sorted(base_leaves.items()):
            new = new_leaves.get(path)
            if new is None:
                yield path, base, None, None
                continue
            yield path, base, new, new >= (1.0 - tolerance) * base


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline-dir", type=Path, required=True,
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--fresh-dir", type=Path, default=_REPO_ROOT,
                    help="directory with freshly produced BENCH_*.json")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="allowed fractional regression (default 0.30)")
    opts = ap.parse_args(argv)

    failures = 0
    checked = 0
    for fname, paths in TRACKED.items():
        base_path = opts.baseline_dir / fname
        fresh_path = opts.fresh_dir / fname
        if not base_path.exists() or not fresh_path.exists():
            print(f"SKIP {fname}: missing "
                  f"{'baseline' if not base_path.exists() else 'fresh'} file")
            continue
        baseline = json.loads(base_path.read_text())
        fresh = json.loads(fresh_path.read_text())
        for path, base, new, ok in compare(baseline, fresh, paths,
                                           opts.tolerance):
            if ok is None:
                if base is None:
                    # not in the baseline yet (new metric): skip until a
                    # refreshed baseline is committed
                    print(f"SKIP {fname}:{path} (missing in baseline)")
                else:
                    # in the baseline but GONE from the fresh run: a dropped
                    # or renamed metric must not silently shrink coverage
                    print(f"REGRESSION  {fname}:{path}  baseline={base:.2f}"
                          "  fresh=MISSING")
                    checked += 1
                    failures += 1
                continue
            checked += 1
            floor = (1.0 - opts.tolerance) * base
            status = "ok" if ok else "REGRESSION"
            print(f"{status:>10}  {fname}:{path}  baseline={base:.2f}  "
                  f"fresh={new:.2f}  floor={floor:.2f}")
            if not ok:
                failures += 1
    if checked == 0:
        print("trend gate: nothing compared — refusing to pass vacuously")
        return 1
    if failures:
        print(f"trend gate: {failures}/{checked} tracked speedups regressed "
              f">{opts.tolerance:.0%}")
        return 1
    print(f"trend gate: {checked} tracked speedups within "
          f"{opts.tolerance:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
