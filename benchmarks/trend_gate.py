"""Benchmark trend gate: fail CI when recorded speedups regress.

Compares the *speedup* metrics of freshly produced ``BENCH_cluster.json`` /
``BENCH_hotpath.json`` against the committed baselines.  Speedups are
ratios (pipelined/serial, optimised/seed), which makes them roughly
machine-independent — unlike absolute calls/sec, they are comparable
between a committed full run and a CI smoke run, so the smoke job can gate
on them: a speedup collapse means a coalescing/pipelining path stopped
working, not that the runner was slow.

Usage (the CI bench-smoke job)::

    cp BENCH_cluster.json BENCH_hotpath.json baseline/   # committed values
    python -m benchmarks.run --smoke                     # rewrites BENCH_*
    python -m benchmarks.trend_gate --baseline-dir baseline

Exit status 1 when any tracked metric falls below
``(1 - tolerance) * baseline`` (default tolerance 0.30, i.e. a >30%
regression), or when a baseline metric is missing from the fresh run (a
dropped/renamed metric must not silently shrink gate coverage).  Metrics
not yet in the baseline are reported and skipped — schema growth must not
break older baselines.

A second gate class, ``CEILINGS``, covers lower-is-better ABSOLUTE
metrics (currently the static small-RPC round trip): the fresh value must
stay under a fixed ceiling regardless of the baseline, because a
transport-wide pathology (e.g. doorbell wakeups lost, every receive eating
the park timeout) slows every leg of a ratio equally and sails through
the relative checks.

Smoke-run comparability: most tracked metrics are ratios and survive the
smoke job's tiny sizes, but a few are *size-dependent* — the x64 batching
speedup needs enough frames to amortise, and smoke only runs the smallest
put/get size.  When the fresh report says ``"smoke": true``, paths listed
in ``SMOKE_SIZE_DEPENDENT`` are skipped and baseline leaves absent from
the fresh run are skipped rather than failed (smoke runs fewer sizes by
design).  Full runs keep the strict dropped-metric check.

Trend-slope gate (``--history-dir``)
------------------------------------

The committed-point check above cannot see *creep*: three consecutive -15%
regressions each pass a 30% tolerance while the metric quietly halves.
With ``--history-dir`` the gate also persists every run's tracked leaves
to ``<dir>/<file>.history.jsonl`` (CI caches the directory between runs
and uploads it as an artifact) and fits a least-squares line over the last
``--slope-window`` runs of each leaf: when the fitted decline across the
window exceeds ``--slope-tolerance`` (default 0.30, same spirit as the
point tolerance), the run fails with ``TREND`` even though every
individual point was within tolerance of the committed baseline.  Leaves
need ``--slope-min-runs`` history points (default 3) before the slope is
judged — a fresh cache never fails vacuously.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

_REPO_ROOT = Path(__file__).resolve().parents[1]

#: (file, [path, ...]) — dotted paths of the ratio metrics under gate.
#: Dict leaves compare key-by-key.
TRACKED = {
    "BENCH_cluster.json": [
        "sweep.round_robin.4.speedup",
        "sweep.least_outstanding.4.speedup",
        "resize.speedup_4w_over_2w",
        # data-plane crash recovery: fraction of replicated buffers intact
        # after kill 4->3 (must stay 1.0 — any dip is a recovery bug)
        "recovery.recovered_fraction",
        # host crash + in-place rebuild: directory reconstructed from
        # survivor dir_dump shards (must stay 1.0, same zero tolerance)
        "recovery.host_restart.recovered_fraction",
        # active-access data plane: mutate-at-data speedup over the naive
        # get-mutate-put round trip (dict leaf per buffer size; smoke runs
        # produce their own sizes and skip the full-run leaves), and the
        # refresh-mode convergence witness (must stay 1.0 — a replica
        # serving stale bytes after a committed mutation is a coherence
        # bug, not a slowdown)
        "dataplane.speedup",
        "dataplane.invalidate.converged_fraction",
    ],
    "BENCH_hotpath.json": [
        "batching_speedup_x64",
        "putget_median_speedup_vs_seed",
        # WirePlan/fusion PR: static-vs-dynamic and fused-vs-static ratios
        # (in-run ratios — machine-independent like the others)
        "rpc_us.speedup.static_rtt_vs_dynamic",
        "rpc_us.speedup.static_stream_vs_dynamic",
        "rpc_us.speedup.fused_stream_vs_static",
        # doorbell/shape-cache/relay-fusion PR: the repeat-shape dynamic
        # call must stay within 1.3x of static (ratio >= ~0.77), the
        # shaped-vs-TLV stream win must not collapse, and relayed fused
        # throughput must track the unfused leg
        "rpc_us.speedup.dynamic_repeat_shape_rtt_vs_static",
        "rpc_us.speedup.dynamic_shaped_stream_vs_tlv",
        "rpc_us.speedup.relay_fused_vs_unfused",
    ],
    "BENCH_serving.json": [
        # worker-driven serving PR: aggregate decode throughput, its ratio
        # over the lockstep drive, and kill-under-traffic recovery (the
        # recovery leaves must stay 1.0 — zero tolerance below)
        "serving.tokens_per_s",
        "serving.speedup_vs_lockstep",
        "serving.kill_recovery.slo_held",
        "serving.kill_recovery.completed_fraction",
    ],
}

#: ``file:path`` -> ceiling — LOWER-is-better absolute gates, judged against
#: the FRESH run alone (no baseline ratio): these catch a mechanism falling
#: off a cliff (e.g. the doorbell losing wakeups and every RTT eating the
#: 2 ms park timeout) that a ratio gate cannot see because both legs slow
#: down together.  Ceilings are deliberately generous — they must hold on a
#: loaded single-core CI runner, not just an idle multi-core box (measured
#: ~27 us multi-core, ~400 us single-core; park-timeout pathology ~4000 us).
#: Ceiling leaves are recorded in the slope history for visibility but are
#: excluded from the slope fit (the fitted-decline check models
#: higher-is-better ratios).
CEILINGS = {
    "BENCH_hotpath.json:rpc_us.rtt_us.static": 1500.0,
    # chain-replicated put: host sends bytes ONCE, the primary streams the
    # replica chain — put must stay under an absolute 1.5x of the MEASURED
    # host-sequential leg (host pushes the bytes to every holder itself;
    # full-run target is 1.3x, the ceiling holds for smoke too).  This
    # ratio is core-count independent — overhead vs replicas=0 is not (it
    # floors at ~(R+1)x on a single-core runner).  Breaching it means the
    # chain stopped streaming (e.g. a forward serialised behind a blocked
    # flush, as in the drain-batch self-deadlock this PR fixed) — that
    # pathology parks a hop on a 30 s timeout, far past any ceiling.
    "BENCH_cluster.json:dataplane.chain_put.replicas1.vs_host_sequential_x":
        1.5,
    # the worker-driven serving contract: ~1 admission RPC per request and
    # nothing per token — at max_new_tokens >= 16 that is <= 1/16 with
    # margin for cancel/recovery traffic.  Breaching 0.1 means the host is
    # back in the per-token loop.
    "BENCH_serving.json:serving.host_rpcs_per_token": 0.1,
}


#: metrics whose value depends on the run's sizes, not just the code path —
#: meaningless to compare between a full baseline and a smoke fresh run
SMOKE_SIZE_DEPENDENT = {
    "BENCH_hotpath.json": ["batching_speedup_x64"],
    # absolute tokens/s depends on request count/budget and the runner;
    # the speedup ratio also shifts with the smoke leg's shorter decode
    # budgets (fewer steps amortising each admission)
    "BENCH_serving.json": ["serving.tokens_per_s",
                           "serving.speedup_vs_lockstep"],
}

#: correctness leaves gated with ZERO tolerance (point and slope): these are
#: fractions of things that must not be lost, not timings — a 30%-tolerated
#: dip would wave through a real recovery bug
ZERO_TOLERANCE = {
    "BENCH_cluster.json:recovery.recovered_fraction",
    "BENCH_cluster.json:recovery.host_restart.recovered_fraction",
    # a committed mutation's replicas must hold the new bytes — fraction
    # is 0 or 1, any dip is a coherence bug
    "BENCH_cluster.json:dataplane.invalidate.converged_fraction",
    # kill-a-worker-under-live-traffic: every request must finish with its
    # full token budget and the SLO must hold through the failure
    "BENCH_serving.json:serving.kill_recovery.slo_held",
    "BENCH_serving.json:serving.kill_recovery.completed_fraction",
}


def _dig(doc, dotted: str):
    node = doc
    for part in dotted.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node


def _leaves(dotted: str, value):
    """Flatten a metric to (path, float) leaves (dict => one per key)."""
    if isinstance(value, dict):
        for k, v in value.items():
            yield from _leaves(f"{dotted}.{k}", v)
    elif isinstance(value, (int, float)):
        yield dotted, float(value)


def compare(baseline: dict, fresh: dict, paths, tolerance: float,
            smoke_skip=(), zero_tol=()):
    """Yield ``(path, base, new, ok)`` for every tracked leaf.

    ``ok`` is True/False for a compared leaf, or None for a skip: a leaf
    missing in the baseline (new metric), a smoke-size-dependent path in a
    smoke run, or a smoke run that did not produce a baseline leaf (smoke
    runs fewer sizes by design).  A baseline leaf missing from a *full*
    fresh run yields ``ok=False`` with ``new=None`` — a dropped/renamed
    metric must not silently shrink gate coverage."""
    fresh_is_smoke = bool(fresh.get("smoke"))
    for dotted in paths:
        if fresh_is_smoke and dotted in smoke_skip:
            yield dotted, None, None, None
            continue
        base_leaves = dict(_leaves(dotted, _dig(baseline, dotted)))
        new_leaves = dict(_leaves(dotted, _dig(fresh, dotted)))
        if not base_leaves:
            yield dotted, None, new_leaves or None, None
            continue
        for path, base in sorted(base_leaves.items()):
            new = new_leaves.get(path)
            if new is None:
                # smoke runs produce a size subset: skip, don't fail
                yield path, base, None, (None if fresh_is_smoke else False)
                continue
            tol = 0.0 if path in zero_tol else tolerance
            yield path, base, new, new >= (1.0 - tol) * base


def _fresh_leaves(fresh: dict, paths, smoke_skip) -> dict[str, float]:
    """Tracked leaves present in a fresh report (history record shape);
    smoke-size-dependent paths are dropped from smoke runs so a history
    series never mixes incomparable sizes."""
    fresh_is_smoke = bool(fresh.get("smoke"))
    out: dict[str, float] = {}
    for dotted in paths:
        if fresh_is_smoke and dotted in smoke_skip:
            continue
        out.update(_leaves(dotted, _dig(fresh, dotted)))
    return out


def append_history(history_file: Path, fresh: dict, paths, smoke_skip,
                   now: float) -> list[dict]:
    """Append this run's tracked leaves to the jsonl history; returns the
    full (parsed) history including the new entry."""
    entries: list[dict] = []
    if history_file.exists():
        for line in history_file.read_text().splitlines():
            line = line.strip()
            if not line:
                continue
            try:
                entries.append(json.loads(line))
            except json.JSONDecodeError:
                continue  # a truncated cache write must not kill the gate
    record = {
        "t": round(now, 1),
        "smoke": bool(fresh.get("smoke")),
        "metrics": _fresh_leaves(fresh, paths, smoke_skip),
    }
    entries.append(record)
    history_file.parent.mkdir(parents=True, exist_ok=True)
    with history_file.open("a") as f:
        f.write(json.dumps(record) + "\n")
    return entries


def fitted_decline(values) -> float:
    """Least-squares slope over run index, expressed as the fitted total
    *fractional change* across the window (negative = decline): slope *
    (n-1) / mean.  Ratios hover around a constant, so the mean is a sane
    scale."""
    n = len(values)
    if n < 2:
        return 0.0
    mean_x = (n - 1) / 2.0
    mean_y = sum(values) / n
    num = sum((i - mean_x) * (y - mean_y) for i, y in enumerate(values))
    den = sum((i - mean_x) ** 2 for i in range(n))
    if den == 0 or mean_y == 0:
        return 0.0
    slope = num / den
    return slope * (n - 1) / mean_y


def slope_check(entries: list[dict], paths_present, *, window: int,
                min_runs: int, tolerance: float, zero_tol=()):
    """Yield ``(path, n_runs, decline, ok)`` per leaf with enough history;
    ``ok`` False when the fitted decline across the window exceeds the
    tolerance (zero-tolerance leaves fail on any decline)."""
    series: dict[str, list[float]] = {}
    for entry in entries:
        for path, value in entry.get("metrics", {}).items():
            series.setdefault(path, []).append(float(value))
    for path in sorted(paths_present):
        values = series.get(path, [])[-window:]
        if len(values) < min_runs:
            continue
        decline = fitted_decline(values)
        tol = 0.0 if path in zero_tol else tolerance
        yield path, len(values), decline, decline >= -tol


def main(argv=None) -> int:
    import time

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline-dir", type=Path, required=True,
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--fresh-dir", type=Path, default=_REPO_ROOT,
                    help="directory with freshly produced BENCH_*.json")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="allowed fractional regression (default 0.30)")
    ap.add_argument("--history-dir", type=Path, default=None,
                    help="persist per-run tracked metrics here and gate on "
                         "the fitted trend slope, not just this point")
    ap.add_argument("--slope-window", type=int, default=10,
                    help="history runs the slope is fitted over (default 10)")
    ap.add_argument("--slope-min-runs", type=int, default=3,
                    help="history points required before the slope gates "
                         "(default 3)")
    ap.add_argument("--slope-tolerance", type=float, default=0.30,
                    help="allowed fitted decline across the window "
                         "(default 0.30)")
    opts = ap.parse_args(argv)

    failures = 0
    checked = 0
    now = time.time()
    for fname, paths in TRACKED.items():
        base_path = opts.baseline_dir / fname
        fresh_path = opts.fresh_dir / fname
        if not base_path.exists() or not fresh_path.exists():
            print(f"SKIP {fname}: missing "
                  f"{'baseline' if not base_path.exists() else 'fresh'} file")
            continue
        baseline = json.loads(base_path.read_text())
        fresh = json.loads(fresh_path.read_text())
        smoke_skip = SMOKE_SIZE_DEPENDENT.get(fname, ())
        zero_tol = {p.split(":", 1)[1] for p in ZERO_TOLERANCE
                    if p.startswith(fname + ":")}
        ceil_paths = {p.split(":", 1)[1]: v for p, v in CEILINGS.items()
                      if p.startswith(fname + ":")}
        if opts.history_dir is not None:
            entries = append_history(
                opts.history_dir / f"{fname}.history.jsonl", fresh,
                list(paths) + sorted(ceil_paths), smoke_skip, now,
            )
            # slope fit covers the higher-is-better ratio leaves only;
            # ceiling leaves ride the history for visibility
            present = _fresh_leaves(fresh, paths, smoke_skip)
            for path, n, decline, ok in slope_check(
                entries, present, window=opts.slope_window,
                min_runs=opts.slope_min_runs,
                tolerance=opts.slope_tolerance, zero_tol=zero_tol,
            ):
                checked += 1
                status = "ok" if ok else "TREND"
                print(f"{status:>10}  {fname}:{path}  slope over {n} runs: "
                      f"{decline:+.1%} fitted "
                      f"(floor -{opts.slope_tolerance:.0%})")
                if not ok:
                    failures += 1
        # absolute ceilings (lower is better), judged on the FRESH run
        # alone — no baseline ratio, no smoke skip: the ceiling is already
        # sized for the slowest supported runner
        for path, ceiling in sorted(ceil_paths.items()):
            value = _dig(fresh, path)
            checked += 1
            if not isinstance(value, (int, float)):
                print(f"REGRESSION  {fname}:{path}  "
                      f"ceiling={ceiling:.0f}  fresh=MISSING")
                failures += 1
                continue
            ok = float(value) <= ceiling
            status = "ok" if ok else "CEILING"
            print(f"{status:>10}  {fname}:{path}  fresh={value:.2f}  "
                  f"ceiling={ceiling:.2f} (lower is better)")
            if not ok:
                failures += 1
        for path, base, new, ok in compare(baseline, fresh, paths,
                                           opts.tolerance,
                                           smoke_skip, zero_tol):
            if ok is None:
                if base is None:
                    # not in the baseline yet (new metric) or size-dependent
                    # under smoke: skip until comparable
                    print(f"SKIP {fname}:{path} (not comparable: new metric "
                          "or smoke-size-dependent)")
                else:
                    print(f"SKIP {fname}:{path} (size absent from smoke run)")
                continue
            if new is None:
                # in the baseline but GONE from a FULL fresh run: a dropped
                # or renamed metric must not silently shrink coverage
                print(f"REGRESSION  {fname}:{path}  baseline={base:.2f}"
                      "  fresh=MISSING")
                checked += 1
                failures += 1
                continue
            checked += 1
            floor = (1.0 - opts.tolerance) * base
            status = "ok" if ok else "REGRESSION"
            print(f"{status:>10}  {fname}:{path}  baseline={base:.2f}  "
                  f"fresh={new:.2f}  floor={floor:.2f}")
            if not ok:
                failures += 1
    if checked == 0:
        print("trend gate: nothing compared — refusing to pass vacuously")
        return 1
    if failures:
        print(f"trend gate: {failures}/{checked} tracked speedups regressed "
              f">{opts.tolerance:.0%}")
        return 1
    print(f"trend gate: {checked} tracked speedups within "
          f"{opts.tolerance:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
