"""put/get bandwidth through the offload data plane (paper Fig. 2 surface)."""

from __future__ import annotations

import time

import numpy as np

import repro.offload.demo_handlers  # noqa: F401
from repro.core.registry import default_registry
from repro.offload.api import OffloadDomain


def run() -> list[tuple[str, float, str]]:
    reg = default_registry()
    if not reg.initialised:
        reg.init()
    dom = OffloadDomain.local(2)
    rows = []
    for nbytes, label in ((1 << 16, "64KB"), (1 << 22, "4MB"), (1 << 26, "64MB")):
        arr = np.random.default_rng(1).standard_normal(nbytes // 8)
        ptr = dom.allocate(1, arr.shape, "float64")
        t0 = time.perf_counter()
        reps = max(1, (1 << 26) // nbytes)
        for _ in range(reps):
            dom.put(arr, ptr)
        dt = (time.perf_counter() - t0) / reps
        rows.append((f"putget/put_{label}", dt * 1e6, f"{nbytes/dt/1e9:.2f} GB/s"))
        t0 = time.perf_counter()
        for _ in range(reps):
            dom.get(ptr)
        dt = (time.perf_counter() - t0) / reps
        rows.append((f"putget/get_{label}", dt * 1e6, f"{nbytes/dt/1e9:.2f} GB/s"))
        dom.free(ptr)
    dom.shutdown()
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val:.1f},{note}")
