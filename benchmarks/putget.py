"""put/get bandwidth through the offload data plane (paper Fig. 2 surface).

``run`` reports the mean over reps (the methodology the seed numbers were
recorded with); ``run_median`` times each call individually and reports the
median, which is robust against scheduler/GC stragglers — BENCH_hotpath.json
records both.
"""

from __future__ import annotations

import time

import numpy as np

import repro.offload.demo_handlers  # noqa: F401
from repro.core.registry import default_registry
from repro.offload.api import OffloadDomain

from benchmarks._stats import median


#: (nbytes, label) per measured transfer size; smoke trims to the smallest
_SIZES = ((1 << 16, "64KB"), (1 << 22, "4MB"), (1 << 26, "64MB"))
_SIZES_SMOKE = ((1 << 16, "64KB"),)


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    reg = default_registry()
    if not reg.initialised:
        reg.init()
    dom = OffloadDomain.local(2)
    rows = []
    for wire in (False, True):
        dom.direct_data_plane = not wire
        prefix = "wire_" if wire else ""
        for nbytes, label in (_SIZES_SMOKE if smoke else _SIZES):
            arr = np.random.default_rng(1).standard_normal(nbytes // 8)
            ptr = dom.allocate(1, arr.shape, "float64")
            t0 = time.perf_counter()
            reps = 1 if smoke else max(4, (1 << 27) // nbytes)  # >=32 at 4MB
            for _ in range(reps):
                dom.put(arr, ptr)
            dt = (time.perf_counter() - t0) / reps
            rows.append((f"putget/{prefix}put_{label}", dt * 1e6,
                         f"{nbytes/dt/1e9:.2f} GB/s"))
            t0 = time.perf_counter()
            for _ in range(reps):
                dom.get(ptr)
            dt = (time.perf_counter() - t0) / reps
            rows.append((f"putget/{prefix}get_{label}", dt * 1e6,
                         f"{nbytes/dt/1e9:.2f} GB/s"))
            dom.free(ptr)
    dom.shutdown()
    return rows


def run_median(smoke: bool = False) -> dict[str, float]:
    """Median us per put/get call, one timing sample per call.

    Reports the default (direct in-process) data plane and the wire path
    (``wire_`` prefix) side by side.
    """
    reg = default_registry()
    if not reg.initialised:
        reg.init()
    dom = OffloadDomain.local(2)
    out: dict[str, float] = {}
    size_reps = (
        ((1 << 16, "64KB", 3),) if smoke
        else ((1 << 16, "64KB", 400), (1 << 22, "4MB", 48),
              (1 << 26, "64MB", 8))
    )
    for wire in (False, True):
        dom.direct_data_plane = not wire
        prefix = "wire_" if wire else ""
        for nbytes, label, reps in size_reps:
            arr = np.random.default_rng(1).standard_normal(nbytes // 8)
            ptr = dom.allocate(1, arr.shape, "float64")
            for op, fn in (("put", lambda: dom.put(arr, ptr)),
                           ("get", lambda: dom.get(ptr))):
                fn()
                fn()  # warm transport + frame pool
                ts = []
                for _ in range(reps):
                    t0 = time.perf_counter()
                    fn()
                    ts.append((time.perf_counter() - t0) * 1e6)
                out[f"{prefix}{op}_{label}"] = round(median(ts), 1)
            dom.free(ptr)
    dom.shutdown()
    return out


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val:.1f},{note}")
    for name, val in run_median().items():
        print(f"putget/{name}_median,{val:.1f},")
