"""Cluster serving under load: worker-driven continuous batching, measured.

Three legs over the REDUCED llama3-405b config (tiny layers — the point is
the *control plane*: at toy decode cost the per-token host RPC of the
lockstep drive is a first-order term, which is exactly the regime the
worker-driven path removes):

* ``throughput`` — the same prompt set served by the **lockstep** drive
  (host submits one ``_serve/step`` per worker per token step) and by the
  **worker-driven** drive (one ``_serve/admit_stream`` lease per request,
  tokens return as fused oneways).  Records aggregate tokens/s for each,
  the speedup, host RPCs per emitted token, and that the two transcripts
  are token-identical (greedy decode — same prompts, same tokens, by
  construction of the protocol, not by luck).
* ``poisson`` — an **open-loop** heavy-traffic harness: sticky sessions
  arrive as a Poisson process at a configured fraction of measured
  capacity (open-loop = arrivals do not wait for completions, so queueing
  is real), through a bounded admission queue that sheds with
  ``OffloadError`` on overflow.  Records TTFT and per-token latency
  p50/p99 against SLO targets.
* ``kill_recovery`` — kill one of four workers under live traffic.  The
  host transcript replays every victim request on a survivor (session
  repin + continuation admit); records sessions repinned, requests lost
  (acceptance: zero), completed fraction, and whether the SLO held
  through the failure.

Writes ``BENCH_serving.json`` (schema ``serving-v1``); the ``serving.*``
leaves are gated by ``benchmarks/trend_gate.py`` — speedup and kill
recovery as trends (recovery at zero tolerance), host RPCs per token
against an absolute ceiling of 0.1.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from benchmarks._stats import percentiles

_REPO_ROOT = Path(__file__).resolve().parents[1]
_JSON_PATH = _REPO_ROOT / "BENCH_serving.json"

WORKERS = 4
SLOTS_PER_WORKER = 2
PROMPT_LEN = 8          # fixed: prefill jit-compiles per prompt length
MAX_NEW = 32            # decode budget per request (throughput leg)
POISSON_MAX_NEW = 16
#: kill-leg requests live for several fused decode blocks, so the victim
#: is guaranteed to hold live sessions when it dies (a 16-token request
#: fits in ONE block and would often finish before the kill lands)
KILL_MAX_NEW = 96
POISSON_LOAD = 0.6      # offered load as a fraction of measured capacity
ADMISSION_LIMIT = 64    # bounded admission queue (shed past this depth)

#: SLO targets the open-loop leg reports against.  Generous on purpose:
#: they must hold on a loaded single-core CI runner; the *trend* gate is
#: what catches creep, the SLO booleans catch collapse.
SLO_TTFT_P99_MS = 2500.0
SLO_PER_TOKEN_P99_MS = 250.0
#: the kill leg gets a looser TTFT bound — a request admitted just before
#: the kill pays death-detection + repin + replayed prefill
SLO_KILL_TTFT_P99_MS = 6000.0


def _build_model():
    import jax

    from repro.configs import get_reduced
    from repro.models.api import build_model

    cfg = get_reduced("llama3-405b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _make_prompts(n: int, seed: int = 7) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    return [rng.integers(1, 100, size=PROMPT_LEN).astype(np.int32)
            for _ in range(n)]


def _make_engine(model, params, *, worker_driven: bool,
                 admission_limit: int | None = None, max_new: int = MAX_NEW):
    from repro.serve.engine import ClusterServingEngine

    return ClusterServingEngine(
        model, params, num_workers=WORKERS,
        slots_per_worker=SLOTS_PER_WORKER,
        max_len=PROMPT_LEN + max_new + 8,
        worker_driven=worker_driven, admission_limit=admission_limit,
    )


def _warm(eng) -> None:
    """Compile prefill + decode on EVERY replica before the measured
    region.  Session placement is a rendezvous hash, so driving warm
    requests through the front door cannot guarantee coverage — a replica
    that missed warmup would bill ~2s of jit to the first measured request
    landing on it.  The replicas are in-process (thread workers), so warm
    each engine directly: admit one short request and step it out through
    BOTH decode paths — single-step and the fused step_many block — so
    neither compiles inside the measured region (the decode loops are
    parked — nothing else touches the replica)."""
    from repro.serve.engine import Request
    from repro.serve.handlers import _NODE_ENGINES

    block = getattr(eng, "decode_block", 1)
    for key in list(eng._engine_keys.values()):
        rep = _NODE_ENGINES[key]
        rep.admit(Request(prompt=np.arange(1, 1 + PROMPT_LEN,
                                           dtype=np.int32),
                          max_new_tokens=block + 3, rid=999_983), 0)
        rep.step()
        if block > 1:
            rep.step_many(block)
        rep.evict(999_983)
        rep.outputs.pop(999_983, None)


def _throughput_section(model, params, smoke: bool) -> dict:
    from repro.serve.engine import Request

    # smoke shrinks the request count only: max_new stays at the full
    # budget so the host-RPCs-per-token ceiling is judged at the real
    # admit/token amortisation (and a fused block still fills)
    n_req = 8 if smoke else 32
    max_new = MAX_NEW
    prompts = _make_prompts(n_req)

    def reqs():
        return [Request(prompt=p, max_new_tokens=max_new, rid=i)
                for i, p in enumerate(prompts)]

    results = {}
    for mode, worker_driven in (("lockstep", False), ("worker_driven", True)):
        eng = _make_engine(model, params, worker_driven=worker_driven)
        try:
            _warm(eng)
            sub0 = eng.sched.stats["submitted"]
            one0 = eng.sched.stats["oneways"]
            t0 = time.perf_counter()
            out = eng.run(reqs(), timeout=300.0)
            dt = time.perf_counter() - t0
            tokens = sum(len(v) for v in out.values())
            rpcs = (eng.sched.stats["submitted"] - sub0
                    + eng.sched.stats["oneways"] - one0)
            results[mode] = {
                "out": out,
                "tokens": tokens,
                "tokens_per_s": round(tokens / dt, 1),
                "host_rpcs": rpcs,
                "host_rpcs_per_token": round(rpcs / max(tokens, 1), 4),
            }
        finally:
            eng.close()
    lock, wd = results["lockstep"], results["worker_driven"]
    identical = lock["out"] == wd["out"]
    section = {
        "requests": n_req,
        "max_new_tokens": max_new,
        "tokens": wd["tokens"],
        "lockstep_tokens_per_s": lock["tokens_per_s"],
        "worker_driven_tokens_per_s": wd["tokens_per_s"],
        "speedup_vs_lockstep": round(
            wd["tokens_per_s"] / max(lock["tokens_per_s"], 1e-9), 2),
        "lockstep_host_rpcs_per_token": lock["host_rpcs_per_token"],
        "host_rpcs_per_token": wd["host_rpcs_per_token"],
        "token_identical": identical,
    }
    return section


def _latency_stats(eng, rids) -> dict:
    """TTFT and per-token latency percentiles from the engine's per-request
    event stamps (ms)."""
    ttft, per_tok = [], []
    with eng._wd:
        for rid in rids:
            ev = eng._events.get(rid, {})
            if "t_first" in ev and "t_submit" in ev:
                ttft.append((ev["t_first"] - ev["t_submit"]) * 1e3)
            ts = ev.get("token_ts", ())
            if len(ts) >= 2:
                per_tok.append((ts[-1] - ts[0]) / (len(ts) - 1) * 1e3)
    out = {}
    if ttft:
        out["ttft_ms"] = {k: round(v, 1)
                          for k, v in percentiles(ttft, (50, 99)).items()}
    if per_tok:
        out["per_token_ms"] = {
            k: round(v, 2) for k, v in percentiles(per_tok, (50, 99)).items()
        }
    return out


def _poisson_section(model, params, capacity_tokens_per_s: float,
                     smoke: bool) -> dict:
    from repro.core.errors import OffloadError
    from repro.serve.engine import Request

    n_req = 48 if smoke else 1000
    max_new = POISSON_MAX_NEW
    cap_req_per_s = max(capacity_tokens_per_s / max_new, 1.0)
    offered = POISSON_LOAD * cap_req_per_s
    rng = np.random.default_rng(11)
    gaps = rng.exponential(1.0 / offered, size=n_req)
    prompts = _make_prompts(n_req, seed=13)

    eng = _make_engine(model, params, worker_driven=True,
                       admission_limit=ADMISSION_LIMIT)
    try:
        _warm(eng)
        submitted: list[int] = []
        shed = 0
        t0 = time.perf_counter()
        next_t = t0
        for i in range(n_req):
            next_t += gaps[i]
            delay = next_t - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            try:
                submitted.append(eng.submit_request(Request(
                    prompt=prompts[i], max_new_tokens=max_new, rid=i,
                )))
            except OffloadError:
                shed += 1  # bounded admission queue: overload is shed, not
                # queued without limit (open-loop back-pressure contract)
        eng.wait(submitted, timeout=600.0)
        dt = time.perf_counter() - t0
        with eng._wd:
            tokens = sum(len(eng._transcripts[r]) for r in submitted)
        stats = _latency_stats(eng, submitted)
        ttft_p99 = stats.get("ttft_ms", {}).get("p99", float("inf"))
        ptok_p99 = stats.get("per_token_ms", {}).get("p99", float("inf"))
        return {
            "arrivals": n_req,
            "offered_req_per_s": round(offered, 1),
            "offered_load_fraction": POISSON_LOAD,
            "admission_limit": ADMISSION_LIMIT,
            "max_new_tokens": max_new,
            "completed": len(submitted),
            "shed": shed,
            "tokens": tokens,
            "tokens_per_s": round(tokens / dt, 1),
            **stats,
            "slo": {
                "ttft_p99_ms_target": SLO_TTFT_P99_MS,
                "per_token_p99_ms_target": SLO_PER_TOKEN_P99_MS,
                "ttft_p99_met": ttft_p99 <= SLO_TTFT_P99_MS,
                "per_token_p99_met": ptok_p99 <= SLO_PER_TOKEN_P99_MS,
            },
        }
    finally:
        eng.close()


def _kill_section(model, params, smoke: bool) -> dict:
    from repro.serve.engine import Request

    n_req = 24 if smoke else 200
    max_new = KILL_MAX_NEW
    prompts = _make_prompts(n_req, seed=17)
    eng = _make_engine(model, params, worker_driven=True, max_new=max_new)
    try:
        _warm(eng)
        rids = [eng.submit_request(Request(
            prompt=prompts[i], max_new_tokens=max_new, rid=i), shed=False)
            for i in range(n_req)]
        # let traffic flow, then kill a worker that is actively serving
        target_tokens = n_req * max_new // 4
        deadline = time.monotonic() + 120
        while time.monotonic() < deadline:
            with eng._wd:
                if sum(len(t) for t in eng._transcripts.values()) \
                        >= target_tokens:
                    break
            time.sleep(0.005)
        victim = eng.serving_nodes()[0]
        t_kill = time.perf_counter()
        eng.pool.kill(victim)
        eng.wait(rids, timeout=600.0)
        recovery_s = time.perf_counter() - t_kill
        with eng._wd:
            lost = sum(1 for r in rids
                       if len(eng._transcripts.get(r, ())) != max_new)
            repinned = sum(1 for r in rids
                           if eng._events.get(r, {}).get("repins", 0) > 0)
            seq_violations = sum(
                1 for r in rids
                if eng._events.get(r, {}).get("seq_ok") is False)
        stats = _latency_stats(eng, rids)
        ttft_p99 = stats.get("ttft_ms", {}).get("p99", float("inf"))
        completed_fraction = (n_req - lost) / n_req
        slo_held = (lost == 0 and seq_violations == 0
                    and ttft_p99 <= SLO_KILL_TTFT_P99_MS)
        return {
            "requests": n_req,
            "max_new_tokens": max_new,
            "kill": f"worker {victim} of {WORKERS}, mid-decode",
            "recovery_s": round(recovery_s, 2),
            "sessions_repinned": repinned,
            "router_replaced": eng.sched.sessions.stats["replaced"],
            "lost_requests": lost,
            "seq_violations": seq_violations,
            "completed_fraction": round(completed_fraction, 3),
            **stats,
            "slo_kill_ttft_p99_ms_target": SLO_KILL_TTFT_P99_MS,
            "slo_held": slo_held,
        }
    finally:
        eng.close()


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    model, params = _build_model()
    throughput = _throughput_section(model, params, smoke)
    poisson = _poisson_section(
        model, params, throughput["worker_driven_tokens_per_s"], smoke)
    kill = _kill_section(model, params, smoke)
    report = {
        "schema": "serving-v1",
        "smoke": smoke,
        "model": "llama3-405b (REDUCED)",
        "workers": WORKERS,
        "slots_per_worker": SLOTS_PER_WORKER,
        "throughput": throughput,
        "poisson": poisson,
        "kill_recovery": kill,
        # flat gate-friendly section (trend_gate TRACKED/CEILINGS paths)
        "serving": {
            "tokens_per_s": throughput["worker_driven_tokens_per_s"],
            "speedup_vs_lockstep": throughput["speedup_vs_lockstep"],
            "host_rpcs_per_token": throughput["host_rpcs_per_token"],
            "kill_recovery": {
                "slo_held": kill["slo_held"],
                "completed_fraction": kill["completed_fraction"],
            },
        },
        "acceptance": {
            "worker_driven_ge_2x_lockstep_at_4_workers":
                throughput["speedup_vs_lockstep"] >= 2.0,
            "host_rpcs_per_token_lt_0_1":
                throughput["host_rpcs_per_token"] < 0.1,
            "token_identical_to_lockstep": throughput["token_identical"],
            "poisson_slo_met": poisson["slo"]["ttft_p99_met"]
                and poisson["slo"]["per_token_p99_met"],
            "kill_zero_lost_requests": kill["lost_requests"] == 0,
            "kill_slo_held": kill["slo_held"],
        },
    }
    _JSON_PATH.write_text(json.dumps(report, indent=2) + "\n")
    rows = [
        ("serving/worker_driven_tokens_per_s",
         throughput["worker_driven_tokens_per_s"],
         f"{throughput['speedup_vs_lockstep']}x vs lockstep, "
         f"{throughput['host_rpcs_per_token']} host RPCs/token"),
        ("serving/poisson_ttft_p99_ms",
         poisson.get("ttft_ms", {}).get("p99", -1.0),
         f"{poisson['arrivals']} arrivals at "
         f"{poisson['offered_req_per_s']} req/s, {poisson['shed']} shed"),
        ("serving/kill_recovery_s", kill["recovery_s"],
         f"{kill['sessions_repinned']} repinned, "
         f"{kill['lost_requests']} lost, SLO held: {kill['slo_held']}"),
        ("serving/speedup_vs_lockstep", throughput["speedup_vs_lockstep"],
         f"-> {_JSON_PATH.name}"),
    ]
    return rows


if __name__ == "__main__":
    import sys

    for name, val, note in run(smoke="--smoke" in sys.argv):
        print(f"{name},{val:.3f},{note}")
