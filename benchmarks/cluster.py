"""Cluster scheduler throughput: pipelined vs serial offload dispatch.

Sweeps scheduling policy x worker count over a thread-worker pool whose
handler sleeps a fixed per-call service time (a stand-in for device-side
work — like compiled jax steps, it releases the GIL, so workers genuinely
overlap).  Two drive modes per configuration:

* ``serial``    — the pre-cluster pattern: one call in flight, wait the
  round trip, repeat.  Throughput is pinned near 1/service_time no matter
  how many workers exist.
* ``pipelined`` — the scheduler keeps up to ``max_inflight`` calls in
  flight per worker (credit-based flow control) and completions are
  harvested with ``as_completed``; throughput scales with the pool.

A second section exercises **elastic resize + sticky sessions**: a live
pool grows 2 -> 4 workers and shrinks back to 2 (drained) under a
continuous submit stream — the acceptance check is zero failed calls and a
throughput gain while grown — and a resize's session-remap fraction is
measured against the rendezvous-hash fair share.

A third section measures the **replicated data plane** (crash recovery):
write-through put overhead with ``replicas=1`` vs ``replicas=0``, then a
kill of one worker in a 4-worker pool holding replicated session buffers —
recording time-to-recovery (death detection + metadata promotion + session
repin), that ZERO buffers were lost, and that every buffer read back
intact through its original (stale-epoch) pointer.

A fourth section kills and rebuilds the **host** in place
(``recovery.host_restart``): a pool holding replicated session buffers has
its host runtime torn down and restarted on the same endpoint, the
directory is reconstructed from survivor ``_ham/dir_dump`` shards, and
every buffer must read back intact through its pre-crash pointer
(docs/failure-model.md).

A fifth section measures the **active-access data plane**
(``dataplane``) on the shm *process* pool: chain-replicated put at
``replicas={1,2}`` against a measured host-sequential leg (the pre-chain
model — the host pushes the bytes to every holder itself; the gated
``vs_host_sequential_x`` ratio is core-count independent, while
``overhead_x`` vs ``replicas=0`` floors at ~(R+1)x on a single-core
runner and is recorded as informational), mutate-at-data RTT
(``demo/saxpy``, ``mutates=True``) vs the naive get-mutate-put round
trip per buffer size, and the invalidate-to-converged latency of a
mutation under ``mutation_refresh=True`` — the replica must hold the new
bytes by the time the mutating future resolves.

Writes ``BENCH_cluster.json`` with the sweeps and the acceptance checks:
pipelined >= 2x serial at 4 workers; resize with zero failures; kill 4->3
with zero lost buffers; host restart with zero lost buffers; chain-put
vs host-sequential within target (1.3x full, trend-gate ceiling 1.5x);
mutate-at-data >= 3x at >= 1 MB (full); refresh-mode mutation converged.
"""

from __future__ import annotations

import json
import threading
import time
from pathlib import Path

import numpy as np

import repro.cluster.pool  # noqa: F401 — registers _cluster/* pre-init
import repro.offload.demo_handlers  # noqa: F401 — demo/saxpy (mutates=True)
from repro.cluster import ClusterPool, Scheduler, SessionRouter, as_completed
from repro.core.closure import f2f
from repro.core.registry import default_registry

_REPO_ROOT = Path(__file__).resolve().parents[1]
_JSON_PATH = _REPO_ROOT / "BENCH_cluster.json"

SLEEP_S = 0.002            # per-call service time on the worker
CALLS = 256                # calls per measured configuration
NODE_COUNTS = (1, 2, 4)
POLICIES = ("round_robin", "least_outstanding")
MAX_INFLIGHT = 16


def _throughput(policy: str, num_workers: int, calls: int, sleep_s: float,
                pipelined: bool) -> float:
    """Calls/sec of one configuration (fresh pool per run)."""
    reg = default_registry()
    if not reg.initialised:
        reg.init()
    pool = ClusterPool.local(num_workers, registry=reg)
    try:
        sched = Scheduler(pool, policy=policy, max_inflight=MAX_INFLIGHT)
        fn = f2f("_cluster/sleep", sleep_s, registry=reg)
        # warmup: one round trip per worker (connects + primes the loop)
        for node in pool.worker_nodes:
            sched.submit(fn, node=node).get(10)
        t0 = time.perf_counter()
        if pipelined:
            futs = [sched.submit(fn) for _ in range(calls)]
            for f in as_completed(futs, timeout=120):
                f.get(0)
        else:
            for _ in range(calls):
                sched.submit(fn).get(30)
        dt = time.perf_counter() - t0
        return calls / dt
    finally:
        pool.close()


def _resize_under_stream(sleep_s: float, phase_s: float) -> dict:
    """Grow 2 -> 4 and shrink back to 2 under a continuous submit stream.

    Returns per-phase throughput, the failure count (acceptance: zero) and
    the session-remap measurement for the grow step.
    """
    reg = default_registry()
    if not reg.initialised:
        reg.init()
    pool = ClusterPool.local(2, registry=reg)
    try:
        sched = Scheduler(pool, max_inflight=MAX_INFLIGHT)
        fn = f2f("_cluster/sleep", sleep_s, registry=reg)
        for node in pool.worker_nodes:
            sched.submit(fn, node=node).get(10)  # warmup

        stop = threading.Event()
        stamps: list[float] = []   # completion timestamps
        errors: list[BaseException] = []
        futs: list = []

        def stream():
            while not stop.is_set():
                try:
                    fut = sched.submit(fn)
                    fut.add_done_callback(
                        lambda f: stamps.append(time.perf_counter())
                    )
                    futs.append(fut)
                except BaseException as e:  # noqa: BLE001 — the metric
                    errors.append(e)

        t = threading.Thread(target=stream)
        t.start()
        try:
            t0 = time.perf_counter()
            time.sleep(phase_s)
            added = [pool.add_node(), pool.add_node()]
            t1 = time.perf_counter()
            time.sleep(phase_s)
            t2 = time.perf_counter()
            for node in added:
                pool.remove_node(node, drain=True)
            t3 = time.perf_counter()
            time.sleep(phase_s)
            t4 = time.perf_counter()
        finally:
            stop.set()
            t.join()
        for f in as_completed(list(futs), timeout=60):
            try:
                f.get(0)
            except BaseException as e:  # noqa: BLE001
                errors.append(e)

        def rate(lo: float, hi: float) -> float:
            n = sum(1 for s in stamps if lo <= s <= hi)
            return n / max(hi - lo, 1e-9)

        phases = {
            "2_workers_calls_per_s": round(rate(t0, t1), 1),
            "4_workers_calls_per_s": round(rate(t1, t2), 1),
            "back_to_2_calls_per_s": round(rate(t3, t4), 1),
        }
        # sticky sessions vs the same resize: fair-share remap for FRESH
        # placements, zero remap for pinned live sessions.  Both routers see
        # the SAME grow (mutable live list) — only the pin table differs.
        live = [1, 2]
        router = SessionRouter(lambda: live)
        keys = [f"bench-s{i}" for i in range(500)]
        before = {k: router.route(k) for k in keys}
        live.extend([3, 4])  # the grow the pinned sessions must survive
        fresh_after = {k: SessionRouter(lambda: live).route(k) for k in keys}
        moved_fresh = sum(1 for k in keys if before[k] != fresh_after[k])
        pinned_after = {k: router.route(k) for k in keys}  # pins hold
        moved_pinned = sum(1 for k in keys if before[k] != pinned_after[k])
        return {
            "service_time_s": sleep_s,
            "grow_shrink": "2 -> 4 -> 2 (drain)",
            "calls_completed": len(stamps),
            "failed_calls": len(errors),
            "throughput": phases,
            "speedup_4w_over_2w": round(
                phases["4_workers_calls_per_s"]
                / max(phases["2_workers_calls_per_s"], 1e-9), 2,
            ),
            "sessions": {
                "keys": len(keys),
                "fresh_remap_fraction_on_grow": round(moved_fresh / len(keys), 3),
                "pinned_remap_fraction_on_grow": moved_pinned / len(keys),
            },
        }
    finally:
        pool.close()


def _recovery_section(smoke: bool) -> dict:
    """Replicated-data-plane cost and crash recovery, measured.

    Phase 1 — write-through overhead: N buffer puts with ``replicas=1``
    (payload lands on primary + replica) timed against ``replicas=0``.
    Phase 2 — kill one of 4 workers holding replicated session buffers
    mid-stream; measure kill -> (death detected + every buffer promoted +
    every session repinned), then verify each buffer reads back intact
    through its ORIGINAL stale-epoch pointer.  Acceptance: zero lost.
    """
    reg = default_registry()
    if not reg.initialised:
        reg.init()
    nbuf = 8 if smoke else 24
    elems = (4 << 10) if smoke else (64 << 10)  # float64: 32 KB / 512 KB

    def timed_puts(replicas: int):
        pool = ClusterPool.local(4, registry=reg, replicas=replicas)
        ptrs = []
        payload = np.arange(float(elems))
        for i in range(nbuf):  # allocation outside the timed region
            ptrs.append(pool.allocate((elems,), "float64",
                                      session=f"rec-{i}"))
        t0 = time.perf_counter()
        for ptr in ptrs:
            pool.put(payload, ptr)
        dt = time.perf_counter() - t0
        return dt, pool, ptrs

    t_plain, pool0, _ = timed_puts(0)
    pool0.close()
    t_repl, pool, ptrs = timed_puts(1)
    payload = np.arange(float(elems))
    try:
        sched = Scheduler(pool, max_inflight=16)
        fn = f2f("_cluster/sleep", 0.001, registry=reg)
        # pin every session at its buffer home, with traffic flowing
        for i in range(nbuf):
            sched.submit(fn, session=f"rec-{i}").get(10)
        stop = threading.Event()
        failed: list = []

        def stream():
            i = 0
            while not stop.is_set():
                try:
                    sched.submit(fn, session=f"rec-{i % nbuf}").get(10)
                except Exception as e:  # noqa: BLE001 — in-flight on the
                    failed.append(e)  # victim at kill time is legitimate
                i += 1

        t = threading.Thread(target=stream)
        t.start()
        victim = sched.sessions.lookup("rec-0")
        victims = [i for i in range(nbuf)
                   if sched.sessions.lookup(f"rec-{i}") == victim]
        t_kill = time.perf_counter()
        pool.kill(victim)
        # recovery point: victim fenced, all its buffers promoted, all its
        # sessions repinned off the corpse
        deadline = time.time() + 30
        while time.time() < deadline:
            if victim not in sched.live_nodes() and all(
                sched.sessions.lookup(f"rec-{i}") != victim for i in victims
            ):
                break
            time.sleep(0.001)
        recovery_ms = (time.perf_counter() - t_kill) * 1e3
        stop.set()
        t.join()
        lost = len(pool.directory.lost_handles())
        intact = sum(
            1 for ptr in ptrs if np.array_equal(pool.get(ptr), payload)
        )
        # post-recovery session traffic flows on the replica holders
        for i in victims:
            sched.submit(fn, session=f"rec-{i}").get(10)
        return {
            "buffers": nbuf,
            "buffer_nbytes": elems * 8,
            "put_ms_replicas0": round(t_plain * 1e3, 2),
            "put_ms_replicas1": round(t_repl * 1e3, 2),
            "writethrough_overhead_x": round(t_repl / max(t_plain, 1e-9), 2),
            "kill": "4 -> 3 workers, replicas=1",
            "victim_buffers": len(victims),
            "recovery_ms": round(recovery_ms, 1),
            "buffers_lost": lost,
            "buffers_intact": intact,
            "recovered_fraction": round(intact / nbuf, 3),
            "sessions_repinned": sched.sessions.stats["recovered"],
            "stale_ptrs_resolved": pool.directory.stats["stale_resolved"],
        }
    finally:
        pool.close()


def _host_restart_section(smoke: bool) -> dict:
    """Host crash + in-place rebuild: the directory must survive.

    A 3-worker pool (``replicas=1``) holds session-bound replicated
    buffers; after gossip settles the host runtime is torn down and a
    fresh one starts on the same endpoint, merging ``_ham/dir_dump``
    shards from every survivor.  Acceptance: zero lost entries, every
    buffer intact through its pre-crash pointer, and post-restart calls
    flow through a fresh scheduler.
    """
    reg = default_registry()
    if not reg.initialised:
        reg.init()
    nbuf = 8 if smoke else 24
    elems = (4 << 10) if smoke else (64 << 10)
    pool = ClusterPool.local(3, registry=reg, replicas=1)
    try:
        payload = np.arange(float(elems))
        ptrs = []
        for i in range(nbuf):
            ptr = pool.allocate((elems,), "float64", session=f"hr-{i}")
            pool.put(payload, ptr)
            ptrs.append(ptr)
        time.sleep(0.3)  # let directory gossip reach every worker
        report = pool.restart_host()
        intact = sum(
            1 for ptr in ptrs if np.array_equal(pool.get(ptr), payload)
        )
        # the old scheduler's future table died with the host: a fresh one
        # must route session traffic on the rebuilt directory
        sched = Scheduler(pool, max_inflight=8)
        fn = f2f("_cluster/sleep", 0.001, registry=reg)
        for i in range(min(nbuf, 4)):
            sched.submit(fn, session=f"hr-{i}").get(10)
        return {
            "buffers": nbuf,
            "buffer_nbytes": elems * 8,
            "restart": "host torn down + rebuilt on same endpoint, "
                       "3 workers, replicas=1",
            "recovered": report["recovered"],
            "lost": report["lost"],
            "restart_ms": round(report["seconds"] * 1e3, 1),
            "buffers_intact": intact,
            "recovered_fraction": round(intact / nbuf, 3),
        }
    finally:
        pool.close()


def _dataplane_section(smoke: bool) -> dict:
    """Active-access data plane: chain-replicated put, mutate-at-data,
    invalidate-to-converged (dataplane module docs; docs/failure-model.md,
    "Write visibility and convergence").

    Phase 1 — chain-put overhead on the shm PROCESS pool: timed puts with
    ``replicas=R`` (host sends bytes ONCE, the primary streams the chain)
    against ``replicas=0`` and against a *measured* host-sequential leg
    (the pre-chain model: the host pushes the same bytes to every holder
    itself).  ``vs_host_sequential_x`` is the gated ratio — it isolates
    what the chain adds over the unavoidable single host send, and is
    core-count independent; ``overhead_x`` (vs ``replicas=0``) is
    recorded for the record but on a single-core runner it has an
    arithmetic floor of ~(R+1)x (the bytes are physically written R+1
    times and nothing overlaps), so it is not gate material.
    Phase 2 — mutate-at-data RTT via ``pool.mutate`` (``demo/saxpy``,
    mutates=True, one sync call at the primary + the dirty-epoch
    commit) vs the naive get-mutate-put round trip, per buffer size,
    median-timed on a 2-process shm pool.  Phase 3 — a mutation
    under ``mutation_refresh=True``: the replica must hold the NEW bytes
    when the mutating future resolves (convergence is the contract, the
    latency is the metric).
    """
    reg = default_registry()
    if not reg.initialised:
        reg.init()
    nbuf = 4 if smoke else 8
    # bandwidth-sized even under smoke: the gated vs_host_sequential ratio
    # measures chain *streaming* — at latency-dominated sizes the extra
    # hop RTT alone dominates and the gate would see noise, not the
    # mechanism
    elems = 128 << 10  # float64: 1 MB
    payload = np.arange(float(elems))

    def timed_puts(replicas: int) -> tuple[float, float]:
        """(median chain-put s, median host-sequential s) on a 4-process
        shm pool — real wire framing, no in-process memcpy shortcut."""
        pool = ClusterPool.shm(4, registry=reg, replicas=replicas)
        try:
            ptrs = [
                pool.allocate((elems,), "float64", session=f"dp{replicas}-{i}")
                for i in range(nbuf)
            ]
            for ptr in ptrs:
                pool.put(payload, ptr)  # warm links + buffers off the clock
            chain_ts, seq_ts = [], []
            for ptr in ptrs:
                t0 = time.perf_counter()
                pool.put(payload, ptr)
                chain_ts.append(time.perf_counter() - t0)
                # the pre-chain model, measured not modelled: the host
                # itself pushes the bytes to the primary AND each replica
                rec = pool.directory.lookup(ptr.handle)
                holders = [ptr.node, *(rec.replicas if rec else ())]
                t0 = time.perf_counter()
                for h in holders:
                    pool.domain.put(payload, ptr.at(h))
                seq_ts.append(time.perf_counter() - t0)
            chain_ts.sort()
            seq_ts.sort()
            return chain_ts[len(chain_ts) // 2], seq_ts[len(seq_ts) // 2]
        finally:
            pool.close()

    t_plain, _ = timed_puts(0)
    chain: dict = {"put_ms_replicas0": round(t_plain * 1e3, 2)}
    for r in (1, 2):
        t_r, t_seq = timed_puts(r)
        chain[f"replicas{r}"] = {
            "put_ms": round(t_r * 1e3, 2),
            "host_sequential_ms": round(t_seq * 1e3, 2),
            # vs replicas=0 — informational: floors at ~(R+1)x on a
            # single-core runner (every byte is written R+1 times, and
            # nothing overlaps); approaches vs_host_sequential_x once
            # links run in parallel
            "overhead_x": round(t_r / max(t_plain, 1e-9), 2),
            # the gated ratio: chain put vs the measured pre-chain model
            # (the host sends the bytes R+1 times); core-count independent
            "vs_host_sequential_x": round(t_r / max(t_seq, 1e-9), 2),
        }

    # -- mutate-at-data vs get-mutate-put ------------------------------
    # ``pool.mutate`` is the protocol under test: ONE sync call at the
    # primary plus the dirty-epoch commit, nothing else attached.  A
    # Scheduler layers queueing/deadlines/retries on top of this same
    # protocol — that machinery is what the sweep section above prices,
    # not a data-plane cost.  Measured on a 2-process shm pool (real
    # wire framing, same rationale as the chain-put phase) against the
    # naive round trip the paper's offload model forces: pull the bytes
    # to the host, modify, push them back.
    sizes = ((256 << 10),) if smoke else ((1 << 20), (8 << 20))
    iters = 3 if smoke else 5
    mutate: dict = {}
    pool = ClusterPool.shm(2, registry=reg, replicas=1)
    try:
        for nbytes in sizes:
            n = nbytes // 8
            # co-located on one primary: a mutating call executes where
            # its buffers live, so every referenced buffer must be there
            home = pool.worker_nodes[0]
            x = pool.allocate((n,), "float64", node=home,
                              session=f"m-{nbytes}")
            y = pool.allocate((n,), "float64", node=home,
                              session=f"m-{nbytes}")
            pool.put(np.ones(n), x)
            pool.put(np.zeros(n), y)
            fn = f2f("demo/saxpy", 0.5, x, y, registry=reg)
            pool.mutate(fn)  # warmup (also drops y's replica)
            correct = bool(np.allclose(pool.get(y), 0.5))
            mut_ts, naive_ts = [], []
            for _ in range(iters):
                t0 = time.perf_counter()
                pool.mutate(fn)
                mut_ts.append(time.perf_counter() - t0)
            xs = pool.get(x)
            for _ in range(iters):
                t0 = time.perf_counter()
                # shm get hands out a READ-ONLY zero-copy view; the
                # host-modify model needs its own writable copy — an
                # inherent cost of moving the bytes to the computation
                ys = np.array(pool.get(y))
                ys += 0.5 * xs
                pool.put(ys, y)
                naive_ts.append(time.perf_counter() - t0)
            mut_ts.sort()
            naive_ts.sort()
            t_mutate = mut_ts[len(mut_ts) // 2]
            t_naive = naive_ts[len(naive_ts) // 2]
            mutate[str(nbytes)] = {
                "mutate_rtt_ms": round(t_mutate * 1e3, 3),
                "get_mutate_put_ms": round(t_naive * 1e3, 3),
                "correct": correct,
            }
        speedups = {
            k: round(v["get_mutate_put_ms"] / max(v["mutate_rtt_ms"], 1e-9), 2)
            for k, v in mutate.items()
        }
    finally:
        pool.close()

    # -- invalidate-to-converged (refresh mode) -------------------------
    n = (8 << 10) if smoke else (128 << 10)
    pool = ClusterPool.local(3, registry=reg, replicas=1,
                             mutation_refresh=True)
    pool.domain.direct_data_plane = False  # wire protocol, as above
    try:
        sched = Scheduler(pool, policy="locality", max_inflight=8)
        home = pool.worker_nodes[0]
        x = pool.allocate((n,), "float64", node=home, session="inv")
        y = pool.allocate((n,), "float64", node=home, session="inv")
        pool.put(np.ones(n), x)
        pool.put(np.zeros(n), y)
        fn = f2f("demo/saxpy", 1.0, x, y, registry=reg)
        t0 = time.perf_counter()
        sched.submit(fn).get(30)
        to_converged_ms = (time.perf_counter() - t0) * 1e3
        rec = pool.directory.lookup(y.handle)
        replica_holders = list(rec.replicas) if rec is not None else []
        converged = False
        if replica_holders:
            # read the REPLICA's actual bytes: refresh streamed the new
            # write down the chain before the mutating future resolved
            rep_view = pool.domain.get(y.at(replica_holders[0], rec.epoch))
            converged = bool(np.allclose(rep_view, 1.0))
        invalidate = {
            "mode": "refresh",
            "buffer_nbytes": n * 8,
            "to_converged_ms": round(to_converged_ms, 2),
            "replica_holders": len(replica_holders),
            "converged_fraction": 1.0 if converged else 0.0,
        }
    finally:
        pool.close()

    return {
        "buffers": nbuf,
        "buffer_nbytes": elems * 8,
        "chain_put": chain,
        "mutate_at_data": mutate,
        "speedup": speedups,
        "invalidate": invalidate,
    }


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    calls = 32 if smoke else CALLS
    sleep_s = SLEEP_S
    rows: list[tuple[str, float, str]] = []
    sweep: dict[str, dict] = {}
    for policy in POLICIES:
        sweep[policy] = {}
        for workers in NODE_COUNTS:
            serial = _throughput(policy, workers, max(8, calls // 4),
                                 sleep_s, pipelined=False)
            piped = _throughput(policy, workers, calls, sleep_s,
                                pipelined=True)
            speedup = piped / serial
            sweep[policy][str(workers)] = {
                "serial_calls_per_s": round(serial, 1),
                "pipelined_calls_per_s": round(piped, 1),
                "speedup": round(speedup, 2),
            }
            rows.append((
                f"cluster/{policy}_w{workers}_pipelined", 1e6 / piped,
                f"{piped:,.0f} calls/s ({speedup:.1f}x vs serial)",
            ))
    resize = _resize_under_stream(sleep_s, phase_s=0.3 if smoke else 1.0)
    rows.append((
        "cluster/resize_4w_over_2w_speedup", resize["speedup_4w_over_2w"],
        f"{resize['calls_completed']} calls, "
        f"{resize['failed_calls']} failed during 2->4->2",
    ))
    recovery = _recovery_section(smoke)
    rows.append((
        "cluster/recovery_ms", recovery["recovery_ms"],
        f"kill 4->3: {recovery['buffers_lost']} lost, "
        f"{recovery['buffers_intact']}/{recovery['buffers']} intact, "
        f"write-through {recovery['writethrough_overhead_x']}x",
    ))
    host_restart = _host_restart_section(smoke)
    recovery["host_restart"] = host_restart
    rows.append((
        "cluster/host_restart_ms", host_restart["restart_ms"],
        f"host rebuild: {host_restart['lost']} lost, "
        f"{host_restart['buffers_intact']}/{host_restart['buffers']} intact",
    ))
    dataplane = _dataplane_section(smoke)
    r1 = dataplane["chain_put"]["replicas1"]
    rows.append((
        "dataplane/chain_put_r1_vs_host_seq_x", r1["vs_host_sequential_x"],
        f"chain put replicas=1: {r1['put_ms']} ms "
        f"({r1['overhead_x']}x of replicas=0)",
    ))
    rows.append((
        "dataplane/chain_put_r2_vs_host_seq_x",
        dataplane["chain_put"]["replicas2"]["vs_host_sequential_x"],
        "chain put replicas=2 vs host pushing bytes 3x itself",
    ))
    big = max(dataplane["speedup"], key=int)
    rows.append((
        "dataplane/mutate_vs_getput_x", dataplane["speedup"][big],
        f"mutate-at-data vs get-mutate-put at {int(big) >> 10} KB",
    ))
    rows.append((
        "dataplane/invalidate_to_converged_ms",
        dataplane["invalidate"]["to_converged_ms"],
        f"refresh-mode mutation, replica converged: "
        f"{dataplane['invalidate']['converged_fraction'] == 1.0}",
    ))
    accept = {
        policy: sweep[policy]["4"]["speedup"] >= 2.0 for policy in POLICIES
    }
    # smoke sizes are noise-dominated (64 KB mutate buffers, 2 iters):
    # hold the smoke run to the absolute trend-gate ceiling, the full run
    # to target
    chain_target = 1.5 if smoke else 1.3
    mutate_target = 1.5 if smoke else 3.0
    report = {
        "schema": "cluster-v5",
        "service_time_s": sleep_s,
        "calls": calls,
        "max_inflight": MAX_INFLIGHT,
        "smoke": smoke,
        "sweep": sweep,
        "resize": resize,
        "recovery": recovery,
        "dataplane": dataplane,
        "acceptance": {
            "chain_put_overhead_within_target": {
                "target_x": chain_target,
                "replicas1": r1["vs_host_sequential_x"] <= chain_target,
            },
            "mutate_at_data_speedup_within_target": {
                "target_x": mutate_target,
                "all_sizes": all(
                    v >= mutate_target for v in dataplane["speedup"].values()
                ),
            },
            "mutate_at_data_correct": all(
                v["correct"] for v in dataplane["mutate_at_data"].values()
            ),
            "invalidate_converged":
                dataplane["invalidate"]["converged_fraction"] == 1.0,
            "pipelined_ge_2x_serial_at_4_workers": accept,
            "resize_zero_failed_calls": resize["failed_calls"] == 0,
            "pinned_sessions_zero_remap_on_grow":
                resize["sessions"]["pinned_remap_fraction_on_grow"] == 0,
            "kill_4_to_3_zero_lost_buffers": recovery["buffers_lost"] == 0,
            "kill_4_to_3_all_buffers_intact":
                recovery["recovered_fraction"] == 1.0,
            "host_restart_zero_lost": host_restart["lost"] == 0,
            "host_restart_all_buffers_intact":
                host_restart["recovered_fraction"] == 1.0,
        },
    }
    _JSON_PATH.write_text(json.dumps(report, indent=2) + "\n")
    for policy in POLICIES:
        rows.append((
            f"cluster/{policy}_4w_speedup", sweep[policy]["4"]["speedup"],
            f"-> {_JSON_PATH.name}",
        ))
    return rows


if __name__ == "__main__":
    import sys

    for name, val, note in run(smoke="--smoke" in sys.argv):
        print(f"{name},{val:.3f},{note}")
