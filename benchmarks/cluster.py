"""Cluster scheduler throughput: pipelined vs serial offload dispatch.

Sweeps scheduling policy x worker count over a thread-worker pool whose
handler sleeps a fixed per-call service time (a stand-in for device-side
work — like compiled jax steps, it releases the GIL, so workers genuinely
overlap).  Two drive modes per configuration:

* ``serial``    — the pre-cluster pattern: one call in flight, wait the
  round trip, repeat.  Throughput is pinned near 1/service_time no matter
  how many workers exist.
* ``pipelined`` — the scheduler keeps up to ``max_inflight`` calls in
  flight per worker (credit-based flow control) and completions are
  harvested with ``as_completed``; throughput scales with the pool.

Writes ``BENCH_cluster.json`` with the sweep and the PR's acceptance check:
pipelined >= 2x serial at 4 workers.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import repro.cluster.pool  # noqa: F401 — registers _cluster/* pre-init
from repro.cluster import ClusterPool, Scheduler, as_completed
from repro.core.closure import f2f
from repro.core.registry import default_registry

_REPO_ROOT = Path(__file__).resolve().parents[1]
_JSON_PATH = _REPO_ROOT / "BENCH_cluster.json"

SLEEP_S = 0.002            # per-call service time on the worker
CALLS = 256                # calls per measured configuration
NODE_COUNTS = (1, 2, 4)
POLICIES = ("round_robin", "least_outstanding")
MAX_INFLIGHT = 16


def _throughput(policy: str, num_workers: int, calls: int, sleep_s: float,
                pipelined: bool) -> float:
    """Calls/sec of one configuration (fresh pool per run)."""
    reg = default_registry()
    if not reg.initialised:
        reg.init()
    pool = ClusterPool.local(num_workers, registry=reg)
    try:
        sched = Scheduler(pool, policy=policy, max_inflight=MAX_INFLIGHT)
        fn = f2f("_cluster/sleep", sleep_s, registry=reg)
        # warmup: one round trip per worker (connects + primes the loop)
        for node in pool.worker_nodes:
            sched.submit(fn, node=node).get(10)
        t0 = time.perf_counter()
        if pipelined:
            futs = [sched.submit(fn) for _ in range(calls)]
            for f in as_completed(futs, timeout=120):
                f.get(0)
        else:
            for _ in range(calls):
                sched.submit(fn).get(30)
        dt = time.perf_counter() - t0
        return calls / dt
    finally:
        pool.close()


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    calls = 32 if smoke else CALLS
    sleep_s = SLEEP_S
    rows: list[tuple[str, float, str]] = []
    sweep: dict[str, dict] = {}
    for policy in POLICIES:
        sweep[policy] = {}
        for workers in NODE_COUNTS:
            serial = _throughput(policy, workers, max(8, calls // 4),
                                 sleep_s, pipelined=False)
            piped = _throughput(policy, workers, calls, sleep_s,
                                pipelined=True)
            speedup = piped / serial
            sweep[policy][str(workers)] = {
                "serial_calls_per_s": round(serial, 1),
                "pipelined_calls_per_s": round(piped, 1),
                "speedup": round(speedup, 2),
            }
            rows.append((
                f"cluster/{policy}_w{workers}_pipelined", 1e6 / piped,
                f"{piped:,.0f} calls/s ({speedup:.1f}x vs serial)",
            ))
    accept = {
        policy: sweep[policy]["4"]["speedup"] >= 2.0 for policy in POLICIES
    }
    report = {
        "schema": "cluster-v1",
        "service_time_s": sleep_s,
        "calls": calls,
        "max_inflight": MAX_INFLIGHT,
        "smoke": smoke,
        "sweep": sweep,
        "acceptance": {
            "pipelined_ge_2x_serial_at_4_workers": accept,
        },
    }
    _JSON_PATH.write_text(json.dumps(report, indent=2) + "\n")
    for policy in POLICIES:
        rows.append((
            f"cluster/{policy}_4w_speedup", sweep[policy]["4"]["speedup"],
            f"-> {_JSON_PATH.name}",
        ))
    return rows


if __name__ == "__main__":
    import sys

    for name, val, note in run(smoke="--smoke" in sys.argv):
        print(f"{name},{val:.3f},{note}")
