"""Device-side dispatch cost: the TPU-native half of the Fig. 3 story.

Selecting which computation runs next, three ways:

* ``switch_table``   — HAM device handler table: ONE compiled executable,
  ``lax.switch`` over N branches, key as device data (our mechanism)
* ``dict_dispatch``  — N separately-jitted executables, Python picks one
  per call (executable-swap cost, the "good vendor" case)
* ``retrace``        — re-jit the function every call (the worst case:
  what naive frameworks pay when the step function changes shape/identity)

Plus ``switch_scaling``: table dispatch cost vs table size (O(1) claim).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.device_table import DeviceHandlerTable

from benchmarks._stats import median_us


def _median_us(fn, n=300, warmup=20) -> float:
    return median_us(fn, n, warmup)


def _make_branches(k: int):
    def mk(i):
        def fn(x):
            return x * (i + 1) + i
        return fn
    return [mk(i) for i in range(k)]


def bench_switch_table(num_handlers=8, dim=1024) -> float:
    table = DeviceHandlerTable()
    for i, fn in enumerate(_make_branches(num_handlers)):
        table.register(f"h{i:03d}", fn)
    x = jnp.ones((dim,), jnp.float32)
    spec = jax.ShapeDtypeStruct(x.shape, x.dtype)
    dispatch = table.build(spec)
    keys = [jnp.asarray(i % num_handlers, jnp.int32) for i in range(num_handlers)]
    i = [0]

    def call():
        i[0] = (i[0] + 1) % num_handlers
        dispatch(keys[i[0]], x).block_until_ready()

    return _median_us(call)


def bench_dict_dispatch(num_handlers=8, dim=1024) -> float:
    fns = {i: jax.jit(fn) for i, fn in enumerate(_make_branches(num_handlers))}
    x = jnp.ones((dim,), jnp.float32)
    for f in fns.values():
        f(x).block_until_ready()
    i = [0]

    def call():
        i[0] = (i[0] + 1) % num_handlers
        fns[i[0]](x).block_until_ready()

    return _median_us(call)


def bench_retrace(dim=1024) -> float:
    x = jnp.ones((dim,), jnp.float32)
    i = [0]

    def call():
        i[0] += 1
        k = i[0]

        def fn(x):
            return x * (k % 7 + 1) + k % 3

        jax.jit(fn)(x).block_until_ready()

    return _median_us(call, n=50, warmup=2)


def bench_switch_scaling(sizes=(2, 16, 64, 256), dim=256) -> list[tuple[int, float]]:
    out = []
    for k in sizes:
        table = DeviceHandlerTable()
        for i, fn in enumerate(_make_branches(k)):
            table.register(f"h{i:04d}", fn)
        x = jnp.ones((dim,), jnp.float32)
        dispatch = table.build(jax.ShapeDtypeStruct(x.shape, x.dtype))
        key = jnp.asarray(k // 2, jnp.int32)
        us = _median_us(lambda: dispatch(key, x).block_until_ready(), n=200)
        out.append((k, us))
    return out


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rows = []
    k = 2 if smoke else 8
    sw = bench_switch_table(num_handlers=k)
    dd = bench_dict_dispatch(num_handlers=k)
    rt = bench_retrace()
    rows.append(("dispatch/switch_table", sw, f"HAM device table, {k} branches"))
    rows.append(("dispatch/dict_jitted", dd, "executable swap per call"))
    rows.append(("dispatch/retrace", rt, "re-jit per call"))
    rows.append(("dispatch/SPEEDUP_vs_retrace", rt / sw, "ratio"))
    for k, us in bench_switch_scaling(sizes=(2, 16) if smoke else (2, 16, 64, 256)):
        rows.append((f"dispatch/switch_{k}_branches", us, "O(1) table scaling"))
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val:.2f},{note}")
