"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:

* ``offload/*``    — paper Fig. 3: empty-function offload cost, HAM vs the
  vendor-analogue naive RPC, across transports (THE paper metric)
* ``dispatch/*``   — device-side handler-table dispatch (TPU-native HAM)
* ``registry/*``   — §5.2 init/lookup complexity
* ``serialise/*``  — static bitwise pack vs self-describing vs pickle
* ``putget/*``     — offload data-plane bandwidth
* ``cluster/*``    — pipelined scheduler throughput vs serial round trips
* ``serving/*``    — worker-driven continuous batching vs the lockstep
  drive, open-loop Poisson SLOs, kill-under-traffic recovery

``--smoke`` runs every section at tiny sizes with one repeat — a CI
tripwire, not a measurement: the ``BENCH_*.json`` files it writes are
uploaded as PR artifacts so perf regressions leave a trace, but only
full runs produce comparable numbers.

Roofline terms per (arch × shape × mesh) are produced by the dry-run
(``python -m repro.launch.dryrun --all``), not here — they need the
512-device XLA_FLAGS environment.
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main(argv=None) -> None:
    args = argparse.ArgumentParser(description=__doc__)
    args.add_argument("--smoke", action="store_true",
                      help="tiny sizes, 1 repeat (CI tripwire)")
    opts = args.parse_args(argv)

    from benchmarks import (
        batching,
        cluster,
        device_dispatch,
        offload_overhead,
        putget,
        registry_scaling,
        serialisation,
        serving,
    )

    # the serialisation section's rows are reused by batching.run (which
    # persists them into BENCH_hotpath.json) — measure once, record twice
    serialise_rows: list = []

    def serialisation_section(smoke=False):
        serialise_rows[:] = serialisation.run(smoke=smoke)
        return serialise_rows

    sections = [
        ("offload_overhead (paper Fig. 3)", offload_overhead.run),
        ("device_dispatch", device_dispatch.run),
        ("registry_scaling", registry_scaling.run),
        ("serialisation", serialisation_section),
        ("putget", putget.run),
        ("batching (coalesced hot path + rpc fast path -> BENCH_hotpath.json)",
         lambda smoke=False: batching.run(
             smoke=smoke, serialise_rows=serialise_rows or None)),
        ("cluster (scheduler pipelining -> BENCH_cluster.json)", cluster.run),
        ("serving (worker-driven continuous batching -> BENCH_serving.json)",
         serving.run),
    ]
    failures = 0
    print("name,us_per_call,derived")
    for title, fn in sections:
        print(f"# --- {title} ---")
        try:
            for name, val, note in fn(smoke=opts.smoke):
                print(f"{name},{val:.3f},{note}", flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
