"""Per-architecture smoke tests (deliverable f): every assigned arch, in
reduced form, runs forward + one train step + one decode step on CPU with
shape and finiteness assertions.  Full configs are exercised by the dry-run
only (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models.api import build_model
from repro.models.config import SHAPE_CELLS, supports_cell
from repro.models.counting import count_active_params, count_params
from repro.optim import adamw
from repro.train.step import build_train_step


def _batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    b = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.vlm is not None:
        b["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.vlm.num_patches, cfg.d_model)), jnp.float32)
    if cfg.encdec is not None:
        b["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encdec.encoder_frames, cfg.d_model)),
            jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = model.forward(params, batch)
    S_out = batch["tokens"].shape[1] + (cfg.vlm.num_patches if cfg.vlm else 0)
    assert logits.shape == (2, S_out, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"
    # one optimizer step
    step = jax.jit(build_train_step(model, adamw.AdamWConfig(lr=1e-3)))
    opt = adamw.init(params)
    params2, opt2, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(opt2["step"]) == 1
    # params actually changed
    diff = sum(
        float(jnp.abs(a - b).max())
        for a, b in zip(jax.tree_util.tree_leaves(params2),
                        jax.tree_util.tree_leaves(params))
    )
    assert diff > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    cache = model.init_cache(2, 16)
    step = {"tokens": jnp.zeros((2, 1), jnp.int32), "pos": jnp.asarray(0, jnp.int32)}
    logits, cache2 = model.decode_step(params, cache, step)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    # cache structure preserved
    assert jax.tree_util.tree_structure(cache) == jax.tree_util.tree_structure(cache2)


@pytest.mark.parametrize("arch", ["internlm2-20b", "olmoe-1b-7b", "zamba2-2.7b",
                                  "whisper-large-v3", "xlstm-1.3b"])
def test_decode_matches_full_forward(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    batch = _batch(cfg, B=2, S=12)
    logits = model.forward(params, batch)
    cache = model.init_cache(2, 12)
    if cfg.encdec is not None:
        # enc-dec decode requires the encoder cross-KV (prefill provides it)
        _, pre = model.prefill(params, {"tokens": batch["tokens"][:, :1],
                                        "frames": batch["frames"]})
        cache["cross"] = pre["cross"]
    errs = []
    for t in range(12):
        step = {"tokens": batch["tokens"][:, t:t + 1],
                "pos": jnp.asarray(t, jnp.int32)}
        lg, cache = model.decode_step(params, cache, step)
        errs.append(float(jnp.abs(lg[:, 0] - logits[:, t]).max()))
    assert max(errs) < 5e-3, f"decode diverges from forward: {max(errs)}"


def test_full_config_param_counts_match_published():
    expect = {
        "llama3-405b": 405.8e9, "nemotron-4-340b": 341.0e9,
        "internlm2-20b": 19.9e9, "qwen1.5-4b": 3.95e9,
        "olmoe-1b-7b": 6.9e9, "qwen2-moe-a2.7b": 14.3e9,
        "internvl2-76b": 70.6e9, "zamba2-2.7b": 2.4e9,
        "whisper-large-v3": 1.6e9,
    }
    for arch, n in expect.items():
        got = count_params(get_config(arch))
        assert abs(got - n) / n < 0.08, f"{arch}: {got/1e9:.2f}B vs {n/1e9:.2f}B"
    # MoE active-param counts
    assert abs(count_active_params(get_config("olmoe-1b-7b")) - 1.28e9) < 0.1e9
    assert abs(count_active_params(get_config("qwen2-moe-a2.7b")) - 2.7e9) < 0.2e9


def test_cell_support_rules():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for cell in SHAPE_CELLS:
            ok, why = supports_cell(cfg, cell)
            if cell.name == "long_500k":
                assert ok == (cfg.family in ("ssm", "hybrid")), (arch, why)
            else:
                assert ok


def test_kv_quant_decode_close_to_fp():
    import dataclasses

    cfg = get_reduced("internlm2-20b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    batch = _batch(cfg, B=2, S=10)
    logits = model.forward(params, batch)
    cfg_q = dataclasses.replace(cfg, kv_quant=True)
    model_q = build_model(cfg_q)
    cache = model_q.init_cache(2, 10)
    assert cache["k"].dtype == jnp.int8
    errs = []
    for t in range(10):
        step = {"tokens": batch["tokens"][:, t:t + 1],
                "pos": jnp.asarray(t, jnp.int32)}
        lg, cache = model_q.decode_step(params, cache, step)
        errs.append(float(jnp.abs(lg[:, 0] - logits[:, t]).max()))
    # int8 cache: small, bounded degradation
    rel = max(errs) / float(jnp.abs(logits).max())
    assert rel < 0.05, f"kv_quant degradation too large: {rel}"
