"""End-to-end behaviour: the paper's full story in one test each.

1. Heterogeneous agreement: two *differently-ordered* registries drive one
   fabric and still agree on every key (the communication-free map).
2. HAM as control plane: offloaded training driven entirely by RPC.
3. The Fig. 2 program: allocate/put/async(inner_prod)/get on a worker.
4. Serving with the device dispatch table end-to-end.
"""

import numpy as np
import pytest

import repro.core as ham
from repro.core.closure import f2f
from repro.core.registry import HandlerRegistry
from repro.offload.api import OffloadDomain, deref
from repro.offload.runtime import NodeRuntime, register_internal_handlers


def _user_handlers(reg):
    def inner_prod(a_ptr, b_ptr, n):
        a, b = deref(a_ptr), deref(b_ptr)
        return float(a[:n] @ b[:n])

    def scale(ptr, alpha):
        deref(ptr)[:] *= alpha

    reg.register(inner_prod, name="app/inner_prod")
    reg.register(scale, name="app/scale")


def test_heterogeneous_key_agreement_end_to_end():
    """Process A registers handlers in one order, process B in another —
    frames produced by A's keys execute the right handler on B."""
    from repro.comm.local import LocalFabric

    reg_a = HandlerRegistry()
    register_internal_handlers(reg_a)
    _user_handlers(reg_a)
    table_a = reg_a.init()

    reg_b = HandlerRegistry()
    _user_handlers(reg_b)          # different registration order
    register_internal_handlers(reg_b)
    table_b = reg_b.init()

    assert table_a.digest == table_b.digest
    fabric = LocalFabric(2)
    host = NodeRuntime(0, fabric.endpoint(0), table_a, inline=True)
    worker = NodeRuntime(1, fabric.endpoint(1), table_b).start()
    try:
        ptr_msg = host.send_sync(1, f2f("_ham/alloc", [8], "float64",
                                        registry=reg_a))
        assert ptr_msg[0] == "ptr"
    finally:
        worker.stop()


def test_paper_fig2_program():
    reg = HandlerRegistry()
    register_internal_handlers(reg)
    _user_handlers(reg)
    reg.init()
    dom = OffloadDomain.local(2, registry=reg)
    try:
        n = 1024
        a = np.arange(n, dtype=np.float64)
        b = np.full(n, 2.0)
        target = 1
        a_t = dom.allocate(target, (n,), "float64")
        b_t = dom.allocate(target, (n,), "float64")
        dom.put(a, a_t)
        dom.put(b, b_t)
        result = dom.async_(target, f2f("app/inner_prod", a_t, b_t, n,
                                        registry=reg))
        # "do something in parallel on the host" ... then sync on the future
        c = result.get(30)
        assert c == a @ b
        # mutate remotely, read back
        dom.sync(target, f2f("app/scale", a_t, 3.0, registry=reg))
        np.testing.assert_array_equal(dom.get(a_t), a * 3.0)
    finally:
        dom.shutdown()


@pytest.mark.slow
def test_offloaded_training_via_rpc():
    from repro.configs import get_reduced
    from repro.optim.adamw import AdamWConfig
    from repro.train.loop import Trainer

    reg = HandlerRegistry()
    register_internal_handlers(reg)
    cfg = get_reduced("zamba2-2.7b")
    trainer = Trainer(cfg, AdamWConfig(lr=1e-3), global_batch=4, seq_len=16)
    trainer.register_handlers(reg)
    reg.init()
    dom = OffloadDomain.local(2, registry=reg)
    try:
        m3 = dom.sync(1, f2f("train/run_steps", 3, registry=reg), timeout=300)
        m9 = dom.sync(1, f2f("train/run_steps", 6, registry=reg), timeout=300)
        assert m9["step"] == 9
        assert m9["loss"] < m3["loss"] * 1.2  # training is progressing
    finally:
        dom.shutdown()


@pytest.mark.slow
def test_serving_end_to_end_with_dispatch_table():
    import jax

    from repro.configs import get_reduced
    from repro.models.api import build_model
    from repro.serve.engine import Request, ServingEngine

    cfg = get_reduced("qwen2-moe-a2.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, num_slots=2, max_len=24)
    out = eng.run([
        Request(prompt=np.arange(4) % cfg.vocab_size, max_new_tokens=4),
        Request(prompt=np.arange(6) % cfg.vocab_size, max_new_tokens=3),
        Request(prompt=np.arange(3) % cfg.vocab_size, max_new_tokens=5),
    ])
    assert [len(out[i]) for i in range(3)] == [4, 3, 5]
    assert len(eng.table) == 3  # greedy / sample / noop branches
