"""Worker-driven streaming serve: protocol-level tests (docs/serving.md).

Covers the delivery/ordering contract of the ``_serve/stream*`` path, the
fused multi-step decode block, mode equivalence (worker-driven transcripts
token-identical to the lockstep drive), elasticity under join/leave, and
the failure-model legs: kill-mid-decode replay, cancel, and deadlines.
"""

import threading
import time

import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.flags import STREAM_CANCELLED, STREAM_DONE, STREAM_EXPIRED
from repro.models.api import build_model
from repro.serve.engine import ClusterServingEngine, Request, ServingEngine


@pytest.fixture(scope="module")
def model_and_params():
    import jax

    cfg = get_reduced("llama3-405b")
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _prompts(cfg, n, base=3):
    return [np.arange(base + i % 3) % cfg.vocab_size for i in range(n)]


def _reqs(cfg, n, max_new=8, base=3):
    return [Request(prompt=p, max_new_tokens=max_new, rid=i)
            for i, p in enumerate(_prompts(cfg, n, base))]


# -- engine: fused multi-step block ----------------------------------------


def test_step_many_matches_sequential_steps(model_and_params):
    """A fused block (lax.scan over the handler table) emits exactly the
    tokens k sequential steps would — including a slot whose budget ends
    mid-block (its surplus lane tokens are dropped, not recorded)."""
    model, params = model_and_params
    cfg = model.cfg

    def serve(block):
        eng = ServingEngine(model, params, num_slots=2, max_len=32)
        eng.admit(Request(prompt=np.arange(4) % cfg.vocab_size,
                          max_new_tokens=5, rid=0), 0)
        eng.admit(Request(prompt=np.arange(6) % cfg.vocab_size,
                          max_new_tokens=11, rid=1), 1)
        while any(r is not None for r in eng.slot_req):
            if block > 1:
                eng.step_many(block)
            else:
                eng.step()
        return eng.outputs

    ref = serve(1)
    out = serve(4)
    assert out == ref
    assert {r: len(v) for r, v in out.items()} == {0: 5, 1: 11}


def test_step_early_out_when_all_slots_idle(model_and_params):
    """An empty batch never dispatches — neither via step() nor a fused
    block — but an explicit noop key still does (bubble-filler path)."""
    model, params = model_and_params
    eng = ServingEngine(model, params, num_slots=2, max_len=16)
    assert eng.step() == []
    assert eng.step_many(4) == []
    assert eng.steps_dispatched == 0
    eng.step(key=eng.key_noop)
    assert eng.steps_dispatched == 1


# -- cluster: mode equivalence + stream ordering ---------------------------


@pytest.mark.slow
def test_worker_driven_token_identical_to_lockstep(model_and_params):
    """Same prompts, same seed: the worker-driven drive must produce the
    exact transcripts of the lockstep drive (greedy decode is deterministic
    and slot lanes are independent, so any divergence is a protocol bug)."""
    model, params = model_and_params
    cfg = model.cfg
    outs = {}
    for wd in (False, True):
        eng = ClusterServingEngine(model, params, num_workers=2,
                                   slots_per_worker=2, max_len=32,
                                   worker_driven=wd)
        try:
            outs[wd] = eng.run(_reqs(cfg, 6, max_new=9), timeout=120)
            if wd:
                # one admit RPC per request: the host never drove a step
                assert eng.sched.stats["submitted"] == 6
                # fused-oneway ordering held for every session
                assert all(ev.get("seq_ok", True)
                           for ev in eng._events.values())
        finally:
            eng.close()
    assert outs[True] == outs[False]
    assert {r: len(v) for r, v in outs[True].items()} == {
        i: 9 for i in range(6)
    }


@pytest.mark.slow
def test_join_leave_mid_batch_token_identical(model_and_params):
    """Elastic membership mid-batch: requests served across a join and a
    drained leave still match the lockstep transcripts token for token."""
    model, params = model_and_params
    cfg = model.cfg
    eng = ClusterServingEngine(model, params, num_workers=1,
                               slots_per_worker=2, max_len=32)
    try:
        rids = [eng.submit_request(r, shed=False)
                for r in _reqs(cfg, 6, max_new=8)]
        new = eng.pool.add_node()  # join while the batch is decoding
        eng.wait(rids, timeout=120.0)
        eng.pool.remove_node(new, drain=True)  # leave between batches
        late = [eng.submit_request(  # rid=-1: fresh ids, no transcript reuse
            Request(prompt=p, max_new_tokens=8), shed=False)
            for p in _prompts(cfg, 2)]
        eng.wait(late, timeout=120.0)
        with eng._wd:
            got = {r: list(eng._transcripts[r]) for r in rids}
            got_late = {i: list(eng._transcripts[r])
                        for i, r in enumerate(late)}
    finally:
        eng.close()
    ref = ServingEngine(model, params, num_slots=2, max_len=32).run(
        _reqs(cfg, 6, max_new=8))
    assert got == ref
    ref_late = ServingEngine(model, params, num_slots=2, max_len=32).run(
        _reqs(cfg, 2, max_new=8))
    assert got_late == ref_late


@pytest.mark.slow
def test_kill_mid_decode_replays_without_dup_or_loss(model_and_params):
    """Kill a worker while its loop is streaming: every request replays on
    the survivor and the final transcripts are exactly the reference — no
    duplicated, lost, or reordered tokens (seq_ok holds through the repin
    because the continuation admit offsets the stream's seq base)."""
    model, params = model_and_params
    cfg = model.cfg
    eng = ClusterServingEngine(model, params, num_workers=2,
                               slots_per_worker=2, max_len=64)
    killed = {}

    def killer():
        deadline = time.time() + 60
        while time.time() < deadline:
            with eng._wd:
                streamed = sum(len(t) for t in eng._transcripts.values())
            if streamed >= 12:  # loops are live and mid-decode
                victim = eng.serving_nodes()[0]
                eng.pool.kill(victim)
                killed["node"] = victim
                return
            time.sleep(0.002)

    t = threading.Thread(target=killer)
    try:
        rids = [eng.submit_request(r, shed=False)
                for r in _reqs(cfg, 6, max_new=24)]
        t.start()
        eng.wait(rids, timeout=180.0)
        t.join()
        with eng._wd:
            got = {r: list(eng._transcripts[r]) for r in rids}
            events = {r: dict(eng._events[r]) for r in rids}
    finally:
        t.join(timeout=1.0)
        eng.close()
    assert "node" in killed, "the kill must land mid-run"
    ref = ServingEngine(model, params, num_slots=2, max_len=64).run(
        _reqs(cfg, 6, max_new=24))
    assert got == ref  # exact: no duplicated and no lost tokens
    assert any(ev.get("repins", 0) > 0 for ev in events.values())
    assert all(ev.get("seq_ok", True) for ev in events.values())


# -- failure model: cancel + deadline --------------------------------------


@pytest.mark.slow
def test_cancel_mid_decode_frees_slot(model_and_params):
    """Cancel a streaming request: the host keeps the partial transcript,
    the end-of-stream ack records STREAM_CANCELLED, and the freed slot
    serves a follow-up request to completion."""
    model, params = model_and_params
    cfg = model.cfg
    eng = ClusterServingEngine(model, params, num_workers=1,
                               slots_per_worker=1, max_len=450)
    try:
        rid = eng.submit_request(
            Request(prompt=np.arange(5) % cfg.vocab_size,
                    max_new_tokens=400), shed=False)
        deadline = time.time() + 60
        while time.time() < deadline:
            with eng._wd:
                if len(eng._transcripts.get(rid, ())) >= 4:
                    break
            time.sleep(0.002)
        assert eng.cancel(rid)
        eng.wait([rid], timeout=60.0)
        with eng._wd:
            assert eng._done[rid] == STREAM_CANCELLED
            assert 0 < len(eng._transcripts[rid]) < 400
        follow = eng.submit_request(
            Request(prompt=np.arange(4) % cfg.vocab_size,
                    max_new_tokens=3), shed=False)
        eng.wait([follow], timeout=60.0)
        with eng._wd:
            assert eng._done[follow] == STREAM_DONE
            assert len(eng._transcripts[follow]) == 3
    finally:
        eng.close()


@pytest.mark.slow
def test_deadline_expires_mid_decode(model_and_params):
    """A request whose decode budget outlives its deadline leaves the batch
    at a block boundary with STREAM_EXPIRED and a partial transcript
    (docs/failure-model.md: abandoned requests)."""
    model, params = model_and_params
    cfg = model.cfg
    eng = ClusterServingEngine(model, params, num_workers=1,
                               slots_per_worker=1, max_len=450)
    try:
        rid = eng.submit_request(
            Request(prompt=np.arange(5) % cfg.vocab_size,
                    max_new_tokens=400, deadline=0.15), shed=False)
        eng.wait([rid], timeout=120.0)
        with eng._wd:
            assert eng._done[rid] == STREAM_EXPIRED
            assert 0 < len(eng._transcripts[rid]) < 400
    finally:
        eng.close()
