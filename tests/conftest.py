import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running end-to-end test (model training / serving loops)",
    )
    config.addinivalue_line(
        "markers",
        "fork: forks worker processes over /dev/shm shared memory; skipped "
        "automatically where fork or /dev/shm is unavailable (CI runners, "
        "macOS default spawn, sandboxes)",
    )
    config.addinivalue_line(
        "markers",
        "shm: attaches fresh-interpreter worker subprocesses over /dev/shm "
        "(no os.fork — safe after JAX starts threads); skipped where "
        "/dev/shm is unavailable",
    )
    config.addinivalue_line(
        "markers",
        "chaos: seeded fault-injection suite (repro.comm.chaos) — frame "
        "drop/dup/delay/reorder/partition under deterministic RNG; run "
        "with `-m chaos` (the CI chaos smoke job does)",
    )


def _fork_available() -> bool:
    if not hasattr(os, "fork"):
        return False
    try:
        import multiprocessing

        multiprocessing.get_context("fork")
    except ValueError:
        return False
    return os.path.isdir("/dev/shm") and os.access("/dev/shm", os.W_OK)


def _shm_available() -> bool:
    return os.path.isdir("/dev/shm") and os.access("/dev/shm", os.W_OK)


def pytest_collection_modifyitems(config, items):
    fork_ok = _fork_available()
    shm_ok = _shm_available()
    skip_fork = pytest.mark.skip(
        reason="fork-based cross-process tests need os.fork and a writable /dev/shm"
    )
    skip_shm = pytest.mark.skip(
        reason="shm subprocess tests need a writable /dev/shm"
    )
    for item in items:
        if not fork_ok and "fork" in item.keywords:
            item.add_marker(skip_fork)
        if not shm_ok and "shm" in item.keywords:
            item.add_marker(skip_shm)
