import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running end-to-end test (model training / serving loops)",
    )
    config.addinivalue_line(
        "markers",
        "fork: forks worker processes over /dev/shm shared memory; skipped "
        "automatically where fork or /dev/shm is unavailable (CI runners, "
        "macOS default spawn, sandboxes)",
    )


def _fork_available() -> bool:
    if not hasattr(os, "fork"):
        return False
    try:
        import multiprocessing

        multiprocessing.get_context("fork")
    except ValueError:
        return False
    return os.path.isdir("/dev/shm") and os.access("/dev/shm", os.W_OK)


def pytest_collection_modifyitems(config, items):
    if _fork_available():
        return
    skip_fork = pytest.mark.skip(
        reason="fork-based cross-process tests need os.fork and a writable /dev/shm"
    )
    for item in items:
        if "fork" in item.keywords:
            item.add_marker(skip_fork)
