"""Registry determinism — the paper's core guarantee (§5.2)."""

import pytest
from _hypothesis_compat import given, settings, st

import repro.core as ham
from repro.core.registry import HandlerRegistry


def _noop():
    pass


def _ident(x):
    return x


def _mk(names):
    reg = HandlerRegistry()
    for n in names:
        reg.register(_noop, name=n)
    return reg.init()


@settings(max_examples=50, deadline=None)
@given(st.permutations([f"h/{i:03d}" for i in range(24)]))
def test_key_map_independent_of_registration_order(perm):
    """Any registration order yields the identical key map (the
    communication-free agreement that heterogeneous processes rely on)."""
    base = _mk(sorted(perm))
    other = _mk(list(perm))
    assert base.digest == other.digest
    for name in perm:
        assert base.key_of(name) == other.key_of(name)


@settings(max_examples=50, deadline=None)
@given(st.sets(st.sampled_from([f"h/{i:03d}" for i in range(40)]),
               min_size=1, max_size=40))
def test_digest_detects_different_handler_sets(subset):
    full = _mk([f"h/{i:03d}" for i in range(40)])
    part = _mk(sorted(subset))
    if len(subset) == 40:
        assert part.digest == full.digest
    else:
        assert part.digest != full.digest


def test_keys_are_dense_sorted_indices():
    table = _mk(["b", "a", "c"])
    assert [table.key_of(n) for n in ("a", "b", "c")] == [0, 1, 2]
    assert table.handler_at(0).stable_name.startswith("a")


def test_lambda_rejected_without_explicit_name():
    reg = HandlerRegistry()
    with pytest.raises(ham.UnstableNameError):
        reg.register(lambda: 1)


def test_local_function_rejected():
    reg = HandlerRegistry()

    def local_fn():
        return 2

    with pytest.raises(ham.UnstableNameError):
        reg.register(local_fn)
    # explicit name (the l2f route) works
    reg.register(local_fn, name="explicit/name")
    assert reg.init().key_of("explicit/name") == 0


def test_name_collision_with_different_functions():
    reg = HandlerRegistry()
    reg.register(_noop, name="dup")
    with pytest.raises(ham.RegistryError):
        reg.register(lambda: 2, name="dup")


def test_sealed_registry_rejects_late_registration():
    reg = HandlerRegistry()
    reg.register(_noop, name="x")
    reg.init()
    with pytest.raises(ham.RegistrySealedError):
        reg.register(_noop, name="y")


def test_elastic_reinit_allows_late_registration():
    reg = HandlerRegistry()
    reg.register(_noop, name="x")
    t1 = reg.init(allow_late_registration=True)
    reg.register(_noop, name="y")
    t2 = reg.reinit()  # keeps the late-registration mode
    assert len(t2) == 2 and t1.digest != t2.digest
    reg.register(_noop, name="z")  # still allowed after reinit
    assert len(reg.reinit()) == 3


def test_unknown_key_raises():
    table = _mk(["only"])
    with pytest.raises(ham.UnknownHandlerError):
        table.handler_at(5)


def test_peer_digest_verification():
    a = _mk(["h/1", "h/2"])
    b = _mk(["h/1", "h/2"])
    c = _mk(["h/1"])
    ham.verify_peer_digest(a, b.digest)
    with pytest.raises(ham.KeyMapMismatchError):
        ham.verify_peer_digest(a, c.digest)


def test_static_spec_part_of_identity():
    import numpy as np

    reg1 = HandlerRegistry()
    reg1.register(_ident, name="h", arg_specs=(ham.spec_of(np.zeros(4)),))
    reg2 = HandlerRegistry()
    reg2.register(_ident, name="h", arg_specs=(ham.spec_of(np.zeros(8)),))
    assert reg1.init().digest != reg2.init().digest


def test_read_only_is_routing_metadata_not_identity():
    """read_only feeds sender-side routing (replica serving) only: it must
    not change the stable name or the key-map digest peers agree on."""
    reg_a, reg_b = HandlerRegistry(), HandlerRegistry()
    reg_a.register(_noop, name="x/fn")
    reg_b.register(_noop, name="x/fn", read_only=True)
    ta, tb = reg_a.init(), reg_b.init()
    assert ta.digest == tb.digest
    assert ta.record_of("x/fn").read_only is False
    assert tb.record_of("x/fn").read_only is True
