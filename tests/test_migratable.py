"""Serialisation invariants: roundtrip identity over arbitrary pytrees."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from _hypothesis_compat import hnp

import repro.core as ham
from repro.core import migratable as mig

# -- strategies --------------------------------------------------------------

_scalars = st.one_of(
    st.booleans(),
    st.integers(min_value=-(2**62), max_value=2**62),
    st.floats(allow_nan=False, allow_infinity=False, width=64),
    st.text(max_size=24),
    st.binary(max_size=64),
    st.none(),
)

_arrays = hnp.arrays(
    dtype=st.sampled_from([np.float32, np.float64, np.int32, np.int64,
                           np.uint8, np.bool_]),
    shape=hnp.array_shapes(max_dims=3, max_side=5),
)

_trees = st.recursive(
    st.one_of(_scalars, _arrays),
    lambda children: st.one_of(
        st.lists(children, max_size=4),
        st.tuples(children, children),
        st.dictionaries(st.text(max_size=8), children, max_size=4),
    ),
    max_leaves=12,
)


def _eq(a, b):
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        aa, bb = np.asarray(a), np.asarray(b)
        if aa.dtype != bb.dtype:
            return False
        # bitwise roundtrip: NaNs compare equal (payloads are verbatim)
        eq_nan = aa.dtype.kind in "fc"
        return np.array_equal(aa, bb, equal_nan=eq_nan)
    if isinstance(a, (list, tuple)):
        return (type(a) == type(b) and len(a) == len(b)
                and all(_eq(x, y) for x, y in zip(a, b)))
    if isinstance(a, dict):
        return (isinstance(b, dict) and a.keys() == b.keys()
                and all(_eq(a[k], b[k]) for k in a))
    return a == b and type(a) == type(b)


# -- dynamic path -------------------------------------------------------------


@settings(max_examples=120, deadline=None)
@given(_trees)
def test_dynamic_roundtrip(tree):
    assert _eq(mig.unpack_dynamic(mig.pack_dynamic(tree)), tree)


def test_dynamic_trailing_bytes_rejected():
    payload = mig.pack_dynamic([1, 2]) + b"\x00"
    with pytest.raises(ham.MigratableError):
        mig.unpack_dynamic(payload)


# -- static path --------------------------------------------------------------


@settings(max_examples=80, deadline=None)
@given(st.lists(st.one_of(
    st.integers(min_value=-(2**62), max_value=2**62),
    st.floats(allow_nan=False, allow_infinity=False, width=64),
    st.booleans(),
    hnp.arrays(dtype=st.sampled_from([np.float32, np.int64]),
               shape=hnp.array_shapes(max_dims=2, max_side=6)),
), min_size=1, max_size=5))
def test_static_roundtrip(args):
    args = tuple(args)
    specs = tuple(mig.spec_of(a) for a in args)
    payload = mig.pack_static(args, specs)
    assert len(payload) == mig.static_payload_nbytes(specs)
    out = mig.unpack_static(payload, specs)
    assert all(_eq(np.asarray(a) if isinstance(a, np.ndarray) else a,
                   np.asarray(b) if isinstance(b, np.ndarray) else b)
               for a, b in zip(args, out))


def test_static_spec_mismatch_raises():
    spec = (mig.spec_of(np.zeros((4,), np.float32)),)
    with pytest.raises(ham.SpecMismatchError):
        mig.pack_static((np.zeros((5,), np.float32),), spec)
    with pytest.raises(ham.SpecMismatchError):
        mig.pack_static((np.zeros((4,), np.float64),), spec)


def test_not_bitwise_migratable_raises():
    class Foo:
        pass

    with pytest.raises(ham.NotBitwiseMigratableError):
        mig.spec_of(Foo())
    with pytest.raises(ham.NotBitwiseMigratableError):
        mig.pack_dynamic(Foo())


def test_custom_codec_roundtrip():
    from repro.optim.compression import CompressedTensor

    x = np.random.default_rng(0).standard_normal((16, 8)).astype(np.float32)
    ct = CompressedTensor.compress(x)
    out = mig.unpack_dynamic(mig.pack_dynamic(ct))
    assert isinstance(out, CompressedTensor)
    np.testing.assert_allclose(out.decompress(), x, atol=ct.scale)


def test_buffer_ptr_is_fixed_size_static():
    from repro.offload.buffer import BufferPtr

    ptr = BufferPtr(3, 42, 1024, epoch=2)
    spec = mig.spec_of(ptr)
    payload = mig.pack_static((ptr,), (spec,))
    assert len(payload) == 32  # node + handle + nbytes + epoch, all i64
    (out,) = mig.unpack_static(payload, (spec,))
    assert out == ptr


def test_scan_locality_weights_by_nbytes():
    """The locality-policy regression (ROADMAP item): one byte-heavy buffer
    must outvote many tiny ones — votes weigh data, not pointer count."""
    from repro.offload.buffer import BufferPtr

    small = [BufferPtr(1, h, 8) for h in (1, 2, 3)]       # 24 B on node 1
    big = BufferPtr(2, 9, 100 * 1024 * 1024)              # 100 MB on node 2
    votes = mig.scan_locality((big, *small))
    assert votes[2] > votes[1]
    assert votes == {1: 24, 2: 100 * 1024 * 1024}
    # unknown-size pointers still vote, with unit weight
    assert mig.scan_locality((BufferPtr(5, 1),)) == {5: 1}


def test_scan_locality_depth_bound():
    """Containers nested past MAX_SCAN_DEPTH are not descended — the same
    bound the directory's resolve_args rewrite walk applies, so a pointer
    deep enough to vote is always deep enough to be rewritten."""
    from repro.offload.buffer import BufferPtr

    ptr = BufferPtr(3, 11, 64)
    at_bound = ptr
    for _ in range(mig.MAX_SCAN_DEPTH):  # ptr sits at depth MAX_SCAN_DEPTH
        at_bound = [at_bound]
    assert mig.scan_locality((at_bound,)) == {3: 64}
    past_bound = [at_bound]
    assert mig.scan_locality((past_bound,)) == {}
