"""Optional-dependency shim for hypothesis.

The property-based tests use hypothesis when it is installed (see
``requirements-dev.txt``); in environments without it the suite must still
*collect and run* — ``@given`` tests degrade to individual skips instead of
taking the whole module (and every non-property test in it) down with an
ImportError at collection time.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st

    try:
        from hypothesis.extra import numpy as hnp
    except ImportError:  # pragma: no cover - extra not installed
        hnp = None
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Absorbs any strategy construction (st.integers(...), hnp.arrays(...),
        st.recursive(base, fn), ...) into inert placeholders."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

        def __call__(self, *args, **kwargs):
            pass

    st = _StrategyStub()
    hnp = _StrategyStub()

    def given(*args, **kwargs):  # noqa: ARG001 - mirror hypothesis signature
        def decorate(fn):
            def skipped():
                pytest.skip("hypothesis not installed (see requirements-dev.txt)")

            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped

        return decorate

    def settings(*args, **kwargs):  # noqa: ARG001
        def decorate(fn):
            return fn

        return decorate
