"""HAM-Offload behaviour: the paper §2 surface end to end."""

import numpy as np
import pytest

import repro.core as ham
from repro.core.closure import f2f
from repro.core.executor import ThreadPoolPolicy
from repro.core.registry import HandlerRegistry
from repro.offload.api import OffloadDomain, deref
from repro.offload.buffer import BufferPtr, BufferRegistry
from repro.offload.runtime import current_node, register_internal_handlers


def _make_registry():
    reg = HandlerRegistry()
    register_internal_handlers(reg)

    def inner_prod(a_ptr, b_ptr, n):
        a, b = deref(a_ptr), deref(b_ptr)
        return float(a[:n] @ b[:n])

    def boom():
        raise ValueError("intentional failure")

    def reverse(host_node):
        node = current_node()
        fut = node.send_async(host_node, f2f("_ham/ping", 7, registry=reg))
        return node.wait(fut, 10.0)

    reg.register(inner_prod, name="t/inner_prod")
    reg.register(boom, name="t/boom")
    reg.register(reverse, name="t/reverse")
    reg.register(lambda x: x * 2, name="t/double")
    reg.init()
    return reg


def _f2f(reg, name, *args):
    return f2f(name, *args, registry=reg)


@pytest.fixture
def dom():
    reg = _make_registry()
    d = OffloadDomain.local(3, registry=reg)
    yield d
    d.shutdown()


def test_sync_offload(dom):
    assert dom.sync(1, _f2f(dom.registry, "t/double", 21)) == 42


def test_async_futures_complete_out_of_order(dom):
    futs = [dom.async_(1 + (i % 2), _f2f(dom.registry, "t/double", i))
            for i in range(10)]
    assert [f.get(10) for f in futs] == [2 * i for i in range(10)]


def test_allocate_put_get_free(dom):
    a = np.arange(64, dtype=np.float64)
    ptr = dom.allocate(2, (64,), "float64")
    dom.put(a, ptr)
    np.testing.assert_array_equal(dom.get(ptr), a)
    # partial get with offset
    np.testing.assert_array_equal(dom.get(ptr, offset=10, count=5), a[10:15])
    dom.free(ptr)
    with pytest.raises(ham.RemoteExecutionError):
        dom.get(ptr)


def test_offloaded_compute_on_buffers(dom):
    a = np.arange(128.0)
    b = np.ones(128)
    pa = dom.allocate(1, (128,), "float64")
    pb = dom.allocate(1, (128,), "float64")
    dom.put(a, pa)
    dom.put(b, pb)
    assert dom.sync(1, _f2f(dom.registry, "t/inner_prod", pa, pb, 128)) == a @ b


def test_remote_exception_propagates(dom):
    with pytest.raises(ham.RemoteExecutionError, match="intentional"):
        dom.sync(1, _f2f(dom.registry, "t/boom"))
    # domain still alive
    assert dom.ping(1, 5) == 5


def test_reverse_offload(dom):
    assert dom.sync(2, _f2f(dom.registry, "t/reverse", 0)) == 7


def test_relay_offload_over_fabric(dom):
    fut = dom.relay(via=1, dst=2, function=_f2f(dom.registry, "t/double", 8))
    assert fut.get(10) == 16


def test_barrier(dom):
    dom.barrier()


def test_threadpool_policy_domain():
    reg = _make_registry()
    d = OffloadDomain.local(2, registry=reg,
                            policy_factory=lambda: ThreadPoolPolicy(2))
    try:
        assert d.sync(1, _f2f(reg, "t/double", 4)) == 8
    finally:
        d.shutdown()


def test_buffer_registry_rules():
    br = BufferRegistry(3)
    ptr = br.allocate((4, 4), "float32")
    assert ptr.node == 3
    assert br.deref(ptr).shape == (4, 4)
    with pytest.raises(ham.OffloadError):
        br.deref(BufferPtr(1, ptr.handle))  # wrong address space (§4.1)
    br.free(ptr)
    with pytest.raises(ham.OffloadError):
        br.free(ptr)
    assert br.live_count() == 0


def test_oneway_fire_and_forget(dom):
    dom.oneway(1, _f2f(dom.registry, "t/double", 1))
    dom.barrier()  # drains; no reply expected, no crash
