"""HAM-Offload behaviour: the paper §2 surface end to end."""

import numpy as np
import pytest

import repro.core as ham
from repro.core.closure import f2f
from repro.core.executor import ThreadPoolPolicy
from repro.core.registry import HandlerRegistry
from repro.offload.api import OffloadDomain, deref
from repro.offload.buffer import BufferPtr, BufferRegistry
from repro.offload.runtime import current_node, register_internal_handlers


def _make_registry():
    reg = HandlerRegistry()
    register_internal_handlers(reg)

    def inner_prod(a_ptr, b_ptr, n):
        a, b = deref(a_ptr), deref(b_ptr)
        return float(a[:n] @ b[:n])

    def boom():
        raise ValueError("intentional failure")

    def reverse(host_node):
        node = current_node()
        fut = node.send_async(host_node, f2f("_ham/ping", 7, registry=reg))
        return node.wait(fut, 10.0)

    reg.register(inner_prod, name="t/inner_prod")
    reg.register(boom, name="t/boom")
    reg.register(reverse, name="t/reverse")
    reg.register(lambda x: x * 2, name="t/double")
    reg.init()
    return reg


def _f2f(reg, name, *args):
    return f2f(name, *args, registry=reg)


@pytest.fixture
def dom():
    reg = _make_registry()
    d = OffloadDomain.local(3, registry=reg)
    yield d
    d.shutdown()


def test_sync_offload(dom):
    assert dom.sync(1, _f2f(dom.registry, "t/double", 21)) == 42


def test_chunked_put_get_roundtrip():
    """Large WIRE-path transfers split into pipelined segments reassemble
    exactly (direct_data_plane off so the chunking machinery actually runs)."""
    reg = _make_registry()
    dom = OffloadDomain.local(2, registry=reg)
    dom.direct_data_plane = False
    try:
        n = 1 << 16
        ptr = dom.allocate(1, (n,), "float64")
        arr = np.arange(n, dtype=np.float64)
        dom.put(arr, ptr, chunk_nbytes=1 << 14)  # force 32 in-flight segments
        np.testing.assert_array_equal(dom.get(ptr), arr)
        part = dom.get(ptr, offset=100, count=1000, chunk_count=128)
        np.testing.assert_array_equal(part, arr[100:1100])
        dom.free(ptr)
    finally:
        dom.shutdown()


@pytest.mark.shm
def test_oversized_reply_errors_instead_of_killing_worker():
    """A reply that exceeds the transport frame limit must come back as a
    RemoteExecutionError — not silently kill the worker's event loop and
    strand the caller in a timeout.

    The worker is a *fresh interpreter* attached over shm, not a fork: by
    the time this test runs, earlier tests have imported JAX and started
    its threads, and ``os.fork()`` in a multithreaded process is exactly
    the deadlock JAX's RuntimeWarning warns about — spawning avoids the
    hazard instead of suppressing the warning."""
    from repro.comm.shm import ShmFabric
    from repro.core.registry import default_registry
    from repro.offload.worker import reap, spawn_shm_worker_subprocess

    # subprocess workers re-init the default registry, so the host must use
    # it too (same-source assumption): internal _ham handlers are enough here
    reg = default_registry()
    if not reg.initialised:
        reg.init()
    fab = ShmFabric(2, capacity=1 << 20)  # 1 MB rings
    proc = spawn_shm_worker_subprocess(fab, 1)
    dom = OffloadDomain(fab, registry=reg)
    try:
        assert dom.ping(1, 3, timeout=30.0) == 3
        n = (1 << 21) // 8  # 2 MB buffer
        ptr = dom.allocate(1, (n,), "float64")
        dom.put(np.ones(n), ptr)  # put auto-chunks to the ring size
        with pytest.raises(ham.RemoteExecutionError, match="capacity"):
            dom.get(ptr)  # unchunked 2 MB reply cannot fit a 1 MB ring
        # the worker survived and still serves requests
        assert dom.ping(1, 7, timeout=10.0) == 7
        got = dom.get(ptr, count=n, chunk_count=(1 << 19) // 8)
        assert got.size == n and got[0] == 1.0
        dom.free(ptr)
    finally:
        dom.shutdown()
        reap([proc], timeout=5.0)


def test_direct_and_wire_data_plane_agree(dom):
    """The in-process direct data plane and the wire path are observationally
    identical (shape, dtype, offsets, partial reads)."""
    arr = np.arange(512, dtype=np.float64).reshape(32, 16)
    ptr = dom.allocate(1, arr.shape, "float64")
    assert dom.direct_data_plane  # default on for in-process workers
    dom.put(arr, ptr)
    direct = dom.get(ptr)
    direct_part = dom.get(ptr, offset=8, count=100)
    dom.direct_data_plane = False
    wire = dom.get(ptr)
    wire_part = dom.get(ptr, offset=8, count=100)
    dom.direct_data_plane = True
    assert direct.shape == wire.shape == arr.shape
    np.testing.assert_array_equal(direct, wire)
    np.testing.assert_array_equal(direct, arr)
    np.testing.assert_array_equal(direct_part, wire_part)
    # results are snapshots, not live views into the buffer
    dom.put(np.zeros_like(arr), ptr)
    np.testing.assert_array_equal(direct, arr)
    dom.free(ptr)


def test_async_futures_complete_out_of_order(dom):
    futs = [dom.async_(1 + (i % 2), _f2f(dom.registry, "t/double", i))
            for i in range(10)]
    assert [f.get(10) for f in futs] == [2 * i for i in range(10)]


def test_allocate_put_get_free(dom):
    a = np.arange(64, dtype=np.float64)
    ptr = dom.allocate(2, (64,), "float64")
    dom.put(a, ptr)
    np.testing.assert_array_equal(dom.get(ptr), a)
    # partial get with offset
    np.testing.assert_array_equal(dom.get(ptr, offset=10, count=5), a[10:15])
    dom.free(ptr)
    with pytest.raises(ham.RemoteExecutionError):
        dom.get(ptr)


def test_offloaded_compute_on_buffers(dom):
    a = np.arange(128.0)
    b = np.ones(128)
    pa = dom.allocate(1, (128,), "float64")
    pb = dom.allocate(1, (128,), "float64")
    dom.put(a, pa)
    dom.put(b, pb)
    assert dom.sync(1, _f2f(dom.registry, "t/inner_prod", pa, pb, 128)) == a @ b


def test_remote_exception_propagates(dom):
    with pytest.raises(ham.RemoteExecutionError, match="intentional"):
        dom.sync(1, _f2f(dom.registry, "t/boom"))
    # domain still alive
    assert dom.ping(1, 5) == 5


def test_reverse_offload(dom):
    assert dom.sync(2, _f2f(dom.registry, "t/reverse", 0)) == 7


def test_relay_offload_over_fabric(dom):
    fut = dom.relay(via=1, dst=2, function=_f2f(dom.registry, "t/double", 8))
    assert fut.get(10) == 16


def test_barrier(dom):
    dom.barrier()


def test_threadpool_policy_domain():
    reg = _make_registry()
    d = OffloadDomain.local(2, registry=reg,
                            policy_factory=lambda: ThreadPoolPolicy(2))
    try:
        assert d.sync(1, _f2f(reg, "t/double", 4)) == 8
    finally:
        d.shutdown()


def test_buffer_registry_rules():
    br = BufferRegistry(3)
    ptr = br.allocate((4, 4), "float32")
    assert ptr.node == 3
    assert br.deref(ptr).shape == (4, 4)
    with pytest.raises(ham.OffloadError):
        br.deref(BufferPtr(1, ptr.handle))  # wrong address space (§4.1)
    br.free(ptr)
    with pytest.raises(ham.OffloadError):
        br.free(ptr)
    assert br.live_count() == 0


def test_oneway_fire_and_forget(dom):
    dom.oneway(1, _f2f(dom.registry, "t/double", 1))
    dom.barrier()  # drains; no reply expected, no crash
