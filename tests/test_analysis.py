"""Static analysis + model checking: the tools that gate the tools.

Two engines under test (``docs/static-analysis.md``):

* **hamlint** — the AST protocol linter.  A known-bad fixture corpus under
  ``tests/fixtures/hamlint_bad/`` seeds one violation per rule variant; the
  tests assert each rule fires at the exact file:line, that the live tree
  is clean with zero suppressions, and that ``register()`` rejects at call
  time the subset of defects that are cheap to detect dynamically.
* **modelcheck** — the exhaustive-interleaving explorer.  The mitigated
  protocol models must verify; toggling a mitigation off must rediscover
  the corresponding historical bug (PR 1 torn counter, PR 7 lost wakeups)
  within seconds, as a shortest counterexample trace.
"""

from __future__ import annotations

import time
from pathlib import Path

import pytest

from repro.analysis.hamlint import lint_paths, main as hamlint_main
from repro.analysis.modelcheck import explore, main as modelcheck_main
from repro.analysis.models.doorbell import DoorbellModel
from repro.analysis.models.ring_counters import RingCounterModel
from repro.core.errors import RegistryError
from repro.core.migratable import ArraySpec, ScalarSpec
from repro.core.registry import HandlerRegistry

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "fixtures" / "hamlint_bad"
SRC = REPO / "src"


def _line_of(path: Path, needle: str) -> int:
    """1-based line number of the unique line containing ``needle``."""
    hits = [
        i
        for i, line in enumerate(path.read_text().splitlines(), start=1)
        if needle in line
    ]
    assert len(hits) == 1, f"{needle!r} not unique in {path}: {hits}"
    return hits[0]


@pytest.fixture(scope="module")
def fixture_findings():
    return lint_paths([str(FIXTURES)])


def _expect(findings, rule: str, filename: str, line: int):
    """Assert exactly one finding of ``rule`` at ``filename:line``."""
    matches = [
        f
        for f in findings
        if f.rule == rule and Path(f.path).name == filename and f.line == line
    ]
    assert len(matches) == 1, (
        f"expected one {rule} at {filename}:{line}, got "
        f"{[g.format() for g in findings]}"
    )
    return matches[0]


# ---------------------------------------------------------------------------
# hamlint: each rule fires on its fixture at the right location


def test_readonly_purity_catches_inplace_mutation(fixture_findings):
    line = _line_of(FIXTURES / "bad_readonly.py", "y += alpha")
    f = _expect(fixture_findings, "HAM001", "bad_readonly.py", line)
    assert "read_only=True" in f.message
    assert f"line {line}" in f.message  # names the offending store


def test_readonly_purity_catches_store_through_view(fixture_findings):
    line = _line_of(FIXTURES / "bad_readonly.py", "row[:] = 0.0")
    f = _expect(fixture_findings, "HAM001", "bad_readonly.py", line)
    assert "row" in f.message


def test_readonly_purity_catches_alias_escape(fixture_findings):
    line = _line_of(FIXTURES / "bad_readonly.py", '_stash["x"]')
    f = _expect(fixture_findings, "HAM001", "bad_readonly.py", line)
    assert "alias escape" in f.message


def test_undeclared_mutation_names_the_mutates_fix(fixture_findings):
    line = _line_of(FIXTURES / "bad_undeclared_mutation.py", "y *= alpha")
    f = _expect(fixture_findings, "HAM001", "bad_undeclared_mutation.py", line)
    # the finding must NAME the fix, not just the defect
    assert "mutates=True" in f.message
    assert "read_only=True but" not in f.message  # not the PR 5 wording


def test_declared_mutates_inplace_store_is_legal(fixture_findings):
    """A mutates=True handler's in-place store is the point of the
    annotation — zero findings anywhere in its fixture."""
    assert not [
        f for f in fixture_findings
        if Path(f.path).name == "ok_mutates.py"
    ]


def test_spec_coherence_catches_arity_mismatch(fixture_findings):
    # the finding anchors on the register() call that follows this comment
    line = _line_of(FIXTURES / "bad_arity.py", "# three leaves") + 1
    f = _expect(fixture_findings, "HAM002", "bad_arity.py", line)
    assert "3 leaves" in f.message and "2 positional" in f.message


def test_spec_coherence_catches_bad_scalar_kind(fixture_findings):
    line = _line_of(FIXTURES / "bad_arity.py", 'ScalarSpec("u4")')
    f = _expect(fixture_findings, "HAM002", "bad_arity.py", line)
    assert "'u4'" in f.message


def test_same_source_catches_foreign_registration(fixture_findings):
    line = _line_of(FIXTURES / "bad_unreachable.py", 'name="bad/foreign_fn"')
    f = _expect(fixture_findings, "HAM003", "bad_unreachable.py", line)
    assert "_bad_unreachable_helper" in f.message


def test_same_source_catches_never_at_import(fixture_findings):
    line = _line_of(FIXTURES / "bad_unreachable.py", 'name="bad/never_at_import"')
    f = _expect(fixture_findings, "HAM003", "bad_unreachable.py", line)
    assert "never executes at import" in f.message


def test_wire_constants_catches_collision_and_live_sentinel(fixture_findings):
    line = _line_of(FIXTURES / "bad_flags.py", "FLAG_EXPERIMENTAL")
    f = _expect(fixture_findings, "HAM004", "bad_flags.py", line)
    assert "collides with FLAG_STATIC" in f.message
    line = _line_of(FIXTURES / "bad_flags.py", "MSG_ID_DRAIN")
    f = _expect(fixture_findings, "HAM004", "bad_flags.py", line)
    assert "INSIDE live msg_id space" in f.message


def test_fixture_corpus_is_fully_accounted_for(fixture_findings):
    """Every fixture finding is one the tests above asserted — a rule that
    starts over- or under-firing on the corpus fails here."""
    by_rule = sorted(f.rule for f in fixture_findings)
    assert by_rule == [
        "HAM001", "HAM001", "HAM001", "HAM001",
        "HAM002", "HAM002",
        "HAM003", "HAM003",
        "HAM004", "HAM004",
    ]


def test_live_tree_is_clean_with_zero_suppressions():
    findings = lint_paths([str(SRC)])
    assert findings == [], "\n".join(f.format() for f in findings)


def test_cli_exit_codes(capsys):
    assert hamlint_main([str(SRC)]) == 0
    assert hamlint_main([str(FIXTURES)]) == 1
    out = capsys.readouterr().out
    # CLI output is file:line:col: RULE message
    assert "bad_readonly.py:" in out and "HAM001" in out


# ---------------------------------------------------------------------------
# register(): the cheap subset of hamlint, enforced at call time


def test_register_rejects_arity_mismatch():
    reg = HandlerRegistry()

    def takes_two(a, b):
        return a

    with pytest.raises(RegistryError, match="hamlint"):
        reg.register(
            takes_two,
            arg_specs=(ScalarSpec("i8"), ScalarSpec("i8"), ScalarSpec("f8")),
            name="t/arity",
        )


def test_register_rejects_uncompilable_specs():
    reg = HandlerRegistry()

    def takes_one(a):
        return a

    with pytest.raises(RegistryError, match="t/kind"):
        reg.register(takes_one, arg_specs=(ScalarSpec("u4"),), name="t/kind")


def test_register_accepts_valid_specs():
    reg = HandlerRegistry()

    def saxpy(a, x, y):
        return y

    rec = reg.register(
        saxpy,
        arg_specs=(
            ScalarSpec("f8"),
            ArraySpec((4,), "float32"),
            ArraySpec((4,), "float32"),
        ),
        name="t/ok",
    )
    assert rec.stable_name.startswith("t/ok")


# ---------------------------------------------------------------------------
# modelcheck: mitigated protocols verify, broken variants rediscover bugs


def test_ring_counters_mitigated_verifies():
    result = explore(RingCounterModel(publishes=2, mitigated=True))
    assert result.ok, result.describe()
    assert result.states > 100  # exhaustive, not a trivial walk


def test_ring_counters_broken_rediscovers_pr1_torn_read():
    start = time.monotonic()
    result = explore(RingCounterModel(publishes=2, mitigated=False))
    assert time.monotonic() - start < 5.0
    assert not result.ok
    assert "torn counter" in result.violation
    # the counterexample is the historical race: a raw read split across a
    # writer's two half-word stores fabricates a never-published value
    assert any("accept raw primary" in step for step in result.trace)


def test_doorbell_mitigated_verifies():
    result = explore(DoorbellModel(producers=2, items=1))
    assert result.ok, result.describe()


def test_doorbell_no_repoll_rediscovers_lost_wakeup():
    start = time.monotonic()
    result = explore(DoorbellModel(producers=1, items=1, repoll=False))
    assert time.monotonic() - start < 5.0
    assert not result.ok
    assert "lost wakeup" in result.violation
    assert any("FUTEX_WAIT parks" in step for step in result.trace)


def test_doorbell_no_seq_check_rediscovers_lost_wakeup():
    result = explore(DoorbellModel(producers=1, items=1, seq_check=False))
    assert not result.ok
    assert "lost wakeup" in result.violation


def test_doorbell_model_tracks_implementation_step_order(monkeypatch):
    """The model builds its consumer from CONSUMER_PARK_PROTOCOL, so an
    implementation reorder (snapshotting seq AFTER the re-poll — a real
    lost-wakeup window) is model-checked, not assumed away."""
    import repro.analysis.models.doorbell as model_mod

    monkeypatch.setattr(
        model_mod,
        "CONSUMER_PARK_PROTOCOL",
        ("arm", "repoll", "read_seq", "wait_if_unchanged"),
    )
    result = explore(DoorbellModel(producers=1, items=1))
    assert not result.ok
    assert "lost wakeup" in result.violation


def test_modelcheck_cli_quick_gate(capsys):
    start = time.monotonic()
    assert modelcheck_main(["--quick"]) == 0
    assert time.monotonic() - start < 5.0
    out = capsys.readouterr().out
    assert out.count("[PASS]") == 5
