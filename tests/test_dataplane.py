"""Location-transparent data plane: directory, epochs, replication,
crash promotion, session repin, lossless drain migration, free hygiene."""

import threading
import time

import numpy as np
import pytest

import repro.cluster.pool  # noqa: F401 — registers _cluster/* + _ham/buf_*
from repro.cluster import BufferDirectory, ClusterPool, Scheduler, gather
from repro.cluster.pool import register_cluster_handlers
from repro.core.closure import f2f
from repro.core.errors import OffloadError, RemoteExecutionError
from repro.core.registry import HandlerRegistry, default_registry
from repro.offload.buffer import BufferPtr, BufferRegistry, handle_minter
from repro.offload.runtime import register_internal_handlers


def _h_bump(ptr):
    """Buffer-MUTATING probe (deliberately not read_only): writes through
    deref, so the scheduler must pin it to the primary copy."""
    from repro.offload.api import deref

    deref(ptr)[...] += 1.0


def _h_bump_declared(ptr):
    """The same write, DECLARED (mutates=True): the scheduler routes it at
    the primary and commits the dirty epoch + replica invalidation when it
    completes."""
    from repro.offload.api import deref

    deref(ptr)[...] += 1.0


def _h_bump_then_fail(ptr):
    """Half-applied mutation: writes, then raises.  The commit must still
    run (the bytes DID change) and the caller must see the error."""
    from repro.offload.api import deref

    deref(ptr)[...] += 1.0
    raise ValueError("half-applied on purpose")


def _registry():
    reg = HandlerRegistry()
    register_internal_handlers(reg)
    register_cluster_handlers(reg)  # includes the _ham/buf_* dataplane set
    reg.register(_h_bump, name="test/bump")
    reg.register(_h_bump_declared, name="test/bump_mut", mutates=True)
    reg.register(_h_bump_then_fail, name="test/bump_mut_fail", mutates=True)
    reg.init()
    return reg


@pytest.fixture
def pool():
    p = ClusterPool.local(3, registry=_registry(), replicas=1)
    yield p
    p.close()


def _wait_dead(sched, node, timeout=10.0):
    deadline = time.time() + timeout
    while node in sched.live_nodes() and time.time() < deadline:
        time.sleep(0.02)
    assert node not in sched.live_nodes()


# -- registry-level pieces ----------------------------------------------------


def test_global_handles_are_node_namespaced():
    a, b = BufferRegistry(1), BufferRegistry(2)
    pa = a.allocate((4,), "float64")
    pb = b.allocate((4,), "float64")
    assert pa.handle != pb.handle
    assert handle_minter(pa.handle) == 1 and handle_minter(pb.handle) == 2


def test_adopt_installs_foreign_handle_and_discard_is_idempotent():
    owner, replica = BufferRegistry(1), BufferRegistry(2)
    ptr = owner.allocate((8,), "float32")
    replica.adopt_empty(ptr.handle, (8,), "float32")
    assert replica.holds(ptr.handle)
    # the replica derefs through a pointer retargeted at itself
    view = replica.deref(ptr.at(2))
    assert view.shape == (8,)
    assert replica.discard(ptr.handle) is True
    assert replica.discard(ptr.handle) is False  # idempotent
    assert replica.live_count() == 0


# -- directory unit behaviour -------------------------------------------------


def test_directory_resolves_stale_epoch_and_promotes():
    d = BufferDirectory()
    ptr = BufferPtr(1, 101, 64, 0)
    out = d.register(ptr, (8,), "float64", replicas=(2, 3))
    assert out == ptr and len(d) == 1
    assert d.resolve(ptr) is ptr  # current pointer passes through untouched
    moved = d.on_node_death(1)
    assert moved == {101: 2}  # lowest-id replica promoted
    fresh = d.resolve(ptr)
    assert (fresh.node, fresh.epoch) == (2, 1)
    assert d.lookup(101).replicas == (3,)
    # a second promotion bumps again
    assert d.on_node_death(2) == {101: 3}
    assert d.resolve(ptr).epoch == 2
    # pointer minted at epoch 1 is also stale now
    assert d.resolve(fresh).node == 3


def test_directory_records_lost_buffers_loudly():
    d = BufferDirectory()
    ptr = d.register(BufferPtr(1, 7, 16, 0), (2,), "float64")
    assert d.on_node_death(1) == {}
    assert d.lost_handles() == [7]
    with pytest.raises(OffloadError, match="lost"):
        d.resolve(ptr)
    with pytest.raises(OffloadError, match="replicas>=1"):
        d.resolve_args((ptr,))


def test_directory_retargets_args_at_any_holder():
    d = BufferDirectory()
    ptr = d.register(BufferPtr(1, 9, 32, 0), (4,), "float64", replicas=(2,))
    # target holds a replica: pointer retargeted there
    (out,), changed = d.resolve_args((ptr,), target=2)
    assert changed and out.node == 2 and out.epoch == 0
    # non-holder target: pointer resolves to the primary
    (out,), changed = d.resolve_args((ptr,), target=3)
    assert not changed and out.node == 1
    # nested containers are rewritten too (one structure level deep)
    (lst, scalar), changed = d.resolve_args(([ptr, 5], 7), target=2)
    assert changed and lst[0].node == 2 and lst[1] == 5 and scalar == 7
    # untracked pointers pass through
    stranger = BufferPtr(9, 999, 8, 0)
    (out,), changed = d.resolve_args((stranger,), target=2)
    assert not changed and out is stranger


def test_directory_locality_resolver_votes_for_all_holders():
    d = BufferDirectory()
    ptr = d.register(BufferPtr(1, 5, 100, 0), (100,), "uint8",
                     replicas=(2, 3))
    votes = d.locality_resolver(ptr)
    assert votes == {1: 100, 2: 100, 3: 100}
    assert d.locality_resolver("not a ptr") is None
    assert d.locality_resolver(BufferPtr(4, 404, 8, 0)) is None


def test_directory_primary_resolver_votes_primary_only():
    """Calls NOT declared read-only use this resolver: only the primary
    copy may serve them (a replica-routed mutation would diverge)."""
    d = BufferDirectory()
    ptr = d.register(BufferPtr(1, 5, 100, 0), (100,), "uint8",
                     replicas=(2, 3))
    assert d.primary_resolver(ptr) == {1: 100}
    d.on_node_death(1)  # promotion moves the vote with the primary
    assert d.primary_resolver(ptr) == {2: 100}
    assert d.primary_resolver("not a ptr") is None
    assert d.primary_resolver(BufferPtr(4, 404, 8, 0)) is None


def test_resolve_args_depth_matches_scan_locality_vote_depth():
    """Vote implies rewrite: a pointer nested at the scan bound is both
    votable and rewritable; one past the bound is neither (it can never
    ship with a retargeted-but-unrewritten hint)."""
    from repro.core.migratable import MAX_SCAN_DEPTH, scan_locality

    d = BufferDirectory()
    ptr = d.register(BufferPtr(1, 9, 64, 0), (8,), "float64", replicas=(2,))
    at_bound = ptr
    for _ in range(MAX_SCAN_DEPTH):
        at_bound = [at_bound]

    def innermost(v):
        while isinstance(v, list):
            v = v[0]
        return v

    assert scan_locality((at_bound,), resolver=d.locality_resolver) \
        == {1: 64, 2: 64}
    (out,), changed = d.resolve_args((at_bound,), target=2)
    assert changed and innermost(out).node == 2
    past_bound = [at_bound]
    assert scan_locality((past_bound,), resolver=d.locality_resolver) == {}
    (out,), changed = d.resolve_args((past_bound,), target=2)
    assert not changed and innermost(out) is ptr


# -- pool-level replication + crash recovery ---------------------------------


def test_write_through_put_and_replica_promotion_keeps_data(pool):
    sched = Scheduler(pool)
    arr = np.arange(256.0)
    ptr = pool.allocate(arr.shape, "float64", node=1)
    rec = pool.directory.lookup(ptr.handle)
    assert rec.primary == 1 and len(rec.replicas) == 1
    pool.put(arr, ptr)
    pool.kill(1)
    _wait_dead(sched, 1)
    rec2 = pool.directory.lookup(ptr.handle)
    assert rec2.primary == rec.replicas[0] and rec2.epoch == 1
    # the STALE pointer still reads the full data, transparently
    np.testing.assert_array_equal(pool.get(ptr), arr)
    assert pool.directory.stats["promoted"] == 1
    assert pool.directory.stats["lost"] == 0


def test_kill_worker_mid_stream_sessions_replace_onto_replica_holder():
    """The PR's acceptance property: kill a worker holding replicated
    buffers while a session stream is running; zero buffers lost, its
    sessions resume ON the replica holder, stale-epoch pointers re-resolve
    transparently."""
    pool = ClusterPool.local(3, registry=_registry(), replicas=1)
    try:
        sched = Scheduler(pool, max_inflight=8)
        reg = pool.domain.registry
        arrs, ptrs = {}, {}
        for i in range(6):
            key = f"sess-{i}"
            arr = np.arange(64.0) + i
            ptr = pool.allocate(arr.shape, "float64", session=key)
            pool.put(arr, ptr)
            arrs[key], ptrs[key] = arr, ptr
            # first submit pins the session at its buffer's home
            assert sched.submit(
                f2f("_cluster/touch", ptr, registry=reg), session=key
            ).get(10) == arr.sum()
        placement = {k: sched.sessions.lookup(k) for k in ptrs}
        for k, ptr in ptrs.items():
            assert placement[k] == pool.directory.lookup(ptr.handle).primary
        victim = placement["sess-0"]
        victims = [k for k, n in placement.items() if n == victim]
        expected_home = {
            k: pool.directory.lookup(ptrs[k].handle).replicas[0]
            for k in victims
        }
        # keep a stream of session traffic running through the kill
        streaming = [
            sched.submit(f2f("_cluster/sleep", 0.05, registry=reg),
                         session=k)
            for k in ptrs for _ in range(2)
        ]
        pool.kill(victim)
        _wait_dead(sched, victim)
        # ZERO lost buffers; the victim's buffers promoted onto replicas
        assert pool.directory.stats["lost"] == 0
        assert pool.directory.lost_handles() == []
        # its sessions were re-pinned onto the nodes now holding their data
        for k in victims:
            assert sched.sessions.lookup(k) == expected_home[k]
        # unaffected sessions never moved
        for k in ptrs:
            if k not in victims:
                assert sched.sessions.lookup(k) == placement[k]
        # the stream continues: every session still reaches ITS data with
        # the ORIGINAL (now stale-epoch) pointers
        for k, ptr in ptrs.items():
            fut = sched.submit(f2f("_cluster/touch", ptr, registry=reg),
                               session=k)
            assert fut.get(10) == arrs[k].sum()
            np.testing.assert_array_equal(pool.get(ptr), arrs[k])
        for f in streaming:
            try:
                f.get(10)
            except Exception:  # noqa: BLE001 — in-flight calls on the
                pass  # victim legitimately fail; sessions re-placed after
        assert sched.sessions.stats["recovered"] >= len(victims)
    finally:
        pool.close()


def test_crash_without_replica_is_recorded_lost(pool):
    sched = Scheduler(pool)
    ptr = pool.allocate((16,), "float64", node=2, replicas=0)
    pool.put(np.ones(16), ptr)
    pool.kill(2)
    _wait_dead(sched, 2)
    assert ptr.handle in pool.directory.lost_handles()
    with pytest.raises(OffloadError, match="lost"):
        pool.get(ptr)
    with pytest.raises(OffloadError, match="lost"):
        sched.submit(f2f("_cluster/touch", ptr,
                         registry=pool.domain.registry))


def test_remove_node_drain_migrates_primaries_losslessly(pool):
    sched = Scheduler(pool)
    reg = pool.domain.registry
    # one replicated buffer (promotion path: zero copy) and one
    # replica-less buffer (stream path) homed on the leaving node
    a = pool.allocate((32,), "float64", node=3, session="drain-a")
    b = pool.allocate((1024,), "float64", node=3, replicas=0)
    va, vb = np.arange(32.0), np.arange(1024.0)
    pool.put(va, a)
    pool.put(vb, b)
    assert sched.submit(f2f("_cluster/touch", a, registry=reg),
                        session="drain-a").get(10) == va.sum()
    pool.remove_node(3, drain=True)
    assert pool.directory.stats["lost"] == 0
    for ptr, val in ((a, va), (b, vb)):
        rec = pool.directory.lookup(ptr.handle)
        assert rec.primary in sched.live_nodes() and rec.epoch == 1
        np.testing.assert_array_equal(pool.get(ptr), val)
    # the drained node's session followed its migrated buffer
    assert sched.sessions.lookup("drain-a") == \
        pool.directory.lookup(a.handle).primary
    assert sched.submit(f2f("_cluster/touch", a, registry=reg),
                        session="drain-a").get(10) == va.sum()


def test_free_invalidates_replicas_and_live_count_is_truthful(pool):
    ptr = pool.allocate((8,), "float64", node=1)
    rec = pool.directory.lookup(ptr.handle)
    replica = rec.replicas[0]
    assert pool.buffer_count(1) == 1
    assert pool.buffer_count(replica) == 1
    pool.free(ptr)
    assert pool.directory.lookup(ptr.handle) is None
    for n in pool.live_nodes():
        assert pool.buffer_count(n) == 0  # no replica leaks


def test_worker_side_free_announces_and_invalidates_replicas(pool):
    """A free executed ON a worker (not via pool.free) must still reach the
    directory: the worker announces _ham/buf_freed, the host drops the
    record and invalidates the other holders."""
    ptr = pool.allocate((8,), "float64", node=1)
    replica = pool.directory.lookup(ptr.handle).replicas[0]
    # free at the primary through the plain paper-level data plane
    pool.domain.free(ptr.at(1))
    deadline = time.time() + 10
    while pool.directory.lookup(ptr.handle) is not None \
            and time.time() < deadline:
        time.sleep(0.02)
    assert pool.directory.lookup(ptr.handle) is None
    deadline = time.time() + 10
    while pool.buffer_count(replica) and time.time() < deadline:
        time.sleep(0.02)
    assert pool.buffer_count(replica) == 0


def test_end_session_releases_bound_buffers_cluster_wide(pool):
    sched = Scheduler(pool)
    ptr = pool.allocate((8,), "float64", session="done-s")
    pool.put(np.ones(8), ptr)
    assert len(pool.directory) == 1
    sched.end_session("done-s")
    assert len(pool.directory) == 0
    for n in pool.live_nodes():
        assert pool.buffer_count(n) == 0
    assert sched.sessions.lookup("done-s") is None


def test_locality_votes_route_to_live_replica(pool):
    """Locality policy must treat ANY live holder as local: with the
    primary dead, a read routes to the surviving replica."""
    sched = Scheduler(pool, policy="locality")
    reg = pool.domain.registry
    arr = np.arange(128.0)
    ptr = pool.allocate(arr.shape, "float64", node=2)
    pool.put(arr, ptr)
    replica = pool.directory.lookup(ptr.handle).replicas[0]
    pool.kill(2)
    _wait_dead(sched, 2)
    fut = sched.submit(f2f("_cluster/touch", ptr, registry=reg))
    assert fut.get(10) == arr.sum()
    assert sched.stats["routed"][replica] >= 1


def test_mutating_call_routes_and_pins_to_primary(pool):
    """A handler NOT declared read_only must never be served from a
    replica: locality votes go to the primary only, and its pointers are
    never retargeted — so the mutation can only land on the authoritative
    copy (the replica keeps the bytes of the last put, as documented)."""
    sched = Scheduler(pool, policy="locality")
    reg = pool.domain.registry
    arr = np.arange(16.0)
    ptr = pool.allocate(arr.shape, "float64", node=1)
    pool.put(arr, ptr)
    rec = pool.directory.lookup(ptr.handle)
    replica = rec.replicas[0]
    for _ in range(3):
        sched.submit(f2f("test/bump", ptr, registry=reg)).get(10)
    assert sched.stats["routed"].get(replica, 0) == 0
    assert sched.stats["routed"][1] == 3
    np.testing.assert_array_equal(pool.get(ptr), arr + 3.0)
    # handler-side writes are not write-through: the replica still holds
    # the last put (the documented caveat callers re-put to close)
    np.testing.assert_array_equal(
        pool.domain.get(ptr.at(replica, rec.epoch)), arr
    )


def test_mutating_call_pinned_at_replica_fails_loudly(pool):
    """Pinning a mutating call at a replica holder must fail the deref
    check (pointer stays at the primary), never silently diverge that
    copy; the same pin with a read_only handler is retargeted and works."""
    sched = Scheduler(pool)
    reg = pool.domain.registry
    ptr = pool.allocate((8,), "float64", node=1)
    pool.put(np.zeros(8), ptr)
    replica = pool.directory.lookup(ptr.handle).replicas[0]
    with pytest.raises(RemoteExecutionError):
        sched.submit(f2f("test/bump", ptr, registry=reg),
                     node=replica).get(10)
    np.testing.assert_array_equal(pool.get(ptr), np.zeros(8))  # no write
    fut = sched.submit(f2f("_cluster/touch", ptr, registry=reg),
                       node=replica)
    assert fut.get(10) == 0.0
    assert sched.stats["routed"][replica] >= 1


def test_put_serialises_against_join_backfill(pool):
    """The write-through race: a joiner backfilled from a pre-put snapshot
    of the bytes must not become a promotable holder without receiving the
    put.  The backfill copy is held open mid-window; a concurrent put must
    serialise behind it and write through the new replica too."""
    sched = Scheduler(pool)
    ptr = pool.allocate((64,), "float64", node=1)
    pool.put(np.zeros(64), ptr)
    replica = pool.directory.lookup(ptr.handle).replicas[0]
    pool.kill(replica)  # leave the buffer under-replicated
    _wait_dead(sched, replica)
    assert pool.directory.lookup(ptr.handle).replicas == ()
    copied = threading.Event()
    orig = pool._copy_buffer

    def slow_copy(rec, src, dst, timeout=30.0):
        orig(rec, src, dst, timeout)  # pre-put snapshot lands on the joiner
        copied.set()
        time.sleep(0.3)  # window in which an unserialised put would miss dst

    pool._copy_buffer = slow_copy
    try:
        joined = {}
        t = threading.Thread(
            target=lambda: joined.setdefault("node", pool.add_node())
        )
        t.start()
        assert copied.wait(30)
        new_data = np.arange(64.0)
        pool.put(new_data, ptr)  # must block until the joiner is registered
        t.join(30)
        assert not t.is_alive()
    finally:
        pool._copy_buffer = orig
    rec = pool.directory.lookup(ptr.handle)
    assert rec.replicas == (joined["node"],)
    np.testing.assert_array_equal(
        pool.domain.get(ptr.at(joined["node"], rec.epoch)), new_data
    )
    # the backfilled copy is genuinely promotable: kill the primary, read
    pool.kill(rec.primary)
    _wait_dead(sched, rec.primary)
    np.testing.assert_array_equal(pool.get(ptr), new_data)


def test_join_backfills_under_replicated_buffers(pool):
    sched = Scheduler(pool)
    arr = np.arange(64.0)
    ptr = pool.allocate(arr.shape, "float64", node=1)
    pool.put(arr, ptr)
    replica = pool.directory.lookup(ptr.handle).replicas[0]
    pool.kill(replica)  # the REPLICA dies: buffer is under-replicated
    _wait_dead(sched, replica)
    assert pool.directory.lookup(ptr.handle).replicas == ()
    new = pool.add_node()  # lazy backfill restores the replication factor
    rec = pool.directory.lookup(ptr.handle)
    assert rec.replicas == (new,)
    assert pool.directory.stats["backfilled"] >= 1
    # the backfilled copy really holds the bytes: kill the primary, read
    pool.kill(rec.primary)
    _wait_dead(sched, rec.primary)
    np.testing.assert_array_equal(pool.get(ptr), arr)


# -- the active-access write protocol (chain put + mutate-at-data) -----------


def _holder_dirty(pool, node, handle):
    return pool.domain._inproc[node].applied_dirty.get(int(handle))


def test_chain_put_wire_confirms_every_holder(pool):
    """Over the wire, a replicated put sends the bytes host->primary once;
    the primary streams the chain.  Every holder must end with the payload
    AND an applied_dirty watermark matching the directory's dirty epoch —
    that watermark is what host-crash recovery uses to spot stale tails."""
    pool.domain.direct_data_plane = False
    arr = np.arange(4096.0)
    ptr = pool.allocate(arr.shape, "float64", node=1)
    pool.put(arr, ptr)
    pool.put(arr * 2, ptr)  # second write: dirty must advance, not reset
    rec = pool.directory.lookup(ptr.handle)
    assert rec.replicas != ()
    assert rec.dirty == 2
    for holder in (ptr.node, *rec.replicas):
        np.testing.assert_array_equal(
            pool.domain.get(ptr.at(holder, rec.epoch)), arr * 2
        )
        assert _holder_dirty(pool, holder, ptr.handle) == rec.dirty


def test_chain_put_direct_path_keeps_the_same_contract(pool):
    """Thread pools take the in-process shortcut (memcpy per holder) —
    bytes and applied_dirty must come out exactly as the wire chain's."""
    assert pool.domain.direct_data_plane
    arr = np.arange(512.0)
    ptr = pool.allocate(arr.shape, "float64", node=1)
    pool.put(arr, ptr)
    rec = pool.directory.lookup(ptr.handle)
    assert rec.replicas != () and rec.dirty == 1
    for holder in (ptr.node, *rec.replicas):
        np.testing.assert_array_equal(
            pool.domain.get(ptr.at(holder, rec.epoch)), arr
        )
        assert _holder_dirty(pool, holder, ptr.handle) == rec.dirty


def test_mutation_commit_drops_replicas_for_lazy_backfill(pool):
    """Drop mode (default): a committed mutates=True call invalidates the
    replica copies — they leave the holder set (nothing stale stays
    promotable) and the next join re-backfills the NEW bytes."""
    sched = Scheduler(pool, policy="locality")
    reg = pool.domain.registry
    arr = np.arange(64.0)
    ptr = pool.allocate(arr.shape, "float64", node=1)
    pool.put(arr, ptr)
    assert pool.directory.lookup(ptr.handle).replicas != ()
    sched.submit(f2f("test/bump_mut", ptr, registry=reg)).get(10)
    rec = pool.directory.lookup(ptr.handle)
    assert rec.replicas == ()  # dropped at commit, not left stale
    assert rec.dirty == 2  # put, then the committed mutation
    assert sched.stats["mutations_committed"] == 1
    np.testing.assert_array_equal(pool.get(ptr), arr + 1.0)
    joined = pool.add_node()  # lazy backfill re-replicates the new bytes
    rec = pool.directory.lookup(ptr.handle)
    assert rec.replicas == (joined,)
    np.testing.assert_array_equal(
        pool.domain.get(ptr.at(joined, rec.epoch)), arr + 1.0
    )


def test_mutation_commit_refresh_converges_replica():
    """Refresh mode: the primary chain-pushes the new bytes; the replica
    stays a holder and reflects the mutation by the time the future
    resolves — zero stale-read window beyond the in-flight write."""
    p = ClusterPool.local(3, registry=_registry(), replicas=1,
                          mutation_refresh=True)
    try:
        sched = Scheduler(p, policy="locality")
        reg = p.domain.registry
        arr = np.arange(64.0)
        ptr = p.allocate(arr.shape, "float64", node=1)
        p.put(arr, ptr)
        replica = p.directory.lookup(ptr.handle).replicas[0]
        sched.submit(f2f("test/bump_mut", ptr, registry=reg)).get(10)
        rec = p.directory.lookup(ptr.handle)
        assert rec.replicas == (replica,)  # still a holder
        np.testing.assert_array_equal(
            p.domain.get(ptr.at(replica, rec.epoch)), arr + 1.0
        )
        assert _holder_dirty(p, replica, ptr.handle) == rec.dirty
    finally:
        p.close()


def test_mutation_commit_runs_even_when_handler_raises(pool):
    """A mutating handler that raises AFTER writing is half-applied: the
    caller must see the error, but the commit must still run — replica
    holders would otherwise keep serving the overwritten bytes."""
    sched = Scheduler(pool, policy="locality")
    reg = pool.domain.registry
    ptr = pool.allocate((16,), "float64", node=1)
    pool.put(np.zeros(16), ptr)
    with pytest.raises(RemoteExecutionError, match="half-applied"):
        sched.submit(f2f("test/bump_mut_fail", ptr, registry=reg)).get(10)
    rec = pool.directory.lookup(ptr.handle)
    assert rec.replicas == ()  # invalidated despite the error
    assert sched.stats["mutations_committed"] == 1
    np.testing.assert_array_equal(pool.get(ptr), np.ones(16))


def test_undeclared_mutation_warns_once(pool, caplog):
    """A handler that is neither read_only nor mutates and derefs a
    replicated tracked buffer gets ONE warning naming the mutates=True
    fix — per handler, not per call."""
    import logging

    sched = Scheduler(pool, policy="locality")
    reg = pool.domain.registry
    ptr = pool.allocate((8,), "float64", node=1)
    pool.put(np.zeros(8), ptr)
    with caplog.at_level(logging.WARNING, logger="repro.cluster.scheduler"):
        for _ in range(3):
            sched.submit(f2f("test/bump", ptr, registry=reg)).get(10)
    hits = [r for r in caplog.records if "mutates=True" in r.getMessage()]
    assert len(hits) == 1
    assert "docs/failure-model.md" in hits[0].getMessage()


def test_pool_mutate_routes_to_primary_and_commits(pool):
    """pool.mutate is the bare Active-Access write primitive: one sync call
    at the primary plus the dirty-epoch commit — no scheduler attached.
    If the call ran anywhere but the primary, the post-commit read (served
    by the primary after replicas drop) would return the OLD bytes."""
    reg = pool.domain.registry
    arr = np.arange(64.0)
    ptr = pool.allocate(arr.shape, "float64", node=1)
    pool.put(arr, ptr)
    assert pool.directory.lookup(ptr.handle).replicas != ()
    pool.mutate(f2f("test/bump_mut", ptr, registry=reg))
    rec = pool.directory.lookup(ptr.handle)
    assert rec.replicas == ()  # committed: dropped, not left stale
    assert rec.dirty == 2  # put, then the committed mutation
    np.testing.assert_array_equal(pool.get(ptr), arr + 1.0)


def test_pool_mutate_commits_on_error_and_rejects_misuse(pool):
    """Half-applied mutations still commit (the caller sees the handler's
    error, replicas do not keep the overwritten bytes); handlers not
    declared mutates=True and calls with no tracked buffer are refused
    up front."""
    reg = pool.domain.registry
    ptr = pool.allocate((16,), "float64", node=1)
    pool.put(np.zeros(16), ptr)
    with pytest.raises(RemoteExecutionError, match="half-applied"):
        pool.mutate(f2f("test/bump_mut_fail", ptr, registry=reg))
    rec = pool.directory.lookup(ptr.handle)
    assert rec.replicas == ()  # invalidated despite the error
    np.testing.assert_array_equal(pool.get(ptr), np.ones(16))
    with pytest.raises(OffloadError, match="mutates=True"):
        pool.mutate(f2f("test/bump", ptr, registry=reg))
    with pytest.raises(OffloadError, match="no directory-tracked buffer"):
        pool.mutate(f2f("test/bump_mut", np.zeros(4), registry=reg))


# -- the same recovery story over a REAL process fabric ----------------------


def _default_registry_ready():
    reg = default_registry()
    register_cluster_handlers(reg)
    if not reg.initialised:
        reg.init()
    return reg


@pytest.mark.fork
def test_fork_kill_worker_with_replicated_buffers_recovers():
    """Crash recovery across real process death: a forked shm worker
    holding replicated buffers is killed mid-stream; its session re-places
    onto the replica holder and the ORIGINAL stale pointer still reads the
    data back intact over the wire."""
    reg = _default_registry_ready()
    pool = ClusterPool.shm(3, registry=reg, replicas=1)
    try:
        sched = Scheduler(pool, max_inflight=8)
        pool.ping_all()
        arr = np.arange(4096.0)
        ptr = pool.allocate(arr.shape, "float64", node=1, session="fk")
        pool.put(arr, ptr)
        assert sched.submit(f2f("_cluster/touch", ptr, registry=reg),
                            session="fk").get(20) == arr.sum()
        assert sched.sessions.lookup("fk") == 1
        replica = pool.directory.lookup(ptr.handle).replicas[0]
        streaming = [sched.submit(f2f("_cluster/sleep", 0.05, registry=reg),
                                  session="fk") for _ in range(4)]
        pool.kill(1)
        _wait_dead(sched, 1)
        assert pool.directory.stats["lost"] == 0
        assert sched.sessions.lookup("fk") == replica
        rec = pool.directory.lookup(ptr.handle)
        assert rec.primary == replica and rec.epoch == 1
        np.testing.assert_array_equal(pool.get(ptr), arr)
        assert sched.submit(f2f("_cluster/touch", ptr, registry=reg),
                            session="fk").get(20) == arr.sum()
        for f in streaming:
            try:
                f.get(10)
            except Exception:  # noqa: BLE001 — in-flight on the corpse
                pass
    finally:
        pool.close()


@pytest.mark.fork
def test_fork_remove_node_drain_is_lossless():
    reg = _default_registry_ready()
    pool = ClusterPool.shm(2, registry=reg, replicas=0)
    try:
        sched = Scheduler(pool)
        pool.ping_all()
        arr = np.arange(2048.0)
        ptr = pool.allocate(arr.shape, "float64", node=2)
        pool.put(arr, ptr)
        pool.remove_node(2, drain=True)
        assert sched.live_nodes() == [1]
        rec = pool.directory.lookup(ptr.handle)
        assert rec.primary == 1 and rec.epoch == 1
        assert pool.directory.stats["lost"] == 0
        np.testing.assert_array_equal(pool.get(ptr), arr)
    finally:
        pool.close()
