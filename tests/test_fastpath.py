"""Static-spec RPC fast path: compiled WirePlans, FLAG_STATIC wire format,
small-call fusion (FLAG_FUSED), and wire compat with pre-plan peers."""

import numpy as np
import pytest

import repro.core as ham
import repro.offload.demo_handlers  # noqa: F401 — registers demo/* at
#                            collection, before any test seals the registry
from repro.core import migratable as mig
from repro.core.closure import f2f
from repro.core.errors import SpecMismatchError
from repro.core.executor import ThreadPoolPolicy
from repro.core.message import (
    FLAG_DYNAMIC,
    FLAG_ERROR,
    FLAG_FUSED,
    FLAG_REPLY,
    FLAG_SHAPED,
    FLAG_STATIC,
    decode_fast,
    encode_frame,
    iter_fused,
)
from repro.core.migratable import ArraySpec, ScalarSpec
from repro.core.registry import HandlerRegistry
from repro.core.wireplan import WirePlan
from repro.comm.local import LocalFabric
from repro.offload.runtime import NodeRuntime, register_internal_handlers

ARR = np.arange(28, dtype=np.float64)
ECHO_SPECS = tuple(mig.spec_of(x) for x in (1, 2, 3.0, ARR))


# -- WirePlan unit behaviour -------------------------------------------------


def test_wireplan_layout_matches_legacy_pack_static():
    """The compiled plan's wire bytes are identical to pack_static — the
    invariant that makes FLAG_STATIC advisory (pre-plan peers interop)."""
    cases = [
        ((True, 5, 2.5), None),
        ((1, 2, 3.0, ARR), None),
        ((ARR,), None),
        ((np.arange(12, dtype=np.int32).reshape(3, 4), False, 7), None),
        ((), None),
    ]
    for args, _ in cases:
        specs = tuple(mig.spec_of(a) for a in args)
        plan = WirePlan(specs)
        assert plan.nbytes == mig.static_payload_nbytes(specs)
        buf = bytearray(plan.nbytes)
        plan.pack_args(buf, 0, args)
        assert bytes(buf) == bytes(mig.pack_static(args, specs))
        out = plan.unpack_args(memoryview(buf))
        legacy = mig.unpack_static(buf, specs)
        assert len(out) == len(legacy)
        for a, b in zip(out, legacy):
            if isinstance(a, np.ndarray):
                np.testing.assert_array_equal(a, b)
            else:
                assert a == b and type(a) is type(b)


def test_wireplan_zero_copy_array_views():
    plan = WirePlan((ArraySpec((4,), "float64"),))
    buf = bytearray(plan.nbytes)
    plan.pack_args(buf, 0, (np.arange(4.0),))
    (view,) = plan.unpack_args(memoryview(buf))
    buf[0:8] = mig.pack_static((99.0,), (ScalarSpec("f8"),))
    assert view[0] == 99.0  # aliases the payload, no copy


def test_wireplan_offset_pack_and_2d_noncontiguous():
    arr2 = np.arange(64, dtype=np.float32).reshape(8, 8)
    plan = WirePlan((mig.spec_of(arr2), ScalarSpec("i8")))
    buf = bytearray(16 + plan.nbytes)
    plan.pack_args(buf, 16, (np.asfortranarray(arr2), 7))  # non-contiguous
    out = plan.unpack_args(memoryview(buf)[16:])
    np.testing.assert_array_equal(out[0], arr2)
    assert out[1] == 7


def test_wireplan_opaque_leaf_roundtrip():
    from repro.offload.buffer import BufferPtr

    ptr = BufferPtr(3, 17, 4096)
    plan = WirePlan((mig.spec_of(ptr), ScalarSpec("i8")))
    buf = bytearray(plan.nbytes)
    plan.pack_args(buf, 0, (ptr, 5))
    out = plan.unpack_args(buf)
    assert (out[0].node, out[0].handle, out[0].nbytes) == (3, 17, 4096)
    assert out[1] == 5


def test_wireplan_result_arity_convention():
    # () => None, zero bytes
    p0 = WirePlan(())
    p0.pack_result(bytearray(0), 0, None)
    assert p0.unpack_result(b"") is None
    with pytest.raises(SpecMismatchError):
        p0.pack_result(bytearray(0), 0, 1)
    # one spec => bare value
    p1 = WirePlan((ScalarSpec("f8"),))
    b1 = bytearray(8)
    p1.pack_result(b1, 0, 2.5)
    assert p1.unpack_result(b1) == 2.5
    # N specs => tuple
    p2 = WirePlan((ScalarSpec("i8"), ScalarSpec("b1")))
    b2 = bytearray(p2.nbytes)
    p2.pack_result(b2, 0, (4, True))
    assert p2.unpack_result(b2) == (4, True)
    with pytest.raises(SpecMismatchError):
        p2.pack_result(bytearray(p2.nbytes), 0, 4)  # not a tuple


def test_wireplan_rejects_mismatches():
    plan = WirePlan(ECHO_SPECS)
    buf = bytearray(plan.nbytes)
    with pytest.raises(SpecMismatchError):
        plan.pack_args(buf, 0, (1, 2, 3.0))  # arity
    with pytest.raises(SpecMismatchError):
        plan.pack_args(buf, 0, (1, 2, 3.0, np.zeros(5)))  # shape
    with pytest.raises(SpecMismatchError):
        plan.pack_args(buf, 0, (1, 2, 3.0, ARR.astype(np.float32)))  # dtype
    with pytest.raises(SpecMismatchError):
        plan.pack_args(buf, 0, ("x", 2, 3.0, ARR))  # scalar type
    with pytest.raises(SpecMismatchError):
        plan.unpack_args(memoryview(buf)[: plan.nbytes - 1])  # short payload


def test_handler_table_compiles_dense_plan_arrays():
    reg = _make_registry()
    table = reg.table
    k_static = table.key_of("t/add_s")
    k_dyn = table.key_of("t/add_d")
    assert table.arg_plans[k_static] is not None
    assert table.arg_plans[k_static].nbytes == 16
    assert table.result_plans[k_static] is not None
    assert table.arg_plans[k_dyn] is None
    assert table.result_plans[k_dyn] is None
    assert len(table.arg_plans) == len(table.records) == len(table)


# -- wire format + compat ----------------------------------------------------


def _make_registry():
    reg = HandlerRegistry()
    register_internal_handlers(reg)

    def add(a, b):
        return a + b

    def echo(a, b, scale, arr):
        return float(a + b) * scale

    def boom_on(x):
        if x == 13:
            raise ValueError("unlucky thirteen")
        return x * 2

    order: list = []

    def record_order(x):
        order.append(x)
        return x

    i8, f8 = ScalarSpec("i8"), ScalarSpec("f8")
    reg.register(add, arg_specs=(i8, i8), result_specs=(i8,), name="t/add_s")
    reg.register(add, name="t/add_d")
    reg.register(echo, arg_specs=ECHO_SPECS, result_specs=(f8,),
                 name="t/echo_s")
    reg.register(echo, name="t/echo_d")
    reg.register(boom_on, arg_specs=(i8,), result_specs=(i8,),
                 name="t/boom_on")
    reg.register(record_order, arg_specs=(i8,), result_specs=(i8,),
                 name="t/order")
    reg.register(lambda: (3, 2.5), arg_specs=(), result_specs=(i8, f8),
                 name="t/pair")
    reg._order_log = order  # test hook (threads share the list)
    reg.init()
    return reg


def test_static_request_and_reply_carry_flag_static():
    reg = _make_registry()
    table = reg.table
    fab = LocalFabric(2)
    host = NodeRuntime(0, fab.endpoint(0), table, inline=True)
    epw = fab.endpoint(1)  # raw peer endpoint: observe frames on the wire
    host._send_request(1, f2f("t/add_s", 2, 3, registry=reg), 7)
    key, flags, src, mid, payload = decode_fast(epw.recv(timeout=5))
    assert flags & FLAG_STATIC and not flags & FLAG_DYNAMIC
    assert (key, src, mid) == (table.key_of("t/add_s"), 0, 7)
    assert bytes(payload) == bytes(
        mig.pack_static((2, 3), (ScalarSpec("i8"), ScalarSpec("i8")))
    )
    # dynamic handler request with a speccable shape rides the shape-keyed
    # plan cache (FLAG_SHAPED: u16 sig_len | sig | plan-packed leaves)
    host._send_request(1, f2f("t/add_d", 2, 3, registry=reg), 8)
    _, flags, _, _, payload = decode_fast(epw.recv(timeout=5))
    assert flags & FLAG_SHAPED and not flags & (FLAG_STATIC | FLAG_DYNAMIC)
    assert host._shape_cache.unpack_shaped(payload, expect_args=True) == (2, 3)
    # non-speccable args (a string) keep the TLV fallback with FLAG_DYNAMIC
    host._send_request(1, f2f("t/add_d", "a", "b", registry=reg), 8)
    _, flags, _, _, payload = decode_fast(epw.recv(timeout=5))
    assert flags & FLAG_DYNAMIC and not flags & (FLAG_STATIC | FLAG_SHAPED)
    assert mig.unpack_dynamic(payload) == ["a", "b"]
    # a worker runtime replies to the static request with a STATIC reply
    worker = NodeRuntime(1, epw, table)
    host._send_request(1, f2f("t/add_s", 20, 22, registry=reg), 9)
    worker._handle_frame(worker.endpoint.recv(timeout=5))
    key, flags, src, mid, payload = decode_fast(host.endpoint.recv(timeout=5))
    assert flags & FLAG_REPLY and flags & FLAG_STATIC
    assert table.result_plans[key].unpack_result(payload) == 42
    fab.close()


def test_flag_static_less_peer_frame_still_dispatches():
    """Wire compat: a pre-plan peer packs static payloads with flags=0 —
    the receiver's compiled plan must decode it (identical layout)."""
    reg = _make_registry()
    table = reg.table
    fab = LocalFabric(2)
    worker = NodeRuntime(1, fab.endpoint(1), table).start()
    ep0 = fab.endpoint(0)
    key = table.key_of("t/add_s")
    legacy = encode_frame(
        key,
        mig.pack_static((4, 5), (ScalarSpec("i8"), ScalarSpec("i8"))),
        src_node=0, msg_id=21, flags=0,  # no STATIC, no DYNAMIC: old wire
    )
    ep0.send(1, legacy)
    key2, flags2, _, mid2, payload = decode_fast(ep0.recv(timeout=5))
    assert mid2 == 21 and flags2 & FLAG_REPLY and not flags2 & FLAG_ERROR
    if flags2 & FLAG_STATIC:
        assert table.result_plans[key2].unpack_result(payload) == 9
    else:
        assert mig.unpack_dynamic(payload) == 9
    worker.stop()
    fab.close()


def test_flagless_dynamic_reply_still_resolves():
    """A pre-plan peer's reply carries neither STATIC nor DYNAMIC — it must
    decode as TLV (the legacy reply encoding)."""
    reg = _make_registry()
    table = reg.table
    fab = LocalFabric(2)
    host = NodeRuntime(0, fab.endpoint(0), table).start()
    ep1 = fab.endpoint(1)
    msg_id, fut = host.futures.create()
    reply = encode_frame(
        table.key_of("t/add_d"), mig.pack_dynamic(123),
        src_node=1, msg_id=msg_id, flags=FLAG_REPLY,
    )
    ep1.send(0, reply)
    assert fut.get(5) == 123
    host.stop()
    fab.close()


def test_mixed_static_dynamic_traffic_one_stream():
    reg = _make_registry()
    table = reg.table
    fab = LocalFabric(2)
    worker = NodeRuntime(1, fab.endpoint(1), table).start()
    host = NodeRuntime(0, fab.endpoint(0), table, inline=True)
    futs = []
    for i in range(40):
        name = "t/add_s" if i % 2 else "t/add_d"
        futs.append(host.send_async(1, f2f(name, i, i, registry=reg)))
        if i % 10 == 5:  # interleave sync calls into the same stream
            assert host.send_sync(1, f2f("t/echo_s", 1, 2, 3.0, ARR,
                                         registry=reg)) == 9.0
    assert [host._inline_wait(f, 10) for f in futs] == [2 * i for i in range(40)]
    # multi-leaf static result decodes as a tuple
    assert host.send_sync(1, f2f("t/pair", registry=reg)) == (3, 2.5)
    worker.stop()
    fab.close()


def test_static_result_spec_violation_travels_as_error():
    """A handler that returns something violating its declared result spec
    must error the CALLER (plan pack failure => REPLY|ERROR), not kill the
    worker loop."""
    reg = _make_registry()

    def bad():
        return "not an int"

    reg2 = HandlerRegistry()
    register_internal_handlers(reg2)
    reg2.register(bad, arg_specs=(), result_specs=(ScalarSpec("i8"),),
                  name="t/bad_result")
    table = reg2.init()
    fab = LocalFabric(2)
    worker = NodeRuntime(1, fab.endpoint(1), table).start()
    host = NodeRuntime(0, fab.endpoint(0), table, inline=True)
    with pytest.raises(ham.RemoteExecutionError):
        host.send_sync(1, f2f("t/bad_result", registry=reg2))
    # worker survived
    assert host.send_sync(1, f2f("_ham/ping", 4, registry=reg2)) == 4
    worker.stop()
    fab.close()


# -- fused frames ------------------------------------------------------------


def test_send_fused_values_and_order():
    reg = _make_registry()
    table = reg.table
    fab = LocalFabric(2)
    worker = NodeRuntime(1, fab.endpoint(1), table).start()
    host = NodeRuntime(0, fab.endpoint(0), table, inline=True)
    calls = [f2f("t/order", i, registry=reg) for i in range(24)]
    futs = host.send_fused(1, calls)
    assert [host._inline_wait(f, 10) for f in futs] == list(range(24))
    # executed in submission order, in one dispatch pass per frame
    assert reg._order_log == list(range(24))
    # replies to the fused batch came back fused (egress fold on the worker)
    assert worker.stats["fused"] >= 24
    worker.stop()
    fab.close()


def test_fused_error_isolated_to_its_own_future():
    reg = _make_registry()
    table = reg.table
    fab = LocalFabric(2)
    worker = NodeRuntime(1, fab.endpoint(1), table).start()
    host = NodeRuntime(0, fab.endpoint(0), table, inline=True)
    xs = [7, 13, 9, 13, 11]
    futs = host.send_fused(1, [f2f("t/boom_on", x, registry=reg) for x in xs])
    results = []
    for x, f in zip(xs, futs):
        if x == 13:
            with pytest.raises(ham.RemoteExecutionError, match="thirteen"):
                host._inline_wait(f, 10)
            results.append("err")
        else:
            results.append(host._inline_wait(f, 10))
    assert results == [14, "err", 18, "err", 22]
    worker.stop()
    fab.close()


def test_fused_mixed_static_dynamic_segments():
    reg = _make_registry()
    table = reg.table
    fab = LocalFabric(2)
    worker = NodeRuntime(1, fab.endpoint(1), table).start()
    host = NodeRuntime(0, fab.endpoint(0), table, inline=True)
    calls = [f2f("t/add_s", 1, 2, registry=reg),
             f2f("t/add_d", 10, 20, registry=reg),
             f2f("t/echo_s", 1, 2, 3.0, ARR, registry=reg)]
    futs = host.send_fused(1, calls)
    assert [host._inline_wait(f, 10) for f in futs] == [3, 30, 9.0]
    worker.stop()
    fab.close()


def test_fused_single_executor_pass_on_pool_policy():
    reg = _make_registry()
    table = reg.table

    submits = []

    class CountingPolicy(ThreadPoolPolicy):
        def submit(self, fn):
            submits.append(fn)
            super().submit(fn)

    fab = LocalFabric(2)
    worker = NodeRuntime(1, fab.endpoint(1), table,
                         policy=CountingPolicy(2)).start()
    host = NodeRuntime(0, fab.endpoint(0), table, inline=True)
    futs = host.send_fused(1, [f2f("t/add_s", i, i, registry=reg)
                               for i in range(10)])
    assert [host._inline_wait(f, 10) for f in futs] == [2 * i for i in range(10)]
    assert len(submits) == 1  # ten requests, ONE executor submit
    worker.stop()
    fab.close()


def test_send_fused_pack_failure_discards_every_future():
    """All-or-nothing send_fused: a call whose args violate its spec mid-
    batch must raise to the caller AND leave no orphaned FutureTable
    entries (nothing was handed back to wait on)."""
    from repro.core.closure import Function

    reg = _make_registry()
    table = reg.table
    fab = LocalFabric(2)
    host = NodeRuntime(0, fab.endpoint(0), table, inline=True)
    good = f2f("t/add_s", 1, 2, registry=reg)
    bad = Function(good.record, ("x", "y"))  # bypasses f2f validation
    before = host.futures.outstanding()
    with pytest.raises(SpecMismatchError):
        host.send_fused(1, [good] * 70 + [bad])  # bad lands in chunk 2
    assert host.futures.outstanding() == before
    # and nothing hit the wire: all frames pack before any send
    assert fab.endpoint(1).recv(timeout=0.05) is None
    fab.close()


def test_fused_frame_layout_and_truncation():
    reg = _make_registry()
    table = reg.table
    fab = LocalFabric(2)
    host = NodeRuntime(0, fab.endpoint(0), table, inline=True)
    epw = fab.endpoint(1)
    host._send_fused_request(1, [
        (f2f("t/add_s", 1, 2, registry=reg), 101),
        (f2f("t/add_d", 3, 4, registry=reg), 102),
    ])
    frame = epw.recv(timeout=5)
    key, flags, src, mid, payload = decode_fast(frame)
    assert flags & FLAG_FUSED and (key, mid) == (0, 0) and src == 0
    segs = list(iter_fused(payload))
    assert [s[2] for s in segs] == [101, 102]
    assert segs[0][1] & FLAG_STATIC
    # the dynamic call's shape is speccable, so it rides a shaped segment
    assert segs[1][1] & FLAG_SHAPED
    assert host._shape_cache.unpack_shaped(segs[1][3], expect_args=True) == (3, 4)
    # truncated fused payloads must fail loudly, not mis-slice
    with pytest.raises(ham.MessageFormatError):
        list(iter_fused(payload[: len(payload) - 3]))
    with pytest.raises(ham.MessageFormatError):
        list(iter_fused(payload[:2]))
    fab.close()


def test_egress_fusion_skips_relayed_frames():
    """_ham/forward relays a frame whose src is the ORIGIN; folding it into
    a fused frame would rewrite its source and misroute the reply.  Relay
    through a middle node while its egress is busy — the reply must still
    come back to the origin."""
    reg = _make_registry()
    from repro.offload.api import OffloadDomain

    dom = OffloadDomain.local(3, registry=reg)
    try:
        futs = [dom.relay(via=1, dst=2,
                          function=f2f("t/add_s", i, i, registry=reg))
                for i in range(8)]
        assert [f.get(10) for f in futs] == [2 * i for i in range(8)]
    finally:
        dom.shutdown()


# -- scheduler-level fusion --------------------------------------------------


def _cluster_registry():
    from repro.cluster.pool import register_cluster_handlers

    reg = HandlerRegistry()
    register_internal_handlers(reg)
    register_cluster_handlers(reg)
    i8, f8 = ScalarSpec("i8"), ScalarSpec("f8")

    def mul(a, b):
        return float(a * b)

    def boom_on(x):
        if x == 13:
            raise ValueError("unlucky thirteen")
        return x * 2

    reg.register(mul, arg_specs=(i8, f8), result_specs=(f8,), name="t/mul_s")
    reg.register(boom_on, arg_specs=(i8,), result_specs=(i8,),
                 name="t/boom_on")
    reg.init()
    return reg


def test_scheduler_fusion_end_to_end():
    from repro.cluster import ClusterPool, Scheduler, gather

    reg = _cluster_registry()
    pool = ClusterPool.local(2, registry=reg)
    sched = Scheduler(pool, fuse_window=0.002, fuse_max=8)
    try:
        futs = [sched.submit(f2f("t/mul_s", i, 0.5, registry=reg))
                for i in range(64)]
        assert gather(futs, 30) == [i * 0.5 for i in range(64)]
        assert sched.stats["fused_calls"] == 64
        assert sched.outstanding() == 0  # every credit returned
        # error isolation through the scheduler path
        futs = [sched.submit(f2f("t/boom_on", x, registry=reg))
                for x in (7, 13, 9)]
        assert futs[0].get(10) == 14 and futs[2].get(10) == 18
        with pytest.raises(ham.RemoteExecutionError, match="thirteen"):
            futs[1].get(10)
    finally:
        sched.close()
        pool.close()


def test_scheduler_fusion_preserves_order_vs_unfusible():
    """A non-fusible (dynamic) submit to the same target must not overtake
    parked fused calls: per-target submission order is preserved."""
    from repro.cluster import ClusterPool, Scheduler

    reg = HandlerRegistry()
    register_internal_handlers(reg)
    from repro.cluster.pool import register_cluster_handlers

    register_cluster_handlers(reg)
    order: list = []

    def note(x):
        order.append(x)
        return x

    reg.register(note, arg_specs=(ScalarSpec("i8"),),
                 result_specs=(ScalarSpec("i8"),), name="t/note_s")
    reg.register(note, name="t/note_d")
    reg.init()
    pool = ClusterPool.local(1, registry=reg)
    sched = Scheduler(pool, fuse_window=0.5, fuse_max=100)  # window >> test
    try:
        f1 = sched.submit(f2f("t/note_s", 1, registry=reg), node=1)
        f2 = sched.submit(f2f("t/note_s", 2, registry=reg), node=1)
        f3 = sched.submit(f2f("t/note_d", 3, registry=reg), node=1)  # flushes
        assert [f.get(10) for f in (f1, f2, f3)] == [1, 2, 3]
        assert order == [1, 2, 3]
        # and an explicit flush ships a parked tail without waiting
        f4 = sched.submit(f2f("t/note_s", 4, registry=reg), node=1)
        sched.flush()
        assert f4.get(1) == 4
    finally:
        sched.close()
        pool.close()


# -- end to end over a real forked shm worker --------------------------------


@pytest.mark.shm
def test_static_and_fused_roundtrip_over_shm_subprocess():
    """The full fast path against a REAL worker process over shared memory:
    static round trip, fused batch, mixed static/dynamic stream — crossing
    an actual address-space boundary, fresh interpreter (no fork inherit)."""
    from repro.comm.shm import ShmFabric
    from repro.core.registry import default_registry
    from repro.offload.api import OffloadDomain
    from repro.offload.demo_handlers import _ECHO_ARGS
    from repro.offload.worker import reap, spawn_shm_worker_subprocess

    reg = default_registry()
    if not reg.initialised:
        reg.init()
    fab = ShmFabric(2, capacity=1 << 20)
    proc = spawn_shm_worker_subprocess(fab, 1)
    dom = OffloadDomain(fab, registry=reg, inline_host=True)
    try:
        assert dom.ping(1, 3, timeout=30.0) == 3
        call_s = f2f("demo/echo_small_static", *_ECHO_ARGS)
        call_d = f2f("demo/echo_small_dyn", *_ECHO_ARGS)
        assert dom.sync(1, call_s) == 9.0  # static args + static reply
        assert dom.sync(1, call_d) == 9.0  # TLV both ways, same handler
        # fused batch across the process boundary
        futs = dom.host.send_fused(1, [call_s] * 20)
        assert [dom.host._inline_wait(f, 30) for f in futs] == [9.0] * 20
        # mixed stream
        futs = [dom.host.send_async(1, call_s if i % 2 else call_d)
                for i in range(20)]
        assert [dom.host._inline_wait(f, 30) for f in futs] == [9.0] * 20
    finally:
        dom.shutdown()
        reap([proc], timeout=5.0)
