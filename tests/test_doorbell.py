"""Doorbell wakeup: futex semantics, park/wake races, cross-process RTT.

The spin-then-park receive path (``docs/transport.md``) replaces the shm
ring's spin+sleep loop: after ``RingConfig.spin_budget`` empty polls the
receiver arms its doorbell (waiters=1), re-polls once, then parks in
``FUTEX_WAIT`` on the bell's sequence word.  The protocol's correctness
claims — no lost wakeups beyond one ``park_timeout``, spurious wakes are
harmless, torn seq increments are safe — are what these tests attack.
Tests force ``spin_budget=0`` so every receive actually parks; on the
default config a loaded machine might never leave the spin phase.
"""

from __future__ import annotations

import threading
import time

import pytest

from repro.comm.doorbell import Doorbell, bell_name, futex_available
from repro.comm.shm import RingConfig, ShmFabric

pytestmark = pytest.mark.shm

needs_futex = pytest.mark.skipif(
    not futex_available(), reason="futex syscall unavailable on this platform"
)

#: forces the park path on every receive — the spin phase is skipped
PARK_CFG = RingConfig(spin_budget=0, park_timeout=2e-3)


# -- Doorbell unit behaviour -------------------------------------------------


@needs_futex
def test_wait_returns_immediately_on_stale_seq():
    """FUTEX_WAIT with a mismatched expected value must not block: this is
    the re-check that closes the arm->park race (a ring between arm and
    park changes seq, so the kernel refuses the wait with EAGAIN)."""
    bell = Doorbell("test_db_stale", create=True)
    try:
        seq = bell.read_seq()
        bell.ring()  # seq moved on: a wait on the OLD value must not park
        t0 = time.monotonic()
        bell.wait(seq, timeout_s=1.0)
        assert time.monotonic() - t0 < 0.5
    finally:
        bell.close()
        bell.unlink()


@needs_futex
def test_wait_times_out_on_current_seq():
    """No producer => the wait expires at the park timeout, not earlier
    (spurious immediate returns are allowed by futex(2) but a *systematic*
    early return would mean the expected-value plumbing is wrong)."""
    bell = Doorbell("test_db_timeout", create=True)
    try:
        t0 = time.monotonic()
        bell.wait(bell.read_seq(), timeout_s=0.05)
        # generous lower bound: some kernels round the timespec down
        assert time.monotonic() - t0 >= 0.02
    finally:
        bell.close()
        bell.unlink()


@needs_futex
def test_ring_wakes_parked_waiter():
    bell = Doorbell("test_db_wake", create=True)
    woke = threading.Event()
    try:

        def park():
            bell.arm()
            try:
                # seq read BEFORE the wait: the protocol's ordering rule
                bell.wait(bell.read_seq(), timeout_s=5.0)
                woke.set()
            finally:
                bell.disarm()

        t = threading.Thread(target=park, daemon=True)
        t.start()
        time.sleep(0.05)  # let the waiter actually park
        bell.ring()
        assert woke.wait(timeout=2.0), "parked waiter never woke"
        t.join(timeout=2.0)
    finally:
        bell.close()
        bell.unlink()


def test_ring_without_waiters_skips_syscall():
    """waiters==0 => ring() is just the seq bump (the common case must not
    pay a futex syscall); the seq still advances so a late armer re-polls."""
    bell = Doorbell("test_db_nowaiters", create=True)
    try:
        before = bell.read_seq()
        for _ in range(3):
            bell.ring()
        assert bell.read_seq() == (before + 3) & 0xFFFFFFFF
    finally:
        bell.close()
        bell.unlink()


def test_ring_config_roundtrip():
    cfg = RingConfig(spin_budget=7, sleep_quantum=1e-5, park_timeout=1e-3,
                     use_doorbell=False)
    assert RingConfig.from_dict(cfg.as_dict()) == cfg
    # empty dict => defaults (old spawn specs without a "ring" key)
    assert RingConfig.from_dict(None) == RingConfig()


def test_bell_name_is_per_node():
    assert bell_name("p", 0) != bell_name("p", 1)
    assert bell_name("p", 3) == bell_name("p", 3)


# -- parked receive through the endpoint -------------------------------------


def test_parked_recv_sees_frame_sent_after_park():
    """In-process two-endpoint fabric, spin_budget=0: the receiver is
    parked in FUTEX_WAIT when the frame lands; the producer's ring must
    wake it well before the 10s recv deadline."""
    fab = ShmFabric(2, config=PARK_CFG)
    try:
        a, b = fab.endpoint(0), fab.endpoint(1)
        got = []

        def rx():
            got.append(b.recv(timeout=10.0))

        t = threading.Thread(target=rx, daemon=True)
        t.start()
        time.sleep(0.05)  # receiver reaches the parked state
        a.send(1, b"\x01" * 64)
        t.join(timeout=5.0)
        assert not t.is_alive()
        assert got and bytes(got[0]) == b"\x01" * 64
        a.close()
        b.close()
    finally:
        fab.close()


def test_parked_recv_deadline_still_honoured():
    """Parking must not stretch a recv timeout: with no producer, a 0.2s
    deadline expires in ~0.2s even though each park is 2ms."""
    fab = ShmFabric(2, config=PARK_CFG)
    try:
        b = fab.endpoint(1)
        t0 = time.monotonic()
        assert b.recv(timeout=0.2) is None
        dt = time.monotonic() - t0
        assert 0.15 <= dt < 2.0
        b.close()
    finally:
        fab.close()


@pytest.mark.fork
def test_forked_parked_receiver_rtt_regression():
    """Cross-process ping-pong with every receive forced through the park
    path.  A lost wakeup costs one park_timeout (2 ms); systematic losses
    would push the median RTT to ~4 ms.  The pre-doorbell spin+sleep loop
    on a single-core box measured ~8 ms RTT — the 4 ms median bound fails
    for both pathologies while staying safe on loaded CI runners."""
    import multiprocessing
    import statistics

    fab = ShmFabric(2, config=PARK_CFG)
    n = 100

    def echo(prefix, num_nodes):
        from repro.comm.shm import ShmEndpoint

        ep = ShmEndpoint(prefix, 1, num_nodes, peers=[0], config=PARK_CFG)
        try:
            for _ in range(n):
                frame = ep.recv(timeout=30.0)
                assert frame is not None
                ep.send(0, bytes(frame))
        finally:
            ep.close()

    proc = multiprocessing.get_context("fork").Process(
        target=echo, args=(fab.prefix, 2), daemon=True
    )
    proc.start()
    try:
        ep = fab.endpoint(0)
        rtts = []
        payload = b"\x5a" * 32
        for _ in range(n):
            t0 = time.perf_counter()
            ep.send(1, payload)
            reply = ep.recv(timeout=30.0)
            rtts.append(time.perf_counter() - t0)
            assert reply is not None and bytes(reply) == payload
        assert statistics.median(rtts) < 4e-3, (
            f"parked RTT median {statistics.median(rtts) * 1e6:.0f} us — "
            "doorbell wakeups are being lost (or park never wakes)"
        )
        ep.close()
    finally:
        from repro.offload.worker import reap

        reap([proc], timeout=10.0)
        fab.close()


@pytest.mark.fork
def test_no_lost_wakeups_under_bursty_producer():
    """Producer sends bursts separated by sleeps longer than the consumer's
    spin budget, so the consumer is parked at every burst arrival.  All
    frames must arrive well under the time lost-wakeup stalls would take
    (every burst eating a 2 ms park_timeout x 40 bursts = 80 ms floor;
    bound is far below drop-pathology territory)."""
    import multiprocessing

    fab = ShmFabric(2, config=PARK_CFG)
    bursts, per_burst = 40, 8

    def produce(prefix, num_nodes):
        from repro.comm.shm import ShmEndpoint

        ep = ShmEndpoint(prefix, 0, num_nodes, peers=[1], config=PARK_CFG)
        try:
            for i in range(bursts):
                ep.send_many(1, [bytes([i]) * 16] * per_burst)
                time.sleep(0.002)  # consumer parks between bursts
        finally:
            ep.close()

    proc = multiprocessing.get_context("fork").Process(
        target=produce, args=(fab.prefix, 2), daemon=True
    )
    proc.start()
    try:
        ep = fab.endpoint(1)
        got = 0
        deadline = time.monotonic() + 30.0
        while got < bursts * per_burst:
            assert time.monotonic() < deadline, f"stalled at frame {got}"
            frames = ep.recv_many(max_frames=64, timeout=5.0)
            got += len(frames)
            ep.release()
        assert got == bursts * per_burst
        ep.close()
    finally:
        from repro.offload.worker import reap

        reap([proc], timeout=10.0)
        fab.close()


# -- chaos: park/wake with delayed + reordered delivery ----------------------


@pytest.mark.chaos
def test_parked_receiver_survives_chaos_delay_reorder():
    """Delay faults re-send frames from a timer thread — the doorbell ring
    then happens while the receiver may be mid-park on a seq read before
    the original send.  Reorder shuffles batch order.  Every frame must
    still arrive exactly once with the receiver forced through the park
    path on every poll (no lost wakeups under out-of-band producers)."""
    from repro.comm.chaos import ChaosConfig, ChaosFabric

    inner = ShmFabric(2, config=PARK_CFG)
    chaos = ChaosFabric(inner, seed=11,
                        default=ChaosConfig(delay=0.3, reorder=0.3,
                                            delay_s=0.004))
    n = 120
    try:
        a, b = chaos.endpoint(0), chaos.endpoint(1)
        chaos.arm()
        got = []

        def rx():
            deadline = time.monotonic() + 30.0
            while len(got) < n and time.monotonic() < deadline:
                frame = b.recv(timeout=1.0)
                if frame is not None:
                    got.append(bytes(frame))

        t = threading.Thread(target=rx, daemon=True)
        t.start()
        for i in range(n):
            a.send(1, i.to_bytes(4, "little") * 8)
            if i % 16 == 0:
                time.sleep(0.003)  # let the receiver drain and re-park
        t.join(timeout=30.0)
        chaos.disarm()
        assert not t.is_alive()
        assert len(got) == n, f"got {len(got)}/{n} frames under chaos"
        # no duplication either: delay re-sends the SAME frame once
        assert sorted(got) == sorted(
            i.to_bytes(4, "little") * 8 for i in range(n)
        )
        a.close()
        b.close()
    finally:
        chaos.close()
