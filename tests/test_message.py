"""Frame/header codec invariants."""

import pytest
from _hypothesis_compat import given, settings, st

import repro.core as ham
from repro.core import message as msg


@settings(max_examples=100, deadline=None)
@given(
    key=st.integers(min_value=0, max_value=2**32 - 1),
    src=st.integers(min_value=0, max_value=2**32 - 1),
    msg_id=st.integers(min_value=0, max_value=2**64 - 1),
    flags=st.integers(min_value=0, max_value=7),
    payload=st.binary(max_size=256),
)
def test_frame_roundtrip(key, src, msg_id, flags, payload):
    frame = msg.encode_frame(key, payload, src_node=src, msg_id=msg_id,
                             flags=flags)
    header, view = msg.split_frame(frame)
    assert header.key == key
    assert header.src_node == src
    assert header.msg_id == msg_id
    assert header.flags == flags
    assert bytes(view) == payload


def test_bad_magic_rejected():
    frame = bytearray(msg.encode_frame(1, b"xy"))
    frame[0] ^= 0xFF
    with pytest.raises(ham.MessageFormatError):
        msg.decode_header(frame)


def test_truncated_frame_rejected():
    frame = msg.encode_frame(1, b"hello world")
    with pytest.raises(ham.MessageFormatError):
        msg.split_frame(frame[: msg.HEADER_NBYTES + 3])
    with pytest.raises(ham.MessageFormatError):
        msg.decode_header(frame[:10])


def test_decode_fast_rejects_truncated_payload():
    """Regression: decode_fast must bounds-check payload_len — a truncated
    frame used to yield a silently short memoryview."""
    frame = msg.encode_frame(1, b"hello world", msg_id=7)
    # intact frame decodes fine
    key, flags, src, msg_id, payload = msg.decode_fast(frame)
    assert (key, msg_id, bytes(payload)) == (1, 7, b"hello world")
    # frame cut mid-payload: must raise, not return a short view
    with pytest.raises(ham.MessageFormatError):
        msg.decode_fast(frame[: msg.HEADER_NBYTES + 4])
    with pytest.raises(ham.MessageFormatError):
        msg.decode_fast(bytes(frame)[: msg.HEADER_NBYTES + 4])
    # frame cut mid-header: must also raise cleanly
    with pytest.raises(ham.MessageFormatError):
        msg.decode_fast(frame[:10])


def test_flags_semantics():
    h = msg.Header(key=0, src_node=0, msg_id=1, payload_len=0,
                   flags=msg.FLAG_REPLY | msg.FLAG_ERROR)
    assert h.is_reply and h.is_error and not h.is_dynamic
