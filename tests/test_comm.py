"""Transport invariants: delivery, FIFO per pair, large frames — for every
backend (local threads / shm rings / loopback TCP)."""

import threading

import pytest

from repro.comm.local import LocalFabric
from repro.comm.shm import ShmFabric, ShmRing
from repro.comm.socket import SocketFabric
from repro.core.errors import CommError


@pytest.fixture(params=["local", "shm", "socket"])
def fabric(request):
    if request.param == "local":
        fab = LocalFabric(3)
    elif request.param == "shm":
        fab = ShmFabric(3, capacity=1 << 20)
    else:
        fab = SocketFabric(3)
    yield fab
    fab.close()


def test_point_to_point(fabric):
    a, b = fabric.endpoint(0), fabric.endpoint(1)
    a.send(1, b"hello")
    assert b.recv(timeout=5) == b"hello"


def test_fifo_per_pair(fabric):
    a, b = fabric.endpoint(0), fabric.endpoint(1)
    for i in range(100):
        a.send(1, bytes([i]))
    got = [b.recv(timeout=5)[0] for _ in range(100)]
    assert got == list(range(100))


def test_large_frame(fabric):
    a, b = fabric.endpoint(0), fabric.endpoint(2)
    blob = bytes(range(256)) * 2048  # 512 KB
    a.send(2, blob)
    assert b.recv(timeout=10) == blob


def test_recv_timeout(fabric):
    ep = fabric.endpoint(0)
    assert ep.recv(timeout=0.05) is None


def test_self_send_rejected(fabric):
    ep = fabric.endpoint(0)
    with pytest.raises(CommError):
        ep.send(0, b"loop")


def test_bidirectional(fabric):
    a, b = fabric.endpoint(0), fabric.endpoint(1)
    a.send(1, b"ping")
    assert b.recv(timeout=5) == b"ping"
    b.send(0, b"pong")
    assert a.recv(timeout=5) == b"pong"


def test_shm_ring_wraparound():
    ring = ShmRing("test_ring_wrap", capacity=1 << 12, create=True)
    try:
        reader = ShmRing("test_ring_wrap")
        # frames larger than half the ring force wrap-around handling
        for i in range(64):
            payload = bytes([i]) * 1500
            ring.push(payload, timeout=1.0)
            assert reader.try_pop() == payload
        reader.close()
    finally:
        ring.close()
        ring.unlink()


def test_shm_ring_full_detection():
    ring = ShmRing("test_ring_full", capacity=1 << 10, create=True)
    try:
        ring.push(b"x" * 900, timeout=0.1)
        with pytest.raises(CommError):
            ring.push(b"y" * 900, timeout=0.05)  # no consumer: must time out
    finally:
        ring.close()
        ring.unlink()


def test_shm_concurrent_producer_consumer():
    ring = ShmRing("test_ring_spsc", capacity=1 << 16, create=True)
    out = []

    def consume():
        reader = ShmRing("test_ring_spsc")
        while len(out) < 500:
            f = reader.try_pop()
            if f is not None:
                out.append(f)
        reader.close()

    t = threading.Thread(target=consume)
    t.start()
    try:
        for i in range(500):
            ring.push(i.to_bytes(4, "little") * 8)
        t.join(timeout=10)
        assert len(out) == 500
        assert out[0][:4] == (0).to_bytes(4, "little")
        assert out[-1][:4] == (499).to_bytes(4, "little")
    finally:
        ring.close()
        ring.unlink()
