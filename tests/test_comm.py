"""Transport invariants: delivery, FIFO per pair, large frames — for every
backend (local threads / shm rings / loopback TCP)."""

import threading

import pytest

from repro.comm.local import LocalFabric
from repro.comm.shm import ShmFabric, ShmRing
from repro.comm.socket import SocketFabric
from repro.core.errors import CommError


@pytest.fixture(params=["local", "shm", "socket"])
def fabric(request):
    if request.param == "local":
        fab = LocalFabric(3)
    elif request.param == "shm":
        fab = ShmFabric(3, capacity=1 << 20)
    else:
        fab = SocketFabric(3)
    yield fab
    fab.close()


def test_point_to_point(fabric):
    a, b = fabric.endpoint(0), fabric.endpoint(1)
    a.send(1, b"hello")
    assert b.recv(timeout=5) == b"hello"


def test_fifo_per_pair(fabric):
    a, b = fabric.endpoint(0), fabric.endpoint(1)
    for i in range(100):
        a.send(1, bytes([i]))
    got = [b.recv(timeout=5)[0] for _ in range(100)]
    assert got == list(range(100))


def test_large_frame(fabric):
    a, b = fabric.endpoint(0), fabric.endpoint(2)
    blob = bytes(range(256)) * 2048  # 512 KB
    a.send(2, blob)
    assert b.recv(timeout=10) == blob


def test_recv_timeout(fabric):
    ep = fabric.endpoint(0)
    assert ep.recv(timeout=0.05) is None


def test_self_send_rejected(fabric):
    ep = fabric.endpoint(0)
    with pytest.raises(CommError):
        ep.send(0, b"loop")


def test_bidirectional(fabric):
    a, b = fabric.endpoint(0), fabric.endpoint(1)
    a.send(1, b"ping")
    assert b.recv(timeout=5) == b"ping"
    b.send(0, b"pong")
    assert a.recv(timeout=5) == b"pong"


def test_shm_ring_wraparound():
    ring = ShmRing("test_ring_wrap", capacity=1 << 12, create=True)
    try:
        reader = ShmRing("test_ring_wrap")
        # frames larger than half the ring force wrap-around handling
        for i in range(64):
            payload = bytes([i]) * 1500
            ring.push(payload, timeout=1.0)
            assert reader.try_pop() == payload
        reader.close()
    finally:
        ring.close()
        ring.unlink()


def test_shm_ring_full_detection():
    ring = ShmRing("test_ring_full", capacity=1 << 10, create=True)
    try:
        ring.push(b"x" * 900, timeout=0.1)
        with pytest.raises(CommError):
            ring.push(b"y" * 900, timeout=0.05)  # no consumer: must time out
    finally:
        ring.close()
        ring.unlink()


# -- zero-copy lease protocol -------------------------------------------------


def test_shm_pop_view_aliases_ring_buffer():
    """The leased payload view must BE ring memory — no per-frame copy."""
    ring = ShmRing("test_ring_alias", capacity=1 << 12, create=True)
    try:
        reader = ShmRing("test_ring_alias")
        ring.push(b"\xaa" * 32)
        lease = reader.try_pop_view()
        assert bytes(lease.view) == b"\xaa" * 32
        # mutate the shared segment underneath the view: an aliasing view
        # observes the store, a copied frame cannot
        from repro.comm.shm import _HDR

        off = reader._tail() + 8  # frame data begins after the u64 length
        reader._buf[_HDR + off] = 0x55
        assert lease.view[0] == 0x55
        lease.release()
        assert reader._tail() == reader._head()
        del lease
        reader.close()
    finally:
        ring.close()
        ring.unlink()


def test_shm_zero_copy_wraparound_and_input_types():
    """Zero-copy push accepts bytes/bytearray/memoryview; frames straddling
    the wrap boundary still roundtrip (reassembled into a scratch copy)."""
    ring = ShmRing("test_ring_zcwrap", capacity=1 << 12, create=True)
    try:
        reader = ShmRing("test_ring_zcwrap")
        for i in range(64):
            payload = bytes([i]) * 1500  # >1/3 ring: forces wrap handling
            src = (payload, bytearray(payload), memoryview(payload))[i % 3]
            ring.push(src, timeout=1.0)
            lease = reader.try_pop_view()
            assert lease is not None
            assert bytes(lease.view) == payload
            lease.release()
            del lease
        reader.close()
    finally:
        ring.close()
        ring.unlink()


def test_shm_lease_backpressure():
    """Ring space is only reclaimed on release — an unreleased lease keeps
    the producer blocked even though the frame was consumed."""
    ring = ShmRing("test_ring_bp", capacity=1 << 10, create=True)
    try:
        reader = ShmRing("test_ring_bp")
        ring.push(b"x" * 900, timeout=0.1)
        lease = reader.try_pop_view()
        assert lease is not None
        with pytest.raises(CommError):  # popped but NOT released: still full
            ring.push(b"y" * 900, timeout=0.05)
        lease.release()
        ring.push(b"y" * 900, timeout=0.5)  # space reclaimed
        lease2 = reader.try_pop_view()
        assert bytes(lease2.view) == b"y" * 900
        lease2.release()
        del lease, lease2
        reader.close()
    finally:
        ring.close()
        ring.unlink()


def test_shm_lease_out_of_order_release_rejected():
    ring = ShmRing("test_ring_ooo", capacity=1 << 12, create=True)
    try:
        reader = ShmRing("test_ring_ooo")
        ring.push(b"first")
        ring.push(b"second")
        a = reader.try_pop_view()
        b = reader.try_pop_view()
        with pytest.raises(CommError):
            b.release()  # younger lease first: rejected
        a.release()
        b.release()  # now in order
        with pytest.raises(CommError):
            b.release()  # double release
        del a, b
        reader.close()
    finally:
        ring.close()
        ring.unlink()


def test_shm_push_many_pop_many_batch():
    """N frames move under one head store / one lease (one tail store)."""
    ring = ShmRing("test_ring_batch", capacity=1 << 14, create=True)
    try:
        reader = ShmRing("test_ring_batch")
        frames = [bytes([i]) * (i + 1) for i in range(50)]
        ring.push_many(frames, timeout=1.0)
        lease = reader.pop_many(max_frames=64)
        assert [bytes(v) for v in lease.views] == frames
        assert reader._tail() == 0  # nothing reclaimed until release
        lease.release()
        assert reader._tail() == reader._head()
        # batches larger than the ring are split transparently
        big = [b"z" * 3000 for _ in range(12)]  # 12*3008 > 16 KiB ring
        got = []

        def consume():
            r2 = ShmRing("test_ring_batch")
            while len(got) < 12:
                ls = r2.pop_many()
                if ls is not None:
                    got.extend(bytes(v) for v in ls.views)
                    ls.release()
            ls = None  # drop the last views before unmapping
            r2.close()

        t = threading.Thread(target=consume)
        t.start()
        ring.push_many(big, timeout=5.0)
        t.join(timeout=10)
        assert got == big
        del lease
        reader.close()
    finally:
        ring.close()
        ring.unlink()


def test_send_many_recv_many_roundtrip(fabric):
    """Coalesced batch API delivers the same frames, in order, per pair —
    on every backend (native batching on shm/socket, loop on local)."""
    a, b = fabric.endpoint(0), fabric.endpoint(1)
    frames = [bytes([i % 256]) * (1 + i % 97) for i in range(300)]
    a.send_many(1, frames)
    got = []
    deadline = 300
    while len(got) < len(frames) and deadline:
        batch = b.recv_many(max_frames=64, timeout=5)
        got.extend(bytes(f) for f in batch)
        batch = None  # leased views must not outlive the fabric
        b.release()
        deadline -= 1
    assert got == frames


def test_shm_nested_pop_with_outstanding_lease():
    """A copying try_pop while a lease is outstanding (the handler-recursing-
    into-recv case) must not corrupt FIFO order or the tail counter."""
    ring = ShmRing("test_ring_nested", capacity=1 << 12, create=True)
    try:
        reader = ShmRing("test_ring_nested")
        ring.push(b"leased")
        ring.push(b"copied")
        ring.push(b"after")
        lease = reader.try_pop_view()
        assert bytes(lease.view) == b"leased"
        assert reader.try_pop() == b"copied"  # deferred behind the lease
        assert reader._tail() == 0  # nothing reclaimed yet
        lease.release()
        assert reader.try_pop() == b"after"
        assert reader._tail() == reader._head()
        del lease
        reader.close()
    finally:
        ring.close()
        ring.unlink()


@pytest.mark.fork
def test_shm_cross_process_wrap_heavy_frames():
    """Regression: true cross-process traffic with frames near half the ring
    (constant wrap + counter churn) must never desync the consumer's frame
    walk.  CPython can tear 8-byte counter stores on shared memory; the ring
    publishes each counter twice and readers require a stable pair."""
    import multiprocessing

    cap = 1 << 20
    ring = ShmRing("test_ring_xproc", capacity=cap, create=True)

    def produce():
        w = ShmRing("test_ring_xproc")
        payload = bytes(range(256)) * 1800  # ~460KB: wraps almost every frame
        for i in range(40):
            w.push_many([bytes([i]) + payload])
        w.close()

    p = multiprocessing.get_context("fork").Process(target=produce)
    p.start()
    try:
        got = 0
        expect_payload = bytes(range(256)) * 1800
        import time as _t

        deadline = _t.monotonic() + 30
        while got < 40:
            assert _t.monotonic() < deadline, f"stalled at frame {got}"
            lease = ring.pop_many(8)
            if lease is None:
                continue
            for v in lease.views:
                assert v.nbytes == 1 + len(expect_payload)
                assert v[0] == got
                assert bytes(v[1:]) == expect_payload
                got += 1
            lease.release()
        p.join(timeout=10)
        assert p.exitcode == 0
    finally:
        if p.is_alive():
            p.terminate()
        ring.close()
        ring.unlink()


def test_shm_concurrent_producer_consumer():
    ring = ShmRing("test_ring_spsc", capacity=1 << 16, create=True)
    out = []

    def consume():
        reader = ShmRing("test_ring_spsc")
        while len(out) < 500:
            f = reader.try_pop()
            if f is not None:
                out.append(f)
        reader.close()

    t = threading.Thread(target=consume)
    t.start()
    try:
        for i in range(500):
            ring.push(i.to_bytes(4, "little") * 8)
        t.join(timeout=10)
        assert len(out) == 500
        assert out[0][:4] == (0).to_bytes(4, "little")
        assert out[-1][:4] == (499).to_bytes(4, "little")
    finally:
        ring.close()
        ring.unlink()
