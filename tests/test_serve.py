"""Serving engine: device-table dispatch, continuous batching, consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.device_table import DeviceHandlerTable
from repro.core.errors import RegistryError
from repro.models.api import build_model
from repro.serve.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_reduced("llama3-405b")
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def test_device_table_keys_sorted_and_stable():
    t = DeviceHandlerTable()
    t.register("z", lambda x: x)
    t.register("a", lambda x: x + 1)
    t.register("m", lambda x: x * 2)
    assert [h.stable_name for h in t.handlers] == ["a", "m", "z"]
    assert t.key_of("a") == 0 and t.key_of("z") == 2


def test_device_table_rejects_mismatched_results():
    t = DeviceHandlerTable()
    t.register("a", lambda x: x)
    t.register("b", lambda x: (x, x))  # different result structure
    with pytest.raises(RegistryError):
        t.validate(jax.ShapeDtypeStruct((4,), jnp.float32))


def test_device_table_dispatch_selects_branch():
    t = DeviceHandlerTable()
    t.register("id", lambda x: x)
    t.register("neg", lambda x: -x)
    d = t.build(jax.ShapeDtypeStruct((3,), jnp.float32))
    x = jnp.arange(3.0)
    np.testing.assert_array_equal(d(jnp.int32(t.key_of("id")), x), x)
    np.testing.assert_array_equal(d(jnp.int32(t.key_of("neg")), x), -x)


def test_engine_greedy_matches_manual_decode(model_and_params):
    model, params = model_and_params
    cfg = model.cfg
    prompt = np.arange(6) % cfg.vocab_size
    eng = ServingEngine(model, params, num_slots=1, max_len=32)
    out = eng.run([Request(prompt=prompt, max_new_tokens=5)])
    # manual: prefill + greedy loop
    logits, cache0 = model.prefill(params, {"tokens": jnp.asarray(prompt[None])})
    cache = model.init_cache(1, 32)
    cache = jax.tree_util.tree_map(
        lambda full, part: jax.lax.dynamic_update_slice(
            full, part.astype(full.dtype), (0,) * full.ndim),
        cache, cache0)
    tok = int(jnp.argmax(logits[0, -1]))
    manual = [tok]
    pos = len(prompt)
    for _ in range(4):
        lg, cache = model.decode_step(
            params, cache,
            {"tokens": jnp.asarray([[tok]], jnp.int32),
             "pos": jnp.asarray([pos], jnp.int32)})
        tok = int(jnp.argmax(lg[0, -1]))
        manual.append(tok)
        pos += 1
    assert out[0] == manual


def test_engine_continuous_batching_mixed_lengths(model_and_params):
    model, params = model_and_params
    cfg = model.cfg
    reqs = [
        Request(prompt=np.arange(4) % cfg.vocab_size, max_new_tokens=3),
        Request(prompt=np.arange(9) % cfg.vocab_size, max_new_tokens=6),
        Request(prompt=np.arange(2) % cfg.vocab_size, max_new_tokens=4),
        Request(prompt=np.arange(5) % cfg.vocab_size, max_new_tokens=2),
    ]
    eng = ServingEngine(model, params, num_slots=2, max_len=32)
    out = eng.run(reqs)
    assert sorted(out) == [0, 1, 2, 3]
    for i, r in enumerate(reqs):
        assert len(out[i]) == r.max_new_tokens
    # continuous batching admits late requests into freed slots: the total
    # dispatched steps must be < sum of per-request lengths (batched)
    assert eng.steps_dispatched < sum(r.max_new_tokens for r in reqs)


def test_engine_isolation_between_slots(model_and_params):
    """A request's output must not depend on what shares the batch."""
    model, params = model_and_params
    cfg = model.cfg
    p = np.arange(5) % cfg.vocab_size
    solo = ServingEngine(model, params, num_slots=1, max_len=32).run(
        [Request(prompt=p, max_new_tokens=4)])[0]
    other = np.arange(7)[::-1] % cfg.vocab_size
    mixed = ServingEngine(model, params, num_slots=2, max_len=32).run(
        [Request(prompt=p, max_new_tokens=4),
         Request(prompt=other, max_new_tokens=4)])[0]
    assert solo == mixed


def test_engine_sampling_temperature(model_and_params):
    model, params = model_and_params
    cfg = model.cfg
    p = np.arange(5) % cfg.vocab_size
    eng = ServingEngine(model, params, num_slots=1, max_len=32, seed=7)
    out = eng.run([Request(prompt=p, max_new_tokens=8, temperature=1.5)])
    assert len(out[0]) == 8
    assert all(0 <= t < cfg.vocab_size for t in out[0])


@pytest.mark.slow
def test_cluster_serving_matches_single_engine_lengths(model_and_params):
    """Continuous batching through the worker pool: same requests, same
    output lengths as the single engine, decode steps overlapping across
    two workers."""
    from repro.serve.engine import ClusterServingEngine

    model, params = model_and_params
    cfg = model.cfg
    mk = lambda: [  # noqa: E731 — fresh Request objects per engine (rids mutate)
        Request(prompt=np.arange(3 + i % 3) % cfg.vocab_size,
                max_new_tokens=2 + i % 3)
        for i in range(6)
    ]
    eng = ClusterServingEngine(model, params, num_workers=2,
                               slots_per_worker=2, max_len=24)
    try:
        out = eng.run(mk())
    finally:
        eng.close()
    ref = ServingEngine(model, params, num_slots=2, max_len=24).run(mk())
    assert sorted(out) == sorted(ref)
    assert {r: len(v) for r, v in out.items()} == {
        r: len(v) for r, v in ref.items()
    }
    # both workers actually served traffic
    assert all(n > 0 for n in eng.sched.stats["routed"].values())


@pytest.mark.slow
def test_cluster_serving_survives_resize(model_and_params):
    """Serving elasticity (ROADMAP): engine replicas follow pool membership
    — a node added mid-life takes admissions, a drained removal retires its
    replica, and serving continues across both."""
    from repro.serve.engine import ClusterServingEngine

    model, params = model_and_params
    cfg = model.cfg
    eng = ClusterServingEngine(model, params, num_workers=1,
                               slots_per_worker=2, max_len=24)
    try:
        assert eng.serving_nodes() == [1]
        new = eng.pool.add_node()
        assert new in eng.serving_nodes()  # replica created on join
        reqs = [
            Request(prompt=np.arange(3 + i % 3) % cfg.vocab_size,
                    max_new_tokens=3)
            for i in range(6)
        ]
        out = eng.run(reqs)
        assert {r: len(v) for r, v in out.items()} == {
            i: 3 for i in range(6)
        }
        assert eng.sched.stats["routed"].get(new, 0) > 0  # newcomer served
        eng.pool.remove_node(new, drain=True)
        assert eng.serving_nodes() == [1]  # replica retired with the node
        out2 = eng.run([
            Request(prompt=np.arange(4) % cfg.vocab_size, max_new_tokens=2)
        ])
        assert len(out2[0]) == 2  # serving survived the shrink
    finally:
        eng.close()


@pytest.mark.slow
def test_cluster_serving_recovers_requests_from_dead_worker(model_and_params):
    """Session recovery: kill a serving worker mid-decode; its requests
    re-admit on the survivor from the host-held transcript (prompt +
    tokens so far) and every request still reaches full length."""
    import threading
    import time

    from repro.serve.engine import ClusterServingEngine

    model, params = model_and_params
    cfg = model.cfg
    eng = ClusterServingEngine(model, params, num_workers=2,
                               slots_per_worker=2, max_len=48)
    killed = {}

    def killer():
        deadline = time.time() + 60
        while time.time() < deadline:
            if eng.sched.stats["completed"] >= 6:  # mid-run, decode going
                victim = eng.serving_nodes()[0]
                eng.pool.kill(victim)
                killed["node"] = victim
                return
            time.sleep(0.005)

    t = threading.Thread(target=killer)
    t.start()
    try:
        reqs = [
            Request(prompt=np.arange(3 + i % 3) % cfg.vocab_size,
                    max_new_tokens=10)
            for i in range(6)
        ]
        out = eng.run(reqs, timeout=120)
    finally:
        t.join()
        eng.close()
    assert "node" in killed, "the kill must land mid-run"
    assert sorted(out) == list(range(6))
    assert {r: len(v) for r, v in out.items()} == {i: 10 for i in range(6)}


def test_noop_branch_preserves_state(model_and_params):
    model, params = model_and_params
    eng = ServingEngine(model, params, num_slots=1, max_len=16)
    before = jax.tree_util.tree_map(lambda a: np.asarray(a).copy(),
                                    eng.payload)
    eng.step(key=eng.key_noop)
    for a, b in zip(jax.tree_util.tree_leaves(before),
                    jax.tree_util.tree_leaves(eng.payload)):
        if a.dtype == np.uint32:  # rng key unchanged by noop too
            pass
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
