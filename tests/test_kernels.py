"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU): shape/dtype
sweeps + hypothesis-driven shapes, assert_allclose per kernel."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.grouped_matmul import grouped_matmul
from repro.kernels.mlstm import mlstm_chunked_kernel
from repro.models.mamba2 import ssd_recurrent
from repro.models.xlstm import mlstm_recurrent

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("BH,BKV,S,d,causal", [
    (4, 4, 128, 64, True),
    (8, 2, 256, 64, True),
    (4, 4, 128, 128, False),
    (6, 3, 192, 32, True),
])
def test_flash_attention_sweep(BH, BKV, S, d, causal, dtype):
    qpk = BH // BKV
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (BH, S, d), dtype)
    k = jax.random.normal(ks[1], (BKV, S, d), dtype)
    v = jax.random.normal(ks[2], (BKV, S, d), dtype)
    out = flash_attention(q, k, v, causal=causal, q_per_kv=qpk,
                          block_q=64, block_k=64, interpret=True)
    expected = ref.attention_ref(q, k, v, causal=causal, q_per_kv=qpk)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expected, np.float32),
        atol=ATOL[dtype], rtol=1e-2,
    )


@pytest.mark.parametrize("B,Hkv,qpk,S,d", [
    (2, 2, 4, 256, 64), (3, 1, 8, 128, 128), (2, 4, 1, 192, 64),
])
def test_decode_attention_sweep(B, Hkv, qpk, S, d):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (B, Hkv, qpk, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, S, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, S, d), jnp.float32)
    lengths = jax.random.randint(ks[3], (B,), 1, S + 1)
    out = decode_attention(q, k, v, lengths, block_k=64, interpret=True)
    expected = ref.decode_attention_ref(
        q.reshape(B, Hkv * qpk, d), k, v, lengths, q_per_kv=qpk
    ).reshape(B, Hkv, qpk, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=2e-5, rtol=1e-3)


@settings(max_examples=10, deadline=None)
@given(
    BH=st.integers(1, 4), nc=st.integers(1, 4),
    chunk=st.sampled_from([8, 16]), dk=st.sampled_from([8, 16]),
    dv=st.sampled_from([8, 32]),
)
def test_mlstm_kernel_vs_recurrence(BH, nc, chunk, dk, dv):
    S = nc * chunk
    ks = jax.random.split(jax.random.PRNGKey(BH * 100 + S), 5)
    q = jax.random.normal(ks[0], (BH, S, dk))
    k = jax.random.normal(ks[1], (BH, S, dk))
    v = jax.random.normal(ks[2], (BH, S, dv))
    i_pre = jax.random.normal(ks[3], (BH, S))
    f_pre = jax.random.normal(ks[4], (BH, S)) + 2.0
    h, (C, n, m) = mlstm_chunked_kernel(q, k, v, i_pre, f_pre, chunk=chunk,
                                        interpret=True)
    hr, (Cr, nr, mr) = mlstm_recurrent(
        q[:, :, None], k[:, :, None], v[:, :, None],
        i_pre[:, :, None], f_pre[:, :, None],
    )
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr[:, :, 0]),
                               atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(C), np.asarray(Cr[:, 0]),
                               atol=5e-3, rtol=1e-2)


def test_ssd_kernel_vs_recurrence():
    B, S, H, P, G, N = 2, 64, 4, 16, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(2), 6)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    D = jnp.ones((H,))
    y, h = ops.ssd_chunked(x, dt, A, Bm, Cm, D, chunk=16, interpret=True)
    yr, hr = ssd_recurrent(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-4,
                               rtol=1e-3)


@settings(max_examples=10, deadline=None)
@given(
    E=st.integers(1, 4),
    C=st.sampled_from([16, 48]),
    d=st.sampled_from([32, 64]),
    f=st.sampled_from([16, 64]),
)
def test_grouped_matmul_hypothesis(E, C, d, f):
    ks = jax.random.split(jax.random.PRNGKey(E * 7 + C), 2)
    x = jax.random.normal(ks[0], (E, C, d), jnp.float32)
    w = jax.random.normal(ks[1], (E, d, f), jnp.float32)
    out = grouped_matmul(x, w, block_c=16, block_f=16, block_d=16,
                         interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.grouped_matmul_ref(x, w)),
                               atol=2e-3, rtol=1e-3)


def test_flash_matches_model_attention_path():
    """The kernel and the model's XLA reference compute the same math."""
    from repro.models import layers as L

    B, S, H, Hkv, hd = 2, 64, 8, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))
    out_kernel = ops.flash_attention_bhsd(q, k, v, causal=True, interpret=True)
    mask = L.causal_mask(S, S)
    out_model = L.gqa_scores_softmax_value(q, k, v, mask, q_per_kv=H // Hkv)
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_model),
                               atol=2e-5, rtol=1e-3)
