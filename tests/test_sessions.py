"""SessionRouter: rendezvous-hash invariants + the stickiness contract.

The properties asserted here are the module-level contract of
``repro.cluster.sessions`` — minimal remap on grow, exact restore on
shrink-back, pins that survive unrelated membership changes and re-place
only on their own worker's departure.
"""

from repro.cluster.sessions import SessionRouter, rendezvous_hash

KEYS = [f"session-{i}" for i in range(400)]


def test_hrw_deterministic_and_total():
    nodes = [1, 2, 3]
    first = {k: rendezvous_hash(k, nodes) for k in KEYS}
    again = {k: rendezvous_hash(k, nodes) for k in KEYS}
    assert first == again  # stable hash, not Python's salted hash()
    assert set(first.values()) == {1, 2, 3}  # every node gets keys
    counts = [sum(1 for v in first.values() if v == n) for n in nodes]
    assert min(counts) > len(KEYS) // 10  # roughly balanced


def test_hrw_grow_remaps_only_fair_share():
    before = {k: rendezvous_hash(k, [1, 2, 3]) for k in KEYS}
    after = {k: rendezvous_hash(k, [1, 2, 3, 4]) for k in KEYS}
    moved = [k for k in KEYS if before[k] != after[k]]
    # every moved key moved TO the new node — nothing reshuffles between
    # survivors (the rendezvous property elastic resize relies on)
    assert all(after[k] == 4 for k in moved)
    # and the moved share is about 1/4
    assert 0.10 < len(moved) / len(KEYS) < 0.45


def test_hrw_shrink_restores_exactly():
    before = {k: rendezvous_hash(k, [1, 2, 3]) for k in KEYS}
    grown = {k: rendezvous_hash(k, [1, 2, 3, 4]) for k in KEYS}
    shrunk = {k: rendezvous_hash(k, [1, 2, 3]) for k in KEYS}
    assert shrunk == before
    # keys that never moved to 4 keep the same owner through the resize
    assert all(grown[k] == before[k] for k in KEYS if grown[k] != 4)


def test_router_pins_stick_across_unrelated_resize():
    live = {1, 2, 3}
    router = SessionRouter(lambda: sorted(live))
    placement = {k: router.route(k) for k in KEYS[:50]}
    live.add(4)  # grow: pinned sessions must NOT move (HRW alone would
    #              remap ~1/4 of them — the pin table is the stickiness)
    assert {k: router.route(k) for k in KEYS[:50]} == placement
    live.discard(4)  # unrelated shrink: still pinned
    assert {k: router.route(k) for k in KEYS[:50]} == placement
    assert router.stats["replaced"] == 0


def test_router_replaces_only_on_own_worker_departure():
    live = {1, 2, 3}
    router = SessionRouter(lambda: sorted(live))
    placement = {k: router.route(k) for k in KEYS[:60]}
    victims = [k for k, n in placement.items() if n == 2]
    assert victims  # statistical certainty over 60 keys
    live.discard(2)
    replaced = {k: router.route(k) for k in KEYS[:60]}
    for k, n in replaced.items():
        if k in victims:
            assert n in {1, 3}  # re-placed among survivors...
        else:
            assert n == placement[k]  # ...everyone else untouched
    assert router.stats["replaced"] == len(victims)
    # the re-placement is itself sticky
    assert {k: router.route(k) for k in KEYS[:60]} == replaced


def test_router_eligible_limits_fresh_placements_not_pins():
    live = {1, 2, 3}
    router = SessionRouter(lambda: sorted(live))
    node = router.route("a", eligible=[2])
    assert node == 2  # fresh placement constrained to the eligible set
    # a live pin wins even when excluded from eligibility: stickiness first
    assert router.route("a", eligible=[1, 3]) == 2


def test_router_evict_and_end_session():
    live = {1, 2}
    router = SessionRouter(lambda: sorted(live))
    for k in KEYS[:20]:
        router.route(k)
    on_1 = router.sessions_on(1)
    assert sorted(router.evict_node(1)) == sorted(on_1)
    assert router.sessions_on(1) == []
    router.end_session(KEYS[0])
    assert router.lookup(KEYS[0]) is None


def test_router_no_live_nodes_returns_none():
    router = SessionRouter(lambda: [])
    assert router.route("x") is None
