"""Cluster pool + scheduler: routing policies, credit flow control,
pipelined completions, worker death/restart, shm segment hygiene."""

import os
import time

import numpy as np
import pytest

import repro.cluster.pool  # noqa: F401 — registers _cluster/* at collection,
#                            before any test seals the default registry
from repro.cluster import ClusterPool, Scheduler, as_completed, gather
from repro.cluster.pool import register_cluster_handlers
from repro.core.closure import f2f
from repro.core.errors import (
    NodeDownError,
    OffloadError,
    RemoteExecutionError,
)
from repro.core.registry import HandlerRegistry, default_registry
from repro.offload.runtime import register_internal_handlers


def _registry():
    reg = HandlerRegistry()
    register_internal_handlers(reg)
    register_cluster_handlers(reg)
    reg.init()
    return reg


@pytest.fixture
def pool():
    p = ClusterPool.local(3, registry=_registry())
    yield p
    p.close()


def _sleep(reg, seconds):
    return f2f("_cluster/sleep", seconds, registry=reg)


def _spin(reg, n=10):
    return f2f("_cluster/spin", n, registry=reg)


# -- routing policies --------------------------------------------------------


def test_round_robin_spreads_evenly(pool):
    sched = Scheduler(pool, policy="round_robin")
    futs = [sched.submit(_spin(pool.domain.registry)) for _ in range(9)]
    assert gather(futs, 30) == [45] * 9
    assert sorted(sched.stats["routed"].values()) == [3, 3, 3]


def test_least_outstanding_avoids_busy_worker(pool):
    reg = pool.domain.registry
    sched = Scheduler(pool, policy="least_outstanding", max_inflight=8)
    # pile outstanding calls on node 1, then policy-route a burst: node 1's
    # queue depth (3) always exceeds any transient depth on nodes 2/3 (<=1
    # spin in flight each), so the burst must avoid it
    busy = [sched.submit(_sleep(reg, 0.5), node=1) for _ in range(3)]
    futs = [sched.submit(_spin(reg)) for _ in range(6)]
    gather(futs, 30)
    gather(busy, 10)
    assert sched.stats["routed"][1] == 3  # the pinned calls only
    assert sched.stats["routed"][2] + sched.stats["routed"][3] == 6


def test_locality_routes_to_buffer_owner(pool):
    reg = pool.domain.registry
    sched = Scheduler(pool, policy="locality")
    dom = pool.domain
    arr = np.arange(16.0)
    for target in (1, 2, 3):
        ptr = dom.allocate(target, arr.shape, "float64")
        dom.put(arr, ptr)
        fut = sched.submit(f2f("_cluster/touch", ptr, registry=reg))
        assert fut.get(10) == arr.sum()
    # every call ran on its buffer's owner — a remote deref would have
    # raised (pointers are only valid in their own address space)
    assert sched.stats["routed"] == {1: 1, 2: 1, 3: 1}
    assert sched.stats["locality_hits"] == 3


def test_locality_falls_back_without_votes(pool):
    sched = Scheduler(pool, policy="locality")
    assert sched.submit(_spin(pool.domain.registry)).get(10) == 45
    assert sched.stats["locality_hits"] == 0


# -- pipelining --------------------------------------------------------------


def test_as_completed_yields_in_completion_order(pool):
    reg = pool.domain.registry
    sched = Scheduler(pool, max_inflight=4)
    slow = sched.submit(_sleep(reg, 0.4), node=1)
    fast = [sched.submit(_sleep(reg, 0.01), node=2) for _ in range(3)]
    order = list(as_completed([slow, *fast], timeout=30))
    assert order[-1] is slow  # the slow call finishes last
    assert set(order) == {slow, *fast}


def test_pipelined_submits_overlap_across_workers(pool):
    """The acceptance property at test scale: many in-flight sleeps across
    3 workers must beat the serial round-trip floor by ~worker count."""
    reg = pool.domain.registry
    sched = Scheduler(pool, max_inflight=16)
    n, per_call = 30, 0.02
    t0 = time.perf_counter()
    gather([sched.submit(_sleep(reg, per_call)) for _ in range(n)], 60)
    dt = time.perf_counter() - t0
    assert dt < n * per_call * 0.75  # strictly better than serial execution


def test_gather_orders_by_submission(pool):
    reg = pool.domain.registry
    sched = Scheduler(pool)
    futs = [sched.submit(f2f("_cluster/spin", i, registry=reg))
            for i in (3, 5, 7)]
    assert gather(futs, 30) == [3, 10, 21]


# -- credit-based flow control ----------------------------------------------


def test_backpressure_blocks_then_raises(pool):
    reg = pool.domain.registry
    sched = Scheduler(pool, max_inflight=2, submit_timeout=0.3)
    held = [sched.submit(_sleep(reg, 0.8), node=1) for _ in range(2)]
    t0 = time.perf_counter()
    with pytest.raises(OffloadError, match="backpressure"):
        sched.submit(_sleep(reg, 0.8), node=1)  # no credit on node 1
    assert 0.25 < time.perf_counter() - t0 < 2.0  # blocked, then gave up
    gather(held, 30)
    # credits returned on completion: the same pinned submit works now
    assert sched.submit(_sleep(reg, 0.01), node=1).get(10) == 0.01


def test_policy_routes_around_saturated_worker(pool):
    reg = pool.domain.registry
    sched = Scheduler(pool, max_inflight=1, submit_timeout=5.0)
    blocker = sched.submit(_sleep(reg, 0.5), node=1)
    t0 = time.perf_counter()
    futs = [sched.submit(_spin(reg)) for _ in range(4)]
    gather(futs, 30)
    # the burst never waited on node 1's credit
    assert time.perf_counter() - t0 < 0.45
    assert sched.stats["routed"][1] == 1  # only the blocker
    blocker.get(10)


# -- worker failure (thread pool) -------------------------------------------


def test_thread_worker_death_fails_queued_calls_and_reroutes(pool):
    reg = pool.domain.registry
    sched = Scheduler(pool, max_inflight=8)
    # occupy node 1 (let its loop start executing the sleep), then queue
    # more work behind it
    running = sched.submit(_sleep(reg, 0.3), node=1)
    time.sleep(0.1)
    queued = [sched.submit(_spin(reg), node=1) for _ in range(3)]
    pool.kill(1)  # stops the event loop: queued frames are never drained
    deadline = time.time() + 10
    while 1 in sched.live_nodes() and time.time() < deadline:
        time.sleep(0.02)
    assert sched.live_nodes() == [2, 3]
    for f in queued:
        with pytest.raises(RemoteExecutionError, match="died"):
            f.get(10)
    assert sched.stats["failed_inflight"] >= 3
    # policy traffic reroutes to the survivors
    assert sched.submit(_spin(reg)).get(10) == 45
    with pytest.raises(NodeDownError):
        sched.submit(_spin(reg), node=1)
    del running  # may have completed or failed depending on drain timing

    pool.restart(1)
    deadline = time.time() + 10
    while 1 not in sched.live_nodes() and time.time() < deadline:
        time.sleep(0.02)
    assert sched.live_nodes() == [1, 2, 3]
    assert sched.submit(_spin(reg), node=1).get(10) == 45


# -- worker failure (forked processes over shm) ------------------------------


def _default_registry_ready():
    reg = default_registry()
    register_cluster_handlers(reg)  # no-op if already present/sealed
    if not reg.initialised:
        reg.init()
    return reg


@pytest.mark.fork
def test_fork_worker_killed_mid_stream_fails_inflight_and_reroutes():
    """The PR's failure-semantics contract, against a REAL process death:
    kill one forked worker while its calls are in flight; the scheduler
    must mark it dead, fail those futures with RemoteExecutionError, and
    route subsequent calls to the survivor."""
    reg = _default_registry_ready()
    pool = ClusterPool.shm(2, registry=reg)
    try:
        sched = Scheduler(pool, policy="round_robin", max_inflight=8)
        pool.ping_all()
        inflight = [sched.submit(_sleep(reg, 3.0), node=1) for _ in range(3)]
        time.sleep(0.2)  # let the worker start executing
        pool.kill(1)
        deadline = time.time() + 10
        while 1 in sched.live_nodes() and time.time() < deadline:
            time.sleep(0.05)
        assert sched.live_nodes() == [2], "scheduler must mark the corpse dead"
        for f in inflight:
            with pytest.raises(RemoteExecutionError, match="died"):
                f.get(10)
        assert sched.stats["failed_inflight"] == 3
        results = gather([sched.submit(_spin(reg)) for _ in range(4)], 30)
        assert results == [45] * 4
        assert sched.stats["routed"][2] >= 4  # everything rerouted
    finally:
        pool.close()


@pytest.mark.fork
def test_fork_worker_restart_rejoins_pool():
    reg = _default_registry_ready()
    pool = ClusterPool.shm(2, registry=reg)
    try:
        sched = Scheduler(pool, max_inflight=4)
        pool.ping_all()
        pool.kill(1)
        deadline = time.time() + 10
        while 1 in sched.live_nodes() and time.time() < deadline:
            time.sleep(0.05)
        pool.restart(1)
        deadline = time.time() + 10
        while 1 not in sched.live_nodes() and time.time() < deadline:
            time.sleep(0.05)
        assert sched.live_nodes() == [1, 2]
        assert sched.submit(_spin(reg), node=1).get(20) == 45
    finally:
        pool.close()


@pytest.mark.fork
def test_shm_segments_unlinked_even_when_child_dies():
    """The segment-leak satellite: a child killed mid-run must not leave
    its fabric's segments in /dev/shm after ClusterPool.close()."""
    reg = _default_registry_ready()
    pool = ClusterPool.shm(2, registry=reg)
    prefix = pool.fabric.prefix
    pool.ping_all()
    assert any(f.startswith(prefix) for f in os.listdir("/dev/shm"))
    pool.kill(1)
    time.sleep(0.3)
    pool.close()
    assert not any(f.startswith(prefix) for f in os.listdir("/dev/shm"))
    # close() reaped the children too
    for handle in pool._workers.values():
        assert not handle.alive()


# -- misc --------------------------------------------------------------------


def test_no_live_workers_raises(pool):
    sched = Scheduler(pool, max_inflight=2)
    for n in pool.worker_nodes:
        pool.kill(n)
    deadline = time.time() + 10
    while sched.live_nodes() and time.time() < deadline:
        time.sleep(0.02)
    with pytest.raises(OffloadError, match="no live workers"):
        sched.submit(_spin(pool.domain.registry))


def test_unknown_policy_rejected(pool):
    with pytest.raises(OffloadError, match="unknown policy"):
        Scheduler(pool, policy="fastest_first")


def test_future_msg_id_tracks_table_entry(pool):
    fut = pool.domain.async_(
        1, f2f("_ham/ping", 9, registry=pool.domain.registry)
    )
    assert fut.msg_id > 0
    assert fut.get(10) == 9
