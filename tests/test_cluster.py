"""Cluster pool + scheduler: routing policies, credit flow control,
pipelined completions, worker death/restart, shm segment hygiene."""

import os
import time

import numpy as np
import pytest

import repro.cluster.pool  # noqa: F401 — registers _cluster/* at collection,
#                            before any test seals the default registry
from repro.cluster import ClusterPool, Scheduler, as_completed, gather
from repro.cluster.pool import register_cluster_handlers
from repro.core.closure import f2f
from repro.core.errors import (
    NodeDownError,
    OffloadError,
    RemoteExecutionError,
)
from repro.core.registry import HandlerRegistry, default_registry
from repro.offload.runtime import register_internal_handlers


def _registry():
    reg = HandlerRegistry()
    register_internal_handlers(reg)
    register_cluster_handlers(reg)
    reg.init()
    return reg


@pytest.fixture
def pool():
    p = ClusterPool.local(3, registry=_registry())
    yield p
    p.close()


def _sleep(reg, seconds):
    return f2f("_cluster/sleep", seconds, registry=reg)


def _spin(reg, n=10):
    return f2f("_cluster/spin", n, registry=reg)


# -- routing policies --------------------------------------------------------


def test_round_robin_spreads_evenly(pool):
    sched = Scheduler(pool, policy="round_robin")
    futs = [sched.submit(_spin(pool.domain.registry)) for _ in range(9)]
    assert gather(futs, 30) == [45] * 9
    assert sorted(sched.stats["routed"].values()) == [3, 3, 3]


def test_least_outstanding_avoids_busy_worker(pool):
    reg = pool.domain.registry
    sched = Scheduler(pool, policy="least_outstanding", max_inflight=8)
    # pile outstanding calls on node 1, then policy-route a burst: node 1's
    # queue depth (3) always exceeds any transient depth on nodes 2/3 (<=1
    # spin in flight each), so the burst must avoid it
    busy = [sched.submit(_sleep(reg, 0.5), node=1) for _ in range(3)]
    futs = [sched.submit(_spin(reg)) for _ in range(6)]
    gather(futs, 30)
    gather(busy, 10)
    assert sched.stats["routed"][1] == 3  # the pinned calls only
    assert sched.stats["routed"][2] + sched.stats["routed"][3] == 6


def test_locality_routes_to_buffer_owner(pool):
    reg = pool.domain.registry
    sched = Scheduler(pool, policy="locality")
    dom = pool.domain
    arr = np.arange(16.0)
    for target in (1, 2, 3):
        ptr = dom.allocate(target, arr.shape, "float64")
        dom.put(arr, ptr)
        fut = sched.submit(f2f("_cluster/touch", ptr, registry=reg))
        assert fut.get(10) == arr.sum()
    # every call ran on its buffer's owner — a remote deref would have
    # raised (pointers are only valid in their own address space)
    assert sched.stats["routed"] == {1: 1, 2: 1, 3: 1}
    assert sched.stats["locality_hits"] == 3


def test_locality_falls_back_without_votes(pool):
    sched = Scheduler(pool, policy="locality")
    assert sched.submit(_spin(pool.domain.registry)).get(10) == 45
    assert sched.stats["locality_hits"] == 0


# -- pipelining --------------------------------------------------------------


def test_as_completed_yields_in_completion_order(pool):
    reg = pool.domain.registry
    sched = Scheduler(pool, max_inflight=4)
    slow = sched.submit(_sleep(reg, 0.4), node=1)
    fast = [sched.submit(_sleep(reg, 0.01), node=2) for _ in range(3)]
    order = list(as_completed([slow, *fast], timeout=30))
    assert order[-1] is slow  # the slow call finishes last
    assert set(order) == {slow, *fast}


def test_pipelined_submits_overlap_across_workers(pool):
    """The acceptance property at test scale: many in-flight sleeps across
    3 workers must beat the serial round-trip floor by ~worker count."""
    reg = pool.domain.registry
    sched = Scheduler(pool, max_inflight=16)
    n, per_call = 30, 0.02
    t0 = time.perf_counter()
    gather([sched.submit(_sleep(reg, per_call)) for _ in range(n)], 60)
    dt = time.perf_counter() - t0
    assert dt < n * per_call * 0.75  # strictly better than serial execution


def test_gather_orders_by_submission(pool):
    reg = pool.domain.registry
    sched = Scheduler(pool)
    futs = [sched.submit(f2f("_cluster/spin", i, registry=reg))
            for i in (3, 5, 7)]
    assert gather(futs, 30) == [3, 10, 21]


# -- credit-based flow control ----------------------------------------------


def test_backpressure_blocks_then_raises(pool):
    reg = pool.domain.registry
    sched = Scheduler(pool, max_inflight=2, submit_timeout=0.3)
    held = [sched.submit(_sleep(reg, 0.8), node=1) for _ in range(2)]
    t0 = time.perf_counter()
    with pytest.raises(OffloadError, match="backpressure"):
        sched.submit(_sleep(reg, 0.8), node=1)  # no credit on node 1
    assert 0.25 < time.perf_counter() - t0 < 2.0  # blocked, then gave up
    gather(held, 30)
    # credits returned on completion: the same pinned submit works now
    assert sched.submit(_sleep(reg, 0.01), node=1).get(10) == 0.01


def test_policy_routes_around_saturated_worker(pool):
    reg = pool.domain.registry
    sched = Scheduler(pool, max_inflight=1, submit_timeout=5.0)
    blocker = sched.submit(_sleep(reg, 0.5), node=1)
    t0 = time.perf_counter()
    futs = [sched.submit(_spin(reg)) for _ in range(4)]
    gather(futs, 30)
    # the burst never waited on node 1's credit
    assert time.perf_counter() - t0 < 0.45
    assert sched.stats["routed"][1] == 1  # only the blocker
    blocker.get(10)


# -- worker failure (thread pool) -------------------------------------------


def test_thread_worker_death_fails_queued_calls_and_reroutes(pool):
    reg = pool.domain.registry
    sched = Scheduler(pool, max_inflight=8)
    # occupy node 1 (let its loop start executing the sleep), then queue
    # more work behind it
    running = sched.submit(_sleep(reg, 0.3), node=1)
    time.sleep(0.1)
    queued = [sched.submit(_spin(reg), node=1) for _ in range(3)]
    pool.kill(1)  # stops the event loop: queued frames are never drained
    deadline = time.time() + 10
    while 1 in sched.live_nodes() and time.time() < deadline:
        time.sleep(0.02)
    assert sched.live_nodes() == [2, 3]
    for f in queued:
        with pytest.raises(RemoteExecutionError, match="died"):
            f.get(10)
    assert sched.stats["failed_inflight"] >= 3
    # policy traffic reroutes to the survivors
    assert sched.submit(_spin(reg)).get(10) == 45
    with pytest.raises(NodeDownError):
        sched.submit(_spin(reg), node=1)
    del running  # may have completed or failed depending on drain timing

    pool.restart(1)
    deadline = time.time() + 10
    while 1 not in sched.live_nodes() and time.time() < deadline:
        time.sleep(0.02)
    assert sched.live_nodes() == [1, 2, 3]
    assert sched.submit(_spin(reg), node=1).get(10) == 45


# -- elastic membership -------------------------------------------------------


def test_add_node_joins_scheduler_and_takes_traffic(pool):
    reg = pool.domain.registry
    sched = Scheduler(pool, policy="round_robin")
    new = pool.add_node()
    assert new == 4  # ids are monotonic, never reused
    assert sched.live_nodes() == [1, 2, 3, 4]
    futs = [sched.submit(_spin(reg)) for _ in range(8)]
    assert gather(futs, 30) == [45] * 8
    assert sched.stats["routed"][new] >= 2  # round robin includes the joiner
    # the new node is individually addressable too
    assert sched.submit(_spin(reg), node=new).get(10) == 45


def test_remove_node_drain_finishes_inflight_then_fences(pool):
    reg = pool.domain.registry
    sched = Scheduler(pool, max_inflight=8)
    inflight = [sched.submit(_sleep(reg, 0.3), node=3) for _ in range(3)]
    pool.remove_node(3, drain=True)  # blocks: fence, drain, retire
    # drained calls completed normally — nothing was failed
    assert gather(inflight, 5) == [0.3] * 3
    assert sched.stats["failed_inflight"] == 0
    assert sched.live_nodes() == [1, 2]
    with pytest.raises(NodeDownError):
        sched.submit(_spin(reg), node=3)
    # the id is retired from the pool and the fabric
    assert 3 not in pool.worker_nodes
    assert 3 not in pool.fabric.nodes()


def test_remove_node_without_drain_fails_inflight(pool):
    reg = pool.domain.registry
    sched = Scheduler(pool, max_inflight=8)
    running = sched.submit(_sleep(reg, 0.2), node=2)
    time.sleep(0.05)  # let the worker start executing
    queued = [sched.submit(_sleep(reg, 5.0), node=2) for _ in range(2)]
    pool.remove_node(2, drain=False)
    for f in queued:
        with pytest.raises(RemoteExecutionError, match="died"):
            f.get(10)
    assert sched.live_nodes() == [1, 3]
    del running  # may have completed or failed depending on kill timing


def test_elastic_resize_under_continuous_traffic():
    """The PR's acceptance property: a live pool grows 2 -> 4 and shrinks
    back to 2 (drained) while a continuous submit stream observes ZERO
    failed calls."""
    import threading

    pool = ClusterPool.local(2, registry=_registry())
    try:
        reg = pool.domain.registry
        sched = Scheduler(pool, max_inflight=8)
        stop = threading.Event()
        futs: list = []
        submit_errors: list = []

        def stream():
            while not stop.is_set():
                try:
                    futs.append(sched.submit(_sleep(reg, 0.003)))
                except Exception as e:  # noqa: BLE001 — the assertion target
                    submit_errors.append(e)

        t = threading.Thread(target=stream)
        t.start()
        try:
            time.sleep(0.15)
            added = [pool.add_node(), pool.add_node()]
            assert sched.live_nodes() == [1, 2, *added]
            time.sleep(0.25)  # let traffic spread over 4 workers
            for node in added:
                pool.remove_node(node, drain=True)
            assert sched.live_nodes() == [1, 2]
            time.sleep(0.1)
        finally:
            stop.set()
            t.join()
        results = gather(futs, 120)  # fail-fast on any errored future
        assert submit_errors == []
        assert len(results) > 50
        assert all(r == 0.003 for r in results)
        # the transient workers really carried traffic
        assert all(sched.stats["routed"].get(n, 0) > 0 for n in added)
    finally:
        pool.close()


# -- sticky sessions ----------------------------------------------------------


def test_sessions_stick_across_resize_and_replace_on_death(pool):
    reg = pool.domain.registry
    sched = Scheduler(pool, max_inflight=8)
    keys = [f"s{i}" for i in range(12)]
    for k in keys:
        assert sched.submit(_spin(reg), session=k).get(10) == 45
    placement = {k: sched.sessions.lookup(k) for k in keys}
    assert set(placement.values()) <= {1, 2, 3}

    # an unrelated grow must not move any pinned session
    new = pool.add_node()
    for k in keys:
        sched.submit(_spin(reg), session=k).get(10)
    assert {k: sched.sessions.lookup(k) for k in keys} == placement

    # kill one session-owning worker: only ITS sessions re-place
    victim = placement[keys[0]]
    victims = [k for k, n in placement.items() if n == victim]
    pool.kill(victim)
    deadline = time.time() + 10
    while victim in sched.live_nodes() and time.time() < deadline:
        time.sleep(0.02)
    for k in keys:
        sched.submit(_spin(reg), session=k).get(10)
    after = {k: sched.sessions.lookup(k) for k in keys}
    for k in keys:
        if k in victims:
            assert after[k] != victim and after[k] in sched.live_nodes()
        else:
            assert after[k] == placement[k]
    assert sched.stats["session_routed"] == 3 * len(keys)
    del new


def test_session_submits_respect_credits(pool):
    reg = pool.domain.registry
    sched = Scheduler(pool, max_inflight=2, submit_timeout=0.3)
    held = [sched.submit(_sleep(reg, 0.8), session="hot") for _ in range(2)]
    with pytest.raises(OffloadError, match="backpressure"):
        sched.submit(_sleep(reg, 0.8), session="hot")  # pinned worker full
    gather(held, 30)


# -- queue-depth feedback -----------------------------------------------------


def test_depth_reports_route_second_scheduler_around_busy_worker(pool):
    """Remote queue depth covers load the host-side in-flight count cannot
    see: a second scheduler (fresh counters) must avoid the worker another
    scheduler buried in work, purely from _cluster/stats reports."""
    reg = pool.domain.registry
    sched_a = Scheduler(pool, max_inflight=8)
    busy = [sched_a.submit(_sleep(reg, 0.5), node=1) for _ in range(5)]
    time.sleep(0.3)  # let the worker report its backlog
    assert pool.host.peer_depth.get(1, 0) > 0
    sched_b = Scheduler(pool, policy="least_outstanding", max_inflight=8)
    futs = [sched_b.submit(_spin(reg)) for _ in range(4)]
    assert gather(futs, 30) == [45] * 4
    assert sched_b.stats["routed"].get(1, 0) == 0  # avoided the buried node
    gather(busy, 30)


def test_depth_reports_decay_to_zero_when_idle(pool):
    reg = pool.domain.registry
    sched = Scheduler(pool, max_inflight=8)
    gather([sched.submit(_sleep(reg, 0.1), node=1) for _ in range(4)], 30)
    deadline = time.time() + 5
    while pool.host.peer_depth.get(1, 0) != 0 and time.time() < deadline:
        time.sleep(0.02)
    assert pool.host.peer_depth.get(1, 0) == 0  # idle worker retracted it
    del sched


# -- byte-weighted locality ---------------------------------------------------


def test_locality_routes_to_byte_heavy_node(pool):
    """The locality-weighting regression: a node owning ONE big buffer must
    win against a node owning MANY small ones (votes weigh nbytes)."""
    reg = pool.domain.registry
    sched = Scheduler(pool, policy="locality")
    dom = pool.domain
    smalls = [dom.allocate(1, (1,), "float64") for _ in range(3)]  # 24 B
    big = dom.allocate(2, (1 << 16,), "float64")                   # 512 KB
    fn = f2f("_cluster/touch", (big, *smalls), registry=reg)
    # routing only (the probe handler takes a single ptr): the pick must
    # follow the bytes, not the 3-pointer majority on node 1
    assert sched._pick(fn) == 2
    # and an executed call on the big buffer lands on its owner
    dom.put(np.ones(1 << 16), big)
    assert sched.submit(
        f2f("_cluster/touch", big, registry=reg)
    ).get(10) == float(1 << 16)
    assert sched.stats["routed"][2] == 1


# -- worker failure (forked processes over shm) ------------------------------


def _default_registry_ready():
    reg = default_registry()
    register_cluster_handlers(reg)  # no-op if already present/sealed
    if not reg.initialised:
        reg.init()
    return reg


@pytest.mark.fork
def test_fork_worker_killed_mid_stream_fails_inflight_and_reroutes():
    """The PR's failure-semantics contract, against a REAL process death:
    kill one forked worker while its calls are in flight; the scheduler
    must mark it dead, fail those futures with RemoteExecutionError, and
    route subsequent calls to the survivor."""
    reg = _default_registry_ready()
    pool = ClusterPool.shm(2, registry=reg)
    try:
        sched = Scheduler(pool, policy="round_robin", max_inflight=8)
        pool.ping_all()
        inflight = [sched.submit(_sleep(reg, 3.0), node=1) for _ in range(3)]
        time.sleep(0.2)  # let the worker start executing
        pool.kill(1)
        deadline = time.time() + 10
        while 1 in sched.live_nodes() and time.time() < deadline:
            time.sleep(0.05)
        assert sched.live_nodes() == [2], "scheduler must mark the corpse dead"
        for f in inflight:
            with pytest.raises(RemoteExecutionError, match="died"):
                f.get(10)
        assert sched.stats["failed_inflight"] == 3
        results = gather([sched.submit(_spin(reg)) for _ in range(4)], 30)
        assert results == [45] * 4
        assert sched.stats["routed"][2] >= 4  # everything rerouted
    finally:
        pool.close()


@pytest.mark.fork
def test_fork_worker_restart_rejoins_pool():
    reg = _default_registry_ready()
    pool = ClusterPool.shm(2, registry=reg)
    try:
        sched = Scheduler(pool, max_inflight=4)
        pool.ping_all()
        pool.kill(1)
        deadline = time.time() + 10
        while 1 in sched.live_nodes() and time.time() < deadline:
            time.sleep(0.05)
        pool.restart(1)
        deadline = time.time() + 10
        while 1 not in sched.live_nodes() and time.time() < deadline:
            time.sleep(0.05)
        assert sched.live_nodes() == [1, 2]
        assert sched.submit(_spin(reg), node=1).get(20) == 45
    finally:
        pool.close()


@pytest.mark.fork
def test_fork_elastic_add_remove_node_under_traffic():
    """Elastic membership over a REAL process fabric: grow a forked shm
    pool under traffic (ring creation + attach_peer broadcast + spawn +
    digest verify), then drain-remove the newcomer and reclaim its rings."""
    reg = _default_registry_ready()
    pool = ClusterPool.shm(2, registry=reg)
    try:
        sched = Scheduler(pool, max_inflight=8)
        pool.ping_all()
        inflight = [sched.submit(_sleep(reg, 0.05)) for _ in range(8)]
        new = pool.add_node()
        assert new == 3
        assert sched.live_nodes() == [1, 2, 3]
        # traffic reaches the newcomer, pinned and policy-routed
        assert sched.submit(_spin(reg), node=new).get(20) == 45
        results = gather(
            [sched.submit(_spin(reg)) for _ in range(12)] + inflight, 30
        )
        assert results[:12] == [45] * 12
        assert sched.stats["routed"][new] >= 1

        pool.remove_node(new, drain=True)
        assert sched.live_nodes() == [1, 2]
        assert sched.stats["failed_inflight"] == 0
        # the retired node's ring segments are unlinked immediately
        assert not any(
            f.startswith(pool.fabric.prefix) and f.endswith("_3")
            or f.startswith(f"{pool.fabric.prefix}_3_")
            for f in os.listdir("/dev/shm")
        )
        assert gather([sched.submit(_spin(reg)) for _ in range(4)], 30) \
            == [45] * 4
    finally:
        pool.close()


@pytest.mark.fork
def test_shm_segments_unlinked_even_when_child_dies():
    """The segment-leak satellite: a child killed mid-run must not leave
    its fabric's segments in /dev/shm after ClusterPool.close()."""
    reg = _default_registry_ready()
    pool = ClusterPool.shm(2, registry=reg)
    prefix = pool.fabric.prefix
    pool.ping_all()
    assert any(f.startswith(prefix) for f in os.listdir("/dev/shm"))
    pool.kill(1)
    time.sleep(0.3)
    pool.close()
    assert not any(f.startswith(prefix) for f in os.listdir("/dev/shm"))
    # close() reaped the children too
    for handle in pool._workers.values():
        assert not handle.alive()


# -- misc --------------------------------------------------------------------


def test_no_live_workers_raises(pool):
    sched = Scheduler(pool, max_inflight=2)
    for n in pool.worker_nodes:
        pool.kill(n)
    deadline = time.time() + 10
    while sched.live_nodes() and time.time() < deadline:
        time.sleep(0.02)
    with pytest.raises(OffloadError, match="no live workers"):
        sched.submit(_spin(pool.domain.registry))


def test_unknown_policy_rejected(pool):
    with pytest.raises(OffloadError, match="unknown policy"):
        Scheduler(pool, policy="fastest_first")


def test_future_msg_id_tracks_table_entry(pool):
    fut = pool.domain.async_(
        1, f2f("_ham/ping", 9, registry=pool.domain.registry)
    )
    assert fut.msg_id > 0
    assert fut.get(10) == 9
