"""hamlint fixture: wire constants declared outside the centralized
registry, one colliding with a live bit and one sentinel inside live msg_id
space.  Never imported — parsed by the linter only."""

# collides with FLAG_STATIC (bit 3) in repro.core.flags
FLAG_EXPERIMENTAL = 1 << 3

# a "reserved" msg_id sentinel low enough for live traffic to reach
MSG_ID_DRAIN = 1 << 20
