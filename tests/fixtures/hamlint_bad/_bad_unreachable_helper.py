"""hamlint fixture helper: defines a handler function that a DIFFERENT
module registers at import time (the PR 2 divergence class).  Never
imported — parsed by the linter only."""


def helper_handler(a, b):
    return a * b
