"""hamlint fixture: spec/signature arity mismatch and a bad scalar kind.
Never imported — parsed by the linter only."""

from repro.core.migratable import ScalarSpec
from repro.core.registry import default_registry

_reg = default_registry()


def takes_two(a, b):
    return a + b


# three leaves, two parameters — the payload and the call disagree
_reg.register(
    takes_two,
    arg_specs=(ScalarSpec("i8"), ScalarSpec("i8"), ScalarSpec("f8")),
    name="bad/arity",
)


def takes_one(a):
    return a


# 'u4' is not a wire-plan-compilable scalar kind
_reg.register(
    takes_one,
    arg_specs=(ScalarSpec("u4"),),
    name="bad/scalar_kind",
)
