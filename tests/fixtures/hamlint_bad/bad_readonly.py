"""hamlint fixture: handler declared read_only=True that mutates and
alias-escapes buffer-derived memory (the PR 5 bug class).  Never imported —
parsed by the linter only."""

from repro.core.registry import default_registry
from repro.offload.api import deref

_reg = default_registry()

_stash = {}


@_reg.handler(name="bad/scale_in_place", read_only=True)
def scale_in_place(alpha, x_ptr, y_ptr):
    y = deref(y_ptr)
    y += alpha * deref(x_ptr)          # in-place mutation
    return None


@_reg.handler(name="bad/store_through_view", read_only=True)
def store_through_view(x_ptr):
    row = deref(x_ptr)[0]
    row[:] = 0.0                       # store through a view
    return None


@_reg.handler(name="bad/alias_escape", read_only=True)
def alias_escape(x_ptr):
    _stash["x"] = deref(x_ptr)         # view outlives the call
    return None
