"""hamlint fixture: handler declared mutates=True whose in-place store is
LEGAL — the declaration is the point of the Active Access write path (the
scheduler routes the call at the primary and invalidates replicas on
completion), so HAM001 must produce NO finding here.  Never imported —
parsed by the linter only."""

from repro.core.registry import default_registry
from repro.offload.api import deref


_reg = default_registry()


@_reg.handler(name="ok/declared_scale", mutates=True)
def declared_scale(alpha, y_ptr):
    y = deref(y_ptr)
    y *= alpha                         # declared: no finding
    return None
