"""hamlint fixture: two same-source violations (the PR 2 divergence class).
Never imported — parsed by the linter only."""

from _bad_unreachable_helper import helper_handler

from repro.core.registry import default_registry

_reg = default_registry()

# import-time registration of a function DEFINED ELSEWHERE: workers import
# the defining module (_bad_unreachable_helper), where this statement does
# not exist — key maps diverge
_reg.register(helper_handler, name="bad/foreign_fn")


def local_handler(x):
    return x


def register_late(registry=None):
    # never called at module level: a worker importing this module would
    # not run this registration
    reg = registry or default_registry()
    reg.register(local_handler, name="bad/never_at_import")
