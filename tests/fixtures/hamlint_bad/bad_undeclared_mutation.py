"""hamlint fixture: handler that mutates buffer memory while declaring
NEITHER read_only nor mutates — the write lands on the primary but its
replicas are never invalidated, so a replica-served read observes stale
bytes.  The finding must name the fix: declare mutates=True.  Never
imported — parsed by the linter only."""

from repro.core.registry import default_registry
from repro.offload.api import deref


_reg = default_registry()


@_reg.handler(name="bad/undeclared_scale")
def undeclared_scale(alpha, y_ptr):
    y = deref(y_ptr)
    y *= alpha                         # undeclared in-place mutation
    return None
