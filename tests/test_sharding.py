"""Sharding rules + a small-mesh dry-run (8 host devices via subprocess)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.models.config import ShardingPlan
from repro.models.sharding import Sharder


class _FakeMesh:
    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


def _sharder(**plan_kw):
    mesh = _FakeMesh({"data": 16, "model": 16})
    return Sharder(mesh, ShardingPlan(batch_axes=("pod", "data"), **plan_kw))


def test_divisibility_fallback_to_replication():
    sh = _sharder()
    # 20 heads don't divide the 16-way model axis -> replicate
    assert sh.spec((2560, 20, 128), [None, "model", None])[1] is None
    # 48 heads do
    assert sh.spec((6144, 48, 128), [None, "model", None])[1] == "model"


def test_axis_used_once_per_spec():
    sh = _sharder()
    spec = sh.spec((4096, 4096), ["model", "model"])
    assert spec[0] == "model" and spec[1] is None


def test_candidate_order_first_fit():
    sh = _sharder(fsdp=True, fsdp_axes=("data",))
    # fsdp candidate wins on dim0 when divisible
    spec = sh.spec((1024, 512), [["fsdp"], "model"])
    assert spec[0] == "data" and spec[1] == "model"
    # odd dim0: falls through to replication, model still applies on dim1
    spec = sh.spec((1023, 512), [["fsdp"], "model"])
    assert spec[0] is None and spec[1] == "model"


def test_missing_mesh_axes_ignored():
    mesh = _FakeMesh({"data": 4, "model": 2})
    sh = Sharder(mesh, ShardingPlan(batch_axes=("pod", "data")))
    assert sh.spec((8, 16), ["batch", "model"]) [0] == "data"  # pod absent


def test_seq_shard_gating():
    on = _sharder(seq_shard=True)
    off = _sharder(seq_shard=False)
    assert on.spec((16, 4096, 512), ["batch", "seq", None])[1] == "model"
    assert off.spec((16, 4096, 512), ["batch", "seq", None])[1] is None


_SMALL_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    import jax, json
    from repro.launch import dryrun
    from repro.launch.hlo_analysis import analyze
    from repro.launch.mesh import make_mesh
    from repro.models.api import build_model
    from repro.models.config import ShardingPlan, ShapeCell
    from repro.models.sharding import Sharder
    from repro.configs import get_reduced
    from repro.train.step import build_train_step
    from repro.optim import adamw

    cfg = get_reduced("internlm2-20b")
    cell = ShapeCell("small_train", "train", 32, 8)
    mesh = make_mesh((4, 2), ("data", "model"))
    sharder = Sharder(mesh, ShardingPlan(batch_axes=("pod", "data")))
    model = build_model(cfg)
    in_ns, shapes, donate = dryrun.shardings_for(model, sharder, cell, "float32")
    fn = build_train_step(model, adamw.AdamWConfig(), sharder)
    compiled = jax.jit(fn, in_shardings=in_ns,
                       out_shardings=(in_ns[0], in_ns[1], None),
                       donate_argnums=donate).lower(*shapes).compile()
    cost = analyze(compiled.as_text())
    mem = compiled.memory_analysis()
    print(json.dumps({{
        "flops": cost.flops,
        "coll": cost.collective_bytes,
        "loops": len(cost.loops) if cost.loops else 0,
        "arg_bytes": mem.argument_size_in_bytes,
    }}))
""")


def test_small_mesh_dryrun_compiles_and_analyzes(tmp_path):
    """End-to-end: lower+compile a reduced arch on an 8-device host mesh in a
    fresh interpreter (so this test process keeps its 1-device jax)."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _SMALL_DRYRUN.format(src=os.path.abspath(src))
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=480)
    assert out.returncode == 0, out.stderr[-2000:]
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    assert payload["flops"] > 0
    assert payload["coll"] > 0      # DP gradient sync must appear
    assert payload["loops"] >= 1    # scan over layers detected with trips
