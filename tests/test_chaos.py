"""Failure-domain hardening under seeded fault injection (repro.comm.chaos).

Covers the four robustness layers as one suite (docs/failure-model.md):

* the ChaosFabric determinism contract — same seed + schedule => the
  identical fault sequence, on every transport;
* deadlines/retries with exactly-once replay — mutating handlers execute
  once per logical call no matter how many frames are dropped/duplicated;
* the auto-restart circuit breaker — a crash-looping worker is quarantined
  instead of hot-looped, then readmitted by a half-open probe;
* the durable BufferDirectory — a host crash+restart rebuilds the full
  directory from worker-journalled shards with zero lost buffers;
* the socket acceptance run — >=1000 calls through seeded drop+dup+delay,
  mixed mutating/read-only, all complete, zero double-executions, zero
  stranded futures.

Everything here carries the ``chaos`` marker (the CI chaos smoke job runs
``pytest -m chaos``); the tests also run in the default suite.
"""

import time

import numpy as np
import pytest

import repro.cluster.pool  # noqa: F401 — registers _cluster/* at collection
import repro.offload.demo_handlers  # noqa: F401 — registers chaos/* probes
from repro.offload import dataplane
from repro.cluster import ClusterPool, Scheduler, gather
from repro.cluster.pool import register_cluster_handlers
from repro.comm.chaos import ChaosConfig, ChaosFabric
from repro.comm.local import LocalFabric
from repro.core.closure import f2f
from repro.core.errors import OffloadError
from repro.core.future import Future
from repro.core.message import HEADER_STRUCT, encode_frame
from repro.core.registry import default_registry
from repro.offload.runtime import ReplayCache

pytestmark = pytest.mark.chaos


def _default_registry_ready():
    reg = default_registry()
    register_cluster_handlers(reg)  # no-op if already present/sealed
    if not reg.initialised:
        reg.init()
    return reg


# -- determinism contract (raw fabrics, no runtime) ---------------------------

#: drop + dup only: both are decided-and-done at decide time, so the fault
#: log AND the delivered set are reproducible.  (delay/reorder decisions are
#: equally deterministic, but their *delivery timing* is not — they get
#: their own behavioural tests below.)
_DET_CFG = ChaosConfig(
    drop=0.2, dup=0.15,
    schedule=((5, 8, "drop"), (12, 14, "deliver")),
)


def _drive(fabric, seed, n=40):
    """Send ``n`` HAM frames 0 -> 1 through a seeded wrapper and drain the
    receiver; returns (fault_log, delivered_msg_ids)."""
    chaos = ChaosFabric(fabric, seed=seed, default=_DET_CFG)
    try:
        src, dst = chaos.endpoint(0), chaos.endpoint(1)
        chaos.arm()
        for i in range(n):
            src.send(1, encode_frame(0, b"\0" * 8, src_node=0, msg_id=i + 1))
        ids, quiet = [], 0
        while quiet < 3:  # drain until the link stays silent
            frames = dst.recv_many(64, timeout=0.05)
            if frames:
                # unpack immediately, then release the recv lease — shm
                # frames are zero-copy views into the ring, valid (and
                # holding the segment open) until released
                ids.extend(HEADER_STRUCT.unpack_from(f, 0)[5] for f in frames)
                frames = None
                dst.release()
                quiet = 0
            else:
                quiet += 1
        chaos.disarm()
        return list(chaos.fault_log), ids
    finally:
        chaos.close()


def test_same_seed_reproduces_fault_sequence_local():
    log_a, ids_a = _drive(LocalFabric(2), seed=7)
    log_b, ids_b = _drive(LocalFabric(2), seed=7)
    assert log_a == log_b and ids_a == ids_b
    assert log_a, "a 35% fault rate over 40 frames must log something"
    # the forced schedule window always drops send-side frames 5..7
    send_actions = {s: a for _, _, s, a, w in log_a if w == "send"}
    assert all(send_actions.get(s) == "drop" for s in (5, 6, 7))
    # frames 12..13 are schedule-protected: never in the log on either side
    assert all(s not in (12, 13) for _, _, s, _, _ in log_a)
    # a different seed draws a different sequence
    log_c, _ = _drive(LocalFabric(2), seed=8)
    assert log_c != log_a


def test_fault_sequence_identical_on_socket_fabric():
    from repro.comm.socket import SocketFabric

    log_local, ids_local = _drive(LocalFabric(2), seed=11)
    log_sock, ids_sock = _drive(SocketFabric(2), seed=11)
    assert log_sock == log_local  # decisions are transport-independent
    assert ids_sock == ids_local


@pytest.mark.shm
def test_fault_sequence_identical_on_shm_fabric():
    from repro.comm.shm import ShmFabric

    log_local, ids_local = _drive(LocalFabric(2), seed=11)
    log_shm, ids_shm = _drive(ShmFabric(2, capacity=1 << 20), seed=11)
    assert log_shm == log_local
    assert ids_shm == ids_local


def test_partition_blocks_link_until_unblocked():
    chaos = ChaosFabric(LocalFabric(2), seed=3)  # no probabilistic faults
    try:
        src, dst = chaos.endpoint(0), chaos.endpoint(1)
        chaos.arm().block(0, 1)
        for i in range(5):
            src.send(1, encode_frame(0, b"", src_node=0, msg_id=i + 1))
        assert dst.recv(timeout=0.1) is None  # one-way partition holds
        assert all(a == "drop" for _, _, _, a, _ in chaos.fault_log)
        chaos.unblock(0, 1)
        src.send(1, encode_frame(0, b"", src_node=0, msg_id=99))
        healed = dst.recv(timeout=2.0)
        assert healed is not None
        assert HEADER_STRUCT.unpack_from(healed, 0)[5] == 99
    finally:
        chaos.close()


def test_delayed_frames_eventually_deliver():
    chaos = ChaosFabric(LocalFabric(2), seed=5,
                        default=ChaosConfig(delay=1.0, delay_s=0.01))
    try:
        src, dst = chaos.endpoint(0), chaos.endpoint(1)
        chaos.arm()
        for i in range(3):
            src.send(1, encode_frame(0, b"", src_node=0, msg_id=i + 1))
        got = []
        deadline = time.time() + 5
        while len(got) < 3 and time.time() < deadline:
            got.extend(dst.recv_many(8, timeout=0.05))
        assert len(got) == 3  # held, never lost
        assert {a for _, _, _, a, _ in chaos.fault_log} == {"delay"}
    finally:
        chaos.close()


def test_reordered_batch_loses_nothing():
    chaos = ChaosFabric(LocalFabric(2), seed=5,
                        default=ChaosConfig(reorder=1.0, delay_s=0.01))
    try:
        src, dst = chaos.endpoint(0), chaos.endpoint(1)
        chaos.arm()
        batch = [encode_frame(0, b"", src_node=0, msg_id=i + 1)
                 for i in range(6)]
        src.send_many(1, batch)
        got = []
        deadline = time.time() + 5
        while len(got) < 6 and time.time() < deadline:
            got.extend(dst.recv_many(16, timeout=0.05))
        ids = sorted(HEADER_STRUCT.unpack_from(f, 0)[5] for f in got)
        assert ids == [1, 2, 3, 4, 5, 6]  # scrambled, not dropped
        assert chaos.faults["reorder"] > 0
    finally:
        chaos.close()


# -- replay cache unit behaviour ---------------------------------------------


def test_replay_cache_ack_floor_suppresses_stragglers():
    rc = ReplayCache()
    assert rc.begin(7, 1) is None  # first sight: caller executes
    rc.commit(7, 1, b"reply-frame")
    assert rc.begin(7, 1) == b"reply-frame"  # retransmit: cached reply
    assert rc.stats == {"replayed": 1, "suppressed": 0, "acked": 0}
    rc.ack(7, 1)
    assert rc.stats["acked"] == 1
    # a duplicate reordered behind the ack must NOT re-execute: the floor
    # swallows it (no execution, no reply — the sender already completed)
    assert rc.begin(7, 1) is ReplayCache.IN_PROGRESS
    assert rc.stats["suppressed"] == 1
    # the flush sentinel announces a NEW msg_id space (host restart):
    # everything is forgotten, low ids execute fresh again
    rc.ack(7, ReplayCache.FLUSH)
    assert rc.begin(7, 1) is None


def test_replay_cache_flush_drops_in_progress_entries():
    rc = ReplayCache()
    assert rc.begin(3, 9) is None  # executing when the host restarts
    rc.ack(3, ReplayCache.FLUSH)
    rc.commit(3, 9, b"stale")  # the old call's commit must no-op:
    assert rc.begin(3, 9) is None  # a new call with the same id runs fresh


# -- exactly-once under retry (local pool + chaos) ----------------------------


def test_exactly_once_replay_under_reply_loss():
    """Drop ~28% of worker->host reply frames; every retried chaos/bump
    must hit the worker replay cache instead of re-executing — the counter
    total stays exactly the number of logical calls."""
    reg = _default_registry_ready()
    holder = {}

    def wrap(f):
        holder["chaos"] = ChaosFabric(f, seed=42)
        return holder["chaos"]

    pool = ClusterPool.local(3, registry=reg, wrap_fabric=wrap)
    chaos = holder["chaos"]
    sched = Scheduler(pool, deadline=0.3, retries=8, max_inflight=16)
    try:
        for w in (1, 2, 3):  # lossy replies; requests stay clean
            chaos.set_link(w, 0, ChaosConfig(drop=0.15))
        chaos.arm()
        # partition ONE reply link for one deadline period: worker 1's
        # in-window replies are dropped DETERMINISTICALLY, so the
        # retries>0 assert below never depends on whether the seeded
        # probabilistic drops happened to land on a first-attempt reply.
        # (One link only — workers 2/3 keep returning flow-control
        # credits, so submission never backpressure-stalls.)
        chaos.block(1, 0)
        n = 60
        futs = [sched.submit(f2f("chaos/bump", "t-replay", registry=reg))
                for _ in range(n)]
        time.sleep(0.35)  # > deadline: >=1 in-window reply must retry
        chaos.unblock(1, 0)
        results = gather(futs, 120)
        chaos.disarm()
        # thread workers share one process-global counter, which makes the
        # exactly-once property *sharper* here: n logical calls must produce
        # exactly the post-increment values 1..n — a re-executed retry would
        # push the ceiling past n, a lost call would leave a hole
        assert sorted(results) == list(range(1, n + 1))
        # verification read runs fault-free (any worker: shared counter)
        total = pool.domain.sync(
            1, f2f("chaos/counts", "t-replay", registry=reg))
        assert total == n, "a retry re-executed (or lost) a mutating call"
        assert sched.stats["retries"] > 0  # faults actually bit
        replayed = sum(pool.domain._inproc[w].stats["replayed"]
                       for w in (1, 2, 3))
        assert replayed > 0  # cached replies were re-sent, not re-run
        assert sched.outstanding() == 0  # zero stranded futures
        pool.domain.sync(1, f2f("chaos/reset", "t-replay", registry=reg))
    finally:
        sched.close()
        pool.close()


def test_deadline_exhaustion_raises_diagnosis():
    reg = _default_registry_ready()
    pool = ClusterPool.local(2, registry=reg)
    sched = Scheduler(pool, max_inflight=8)
    try:
        # non-retryable: one attempt, then a diagnosis (at-most-once)
        fut = sched.submit(f2f("_cluster/sleep", 2.0, registry=reg),
                           node=1, deadline=0.2, retries=0)
        with pytest.raises(OffloadError, match="no reply within"):
            fut.get(10)
        assert sched.stats["deadline_failed"] == 1

        # retryable: the retransmits of a still-running call are absorbed
        # by the worker's replay cache (never executed twice), and the
        # exhausted call still gets a diagnosis
        fut = sched.submit(f2f("_cluster/sleep", 2.0, registry=reg),
                           node=2, deadline=0.15, retries=2)
        with pytest.raises(OffloadError, match="no reply within"):
            fut.get(10)
        assert sched.stats["retries"] >= 2
        # the retransmits queue behind the still-running sleep (DirectPolicy
        # executes inline) and are deduped once it finishes — wait for that
        rc = pool.domain._inproc[2].replay
        deadline = time.time() + 10
        while (rc.stats["suppressed"] + rc.stats["replayed"] < 1
               and time.time() < deadline):
            time.sleep(0.05)
        assert rc.stats["suppressed"] + rc.stats["replayed"] >= 1
    finally:
        sched.close()
        pool.close()


def test_future_result_defaults_to_bounded_wait(monkeypatch):
    monkeypatch.setattr(Future, "default_timeout", 0.05)
    f = Future()
    with pytest.raises(OffloadError, match="no reply within"):
        f.result()  # bounded by the class default — never an eternal block
    f.set_result(13)
    assert f.result() == 13  # a late reply still resolves it


# -- auto-restart circuit breaker ---------------------------------------------


def test_crash_loop_quarantines_then_probe_readmits():
    reg = _default_registry_ready()
    pool = ClusterPool.local(
        2, registry=reg, auto_restart=True, monitor_interval=0.02,
        restart_backoff=0.05, restart_backoff_max=0.1, max_restarts=2,
        fail_window=30.0, quarantine_probe=0.25,
    )
    deaths = []
    pool.on_death(deaths.append)
    try:
        handle = pool._workers[1]

        def refuse():
            raise RuntimeError("spawn refused (injected)")

        handle.respawn = refuse  # every restart attempt now fails
        pool.kill(1)
        deadline = time.time() + 10
        while not pool.is_quarantined(1) and time.time() < deadline:
            time.sleep(0.02)
        assert pool.is_quarantined(1), "breaker never tripped"
        assert not pool.is_alive(1)
        # the death was announced exactly once — failed respawns must not
        # re-announce (the scheduler already drained the node)
        assert deaths.count(1) == 1
        # heal the spawner: the next half-open probe restarts + pings the
        # worker and closes the breaker
        del handle.respawn
        deadline = time.time() + 10
        while pool.is_quarantined(1) and time.time() < deadline:
            time.sleep(0.02)
        assert not pool.is_quarantined(1), "half-open probe never readmitted"
        deadline = time.time() + 10
        while not pool.is_alive(1) and time.time() < deadline:
            time.sleep(0.02)
        assert pool.domain.ping(1, 5, timeout=10.0) == 5
    finally:
        pool.close()


def test_readmit_overrides_quarantine():
    reg = _default_registry_ready()
    pool = ClusterPool.local(
        2, registry=reg, auto_restart=True, monitor_interval=0.02,
        restart_backoff=0.05, restart_backoff_max=0.1, max_restarts=1,
        quarantine_probe=60.0,  # probe far away: only readmit() can help
    )
    try:
        handle = pool._workers[1]

        def refuse():
            raise RuntimeError("spawn refused (injected)")

        handle.respawn = refuse
        pool.kill(1)
        deadline = time.time() + 10
        while not pool.is_quarantined(1) and time.time() < deadline:
            time.sleep(0.02)
        assert pool.is_quarantined(1)
        del handle.respawn
        pool.readmit(1)  # operator override: restart now
        assert not pool.is_quarantined(1)
        assert pool.domain.ping(1, 4, timeout=10.0) == 4
    finally:
        pool.close()


# -- durable directory: host crash recovery -----------------------------------


def test_host_restart_recovers_full_directory():
    reg = _default_registry_ready()
    pool = ClusterPool.local(3, registry=reg, replicas=1)
    try:
        arrays, ptrs = {}, {}
        for i in range(6):
            arr = np.arange(16.0) + i
            ptr = pool.allocate(arr.shape, "float64", session=f"s{i}")
            pool.put(arr, ptr)
            arrays[i], ptrs[i] = arr, ptr
        time.sleep(0.3)  # let the dir_gossip oneways land on the workers
        report = pool.restart_host()
        assert report["lost"] == 0
        assert report["recovered"] == 6, "zero lost buffers after host crash"
        for i in range(6):  # bytes survived AND the directory resolves them
            np.testing.assert_array_equal(pool.get(ptrs[i]), arrays[i])
        rec = pool.directory.lookup(ptrs[0].handle)
        assert rec is not None and rec.session == "s0"  # bindings survive
        assert len(rec.holders) == 2  # primary + replica both recovered
    finally:
        pool.close()


def test_host_restart_promotes_when_primary_died_with_host():
    """Worker AND host die together: the rebuilt directory must promote the
    surviving replica (epoch bump) and still serve the bytes."""
    reg = _default_registry_ready()
    pool = ClusterPool.local(3, registry=reg, replicas=1)
    try:
        arr = np.arange(64.0)
        ptr = pool.allocate(arr.shape, "float64", node=1, session="both")
        pool.put(arr, ptr)
        time.sleep(0.3)  # gossip journal reaches the holders
        old_rec = pool.directory.lookup(ptr.handle)
        replica = old_rec.replicas[0]
        pool.kill(1)  # the primary dies...
        time.sleep(0.3)
        report = pool.restart_host()  # ...and then the host crashes
        assert report["lost"] == 0
        rec = pool.directory.lookup(ptr.handle)
        assert rec.primary == replica  # promoted onto the survivor
        assert rec.epoch > old_rec.epoch
        np.testing.assert_array_equal(pool.get(ptr), arr)
    finally:
        pool.close()


# -- chain replication under partition (write protocol, failure-model.md) -----


def _chaos_pool(seed, **kw):
    """Local pool with every link under a seeded (fault-free until armed)
    chaos wrapper; returns (pool, chaos)."""
    holder = {}

    def wrap(f):
        holder["chaos"] = ChaosFabric(f, seed=seed)
        return holder["chaos"]

    pool = ClusterPool.local(3, registry=_default_registry_ready(),
                             replicas=1, wrap_fabric=wrap, **kw)
    return pool, holder["chaos"]


def _wait_dead(sched, node, timeout=10.0):
    deadline = time.time() + timeout
    while node in sched.live_nodes() and time.time() < deadline:
        time.sleep(0.02)
    assert node not in sched.live_nodes()


def test_chain_put_partition_mid_chain_truncates_tail_then_heals(monkeypatch):
    """Partition the primary->replica hop mid-chain: the put must still
    complete (primary confirmed), with the unreachable tail DROPPED from
    the replica set — a detectable gap, never a silently-stale promotable
    copy.  Healing the link + a join backfills a replica carrying the NEW
    bytes, verified promotable by killing the primary and reading back."""
    monkeypatch.setattr(dataplane, "CHAIN_HOP_TIMEOUT", 1.5)
    pool, chaos = _chaos_pool(seed=11)
    sched = Scheduler(pool)
    try:
        pool.domain.direct_data_plane = False  # wire chain, not direct store
        x = np.arange(1024.0)
        ptr = pool.allocate(x.shape, "float64", session="chain-part")
        pool.put(x, ptr)  # healthy write-through: both holders confirm
        rec = pool.directory.lookup(ptr.handle)
        p, r = rec.primary, rec.replicas[0]
        chaos.arm().block(p, r)  # the forward hop goes dark
        y = x * 3.0
        t0 = time.perf_counter()
        pool.put(y, ptr)  # completes: tail truncated, not stuck for 30 s
        assert time.perf_counter() - t0 < 10.0
        assert any(a == "drop" for _, _, _, a, _ in chaos.fault_log)
        rec = pool.directory.lookup(ptr.handle)
        assert rec.primary == p
        assert r not in rec.replicas  # no silently-stale promotable copy
        np.testing.assert_array_equal(pool.get(ptr), y)
        chaos.unblock(p, r)
        chaos.disarm()
        new = pool.add_node()  # heal: lazy backfill restores the factor
        rec = pool.directory.lookup(ptr.handle)
        assert rec.replicas == (new,)
        np.testing.assert_array_equal(
            pool.domain.get(ptr.at(new, rec.epoch)), y)
        # the backfilled copy is genuinely promotable: kill the primary
        pool.kill(p)
        _wait_dead(sched, p)
        np.testing.assert_array_equal(pool.get(ptr), y)
        assert pool.directory.stats["lost"] == 0
    finally:
        sched.close()
        pool.close()


def test_chain_put_primary_unreachable_fails_loudly_keeps_old_bytes(
        monkeypatch):
    """Partition host->primary: the chain never confirms anywhere, so the
    put must raise (torn-write diagnosis, not silent success) while every
    holder keeps the PREVIOUS write; a healed retry converges all copies."""
    monkeypatch.setattr(dataplane, "CHAIN_HOP_TIMEOUT", 1.5)
    pool, chaos = _chaos_pool(seed=12)
    try:
        pool.domain.direct_data_plane = False
        orig_chain_put = pool.domain.chain_put  # shrink the host-side wait
        monkeypatch.setattr(
            pool.domain, "chain_put",
            lambda *a, **k: orig_chain_put(*a, **{**k, "timeout": 2.0}))
        x = np.arange(256.0)
        ptr = pool.allocate(x.shape, "float64", session="chain-torn")
        pool.put(x, ptr)
        rec = pool.directory.lookup(ptr.handle)
        p, r = rec.primary, rec.replicas[0]
        chaos.arm().block(0, p)  # the host cannot reach the primary
        with pytest.raises((OffloadError, TimeoutError)):
            pool.put(x * 2.0, ptr)
        chaos.unblock(0, p)
        chaos.disarm()
        # every holder kept the previous write — readable, just not new
        np.testing.assert_array_equal(pool.get(ptr), x)
        rec = pool.directory.lookup(ptr.handle)
        np.testing.assert_array_equal(
            pool.domain.get(ptr.at(r, rec.epoch)), x)
        z = x * 5.0
        pool.put(z, ptr)  # healed retry converges the full chain
        rec = pool.directory.lookup(ptr.handle)
        assert set(rec.replicas) == {r}
        np.testing.assert_array_equal(pool.get(ptr), z)
        np.testing.assert_array_equal(
            pool.domain.get(ptr.at(r, rec.epoch)), z)
    finally:
        pool.close()


# -- the socket acceptance run ------------------------------------------------


def test_socket_thousand_calls_exactly_once_under_chaos():
    """The PR's acceptance bar: >=1000 calls (4:1 mutating:read-only) over
    the socket fabric with seeded drop+dup+delay on every link.  All must
    complete, the side-effect counters must total EXACTLY the number of
    mutating calls (no loss, no double-execution), and no future may be
    left stranded."""
    reg = _default_registry_ready()
    holder = {}

    def wrap(f):
        holder["chaos"] = ChaosFabric(
            f, seed=20260809,
            default=ChaosConfig(drop=0.03, dup=0.02, delay=0.01,
                                delay_s=0.003),
        )
        return holder["chaos"]

    pool = ClusterPool.socket(3, registry=reg, wrap_fabric=wrap)
    chaos = holder["chaos"]
    sched = None
    try:
        pool.ping_all(timeout=60.0)  # fault-free build-out, then arm
        sched = Scheduler(pool, deadline=0.4, retries=6, max_inflight=32)
        chaos.arm()
        tokens = [f"tok{i}" for i in range(8)]
        futs, bumps = [], 0
        for i in range(1000):
            if i % 5 == 4:  # interleave read-only probes with the mutators
                fn = f2f("chaos/counts", tokens[i % 8], registry=reg)
            else:
                fn = f2f("chaos/bump", tokens[i % 8], registry=reg)
                bumps += 1
            futs.append(sched.submit(fn))
        results = gather(futs, 300)
        chaos.disarm()
        assert len(results) == 1000  # every call completed correctly
        # verification reads run with chaos disarmed
        total = 0
        for w in pool.worker_nodes:
            for tok in tokens:
                total += pool.domain.sync(
                    w, f2f("chaos/counts", tok, registry=reg), 30.0)
        assert total == bumps, (
            f"side-effect total {total} != {bumps} mutating calls: a retry "
            "double-executed or a call was lost"
        )
        assert sched.outstanding() == 0  # zero stranded futures
        assert sched.stats["deadline_failed"] == 0
        assert sched.stats["retries"] > 0  # the chaos actually bit
    finally:
        if sched is not None:
            sched.close()
        pool.close()
