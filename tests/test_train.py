"""Training loop, checkpoint/restart, gradient compression, fault tolerance."""

import time

import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.closure import f2f
from repro.core.registry import HandlerRegistry
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.offload.api import OffloadDomain
from repro.offload.runtime import register_internal_handlers
from repro.optim import adamw
from repro.optim.compression import (
    CompressedTensor,
    ef_compress_tree,
    ef_decompress_tree,
    ef_init,
)
from repro.train.ft import ElasticFleet, HeartbeatMonitor, StragglerDetector
from repro.train.loop import Trainer
from repro.train.step import build_compressed_train_step


def test_loss_decreases(tmp_path):
    cfg = get_reduced("internlm2-20b")
    tr = Trainer(cfg, adamw.AdamWConfig(lr=1e-3, warmup_steps=5),
                 global_batch=8, seq_len=32)
    tr.init()
    first = tr.run_steps(3)["loss"]
    later = tr.run_steps(15)["loss"]
    assert later < first


def test_checkpoint_restart_bit_exact(tmp_path):
    cfg = get_reduced("qwen1.5-4b")
    kw = dict(ckpt_dir=str(tmp_path), ckpt_every=4, global_batch=4, seq_len=16)
    a = Trainer(cfg, adamw.AdamWConfig(lr=1e-3), **kw)
    a.init()
    a.run_steps(6)
    a.checkpoint(blocking=True)
    b = Trainer(cfg, adamw.AdamWConfig(lr=1e-3), **kw)
    assert b.maybe_restore() and b.step == a.step
    ma, mb = a.run_steps(3), b.run_steps(3)
    assert ma["loss"] == pytest.approx(mb["loss"], abs=1e-6)


def test_compressed_train_step_converges():
    cfg = get_reduced("llama3-405b")
    from repro.models.api import build_model

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.init(params)
    residual = ef_init(params)
    step = jax.jit(build_compressed_train_step(
        model, adamw.AdamWConfig(lr=1e-3, warmup_steps=5)))
    src = SyntheticTokens(DataConfig(cfg.vocab_size, 32, 8))
    losses = []
    for i in range(12):
        params, opt, residual, m = step(params, opt, residual, src.batch(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


def test_ef_compression_error_feedback():
    g = {"w": jax.numpy.asarray(np.random.default_rng(0).standard_normal((64,)),
                                jax.numpy.float32)}
    res = ef_init(g)
    q, res = ef_compress_tree(g, res)
    deq = ef_decompress_tree(q)
    # residual exactly captures the quantisation error
    np.testing.assert_allclose(
        np.asarray(deq["w"] + res["w"]), np.asarray(g["w"]), atol=1e-6)


def test_compressed_tensor_wire_roundtrip():
    x = np.random.default_rng(1).standard_normal((32, 8)).astype(np.float32)
    ct = CompressedTensor.compress(x)
    out = CompressedTensor.decode(ct.encode())
    np.testing.assert_allclose(out.decompress(), x, atol=ct.scale)
    assert len(ct.encode()) < x.nbytes / 3  # ~4x smaller


# -- fault tolerance -----------------------------------------------------------


def _domain(n=3):
    reg = HandlerRegistry()
    register_internal_handlers(reg)
    reg.init()
    return OffloadDomain.local(n, registry=reg)


def test_heartbeat_detects_dead_node():
    dom = _domain(3)
    failures = []
    mon = HeartbeatMonitor(dom, [1, 2], interval=0.05, timeout=0.4,
                           on_failure=failures.append).start()
    try:
        time.sleep(0.3)
        assert mon.alive() == [1, 2]
        dom._local_workers[0].stop()  # kill node 1's event loop
        deadline = time.monotonic() + 5
        while not failures and time.monotonic() < deadline:
            time.sleep(0.05)
        assert failures == [1]
        assert mon.alive() == [2]
    finally:
        mon.stop()
        dom.shutdown()


def test_straggler_detection():
    det = StragglerDetector(factor=1.5)
    for _ in range(8):
        det.record(0, 0.10)
        det.record(1, 0.11)
        det.record(2, 0.45)
    assert det.stragglers() == [2]


def test_elastic_fleet_reshard_and_admit():
    dom = _domain(4)
    try:
        fleet = ElasticFleet(dom, [1, 2, 3])
        assert fleet.shard_of(2) == (1, 3)
        shard_map = fleet.remove(2)
        assert shard_map == {1: (0, 2), 3: (1, 2)}
        # joining node must present the same key-map digest
        digest = dom.registry.table.digest.hex()
        fleet.admit(2, digest)
        assert fleet.shard_of(2) == (1, 3)
        from repro.core.errors import KeyMapMismatchError
        with pytest.raises(KeyMapMismatchError):
            fleet.admit(5, "00" * 32)
    finally:
        dom.shutdown()


def test_trainer_controllable_over_ham():
    """The paper's mechanism driving training: run/metrics/stop as RPCs."""
    reg = HandlerRegistry()
    register_internal_handlers(reg)
    cfg = get_reduced("olmoe-1b-7b")
    tr = Trainer(cfg, adamw.AdamWConfig(lr=1e-3), global_batch=4, seq_len=16)
    tr.register_handlers(reg)
    reg.init()
    dom = OffloadDomain.local(2, registry=reg)
    try:
        out = dom.sync(1, f2f("train/run_steps", 3, registry=reg), timeout=120)
        assert out["step"] == 3
        m = dom.sync(1, f2f("train/metrics", registry=reg))
        assert m["step"] == 3 and "loss" in m
        assert dom.sync(1, f2f("train/step", registry=reg)) == 3
    finally:
        dom.shutdown()


def test_data_pipeline_determinism_and_sharding():
    cfg = DataConfig(vocab_size=101, seq_len=16, global_batch=8, seed=3)
    a = SyntheticTokens(cfg, shard=0, num_shards=2)
    b = SyntheticTokens(cfg, shard=1, num_shards=2)
    a2 = SyntheticTokens(cfg, shard=0, num_shards=2)
    np.testing.assert_array_equal(a.batch(5)["tokens"], a2.batch(5)["tokens"])
    assert not np.array_equal(a.batch(5)["tokens"], b.batch(5)["tokens"])
    assert a.batch(5)["tokens"].shape == (4, 16)
    # labels are next-token shifted
    ba = a.batch(7)
    assert ba["tokens"].shape == ba["labels"].shape


def test_ckpt_store_gc_and_manifest(tmp_path):
    from repro.ckpt.store import CheckpointStore

    store = CheckpointStore(str(tmp_path), keep=2)
    tree = {"a": np.arange(5), "b": {"c": np.ones((2, 2))}}
    for s in (1, 2, 3):
        store.save(s, tree, meta={"arch": "t"}, blocking=True)
    assert store.list_steps() == [2, 3]  # gc kept last 2
    man = store.manifest(3)
    assert man["arch"] == "t" and man["step"] == 3
    out = store.restore(3, tree)
    np.testing.assert_array_equal(out["a"], tree["a"])
    np.testing.assert_array_equal(out["b"]["c"], tree["b"]["c"])
