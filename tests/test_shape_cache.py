"""Shape-keyed WirePlan cache: signature grammar, LRU behaviour, wire
byte-parity with the static packer, end-to-end value equality with the
cache on and off, and concurrent shape churn.

The cache (``repro.core.wireplan.ShapeCache``) lets dynamic calls whose
argument shapes repeat ride a compiled plan (``FLAG_SHAPED``) instead of
per-leaf TLV — the signature on the wire fully determines the plan, so
both sides compile the same codec independently (the same-source
assumption the paper leans on, extended to shapes discovered at runtime).
"""

from __future__ import annotations

import threading

import numpy as np
import pytest

import repro.offload.demo_handlers  # noqa: F401 — registers demo/* at
#                            collection, before any test seals the registry
from repro.core.errors import MigratableError
from repro.core.migratable import ArraySpec, ScalarSpec, pack_static, spec_of
from repro.core.wireplan import (
    ShapeCache,
    pack_shaped,
    parse_signature,
    spec_signature,
)

# -- signature grammar -------------------------------------------------------


def test_signature_roundtrip_scalars_and_arrays():
    specs = (ScalarSpec("i8"), ScalarSpec("f8"),
             ArraySpec((2, 3), "float64"), ScalarSpec("b1"))
    for arity in ("A", "V", "T"):
        sig = spec_signature(specs, arity)
        got_arity, got_specs = parse_signature(sig)
        assert got_arity == arity
        assert got_specs == specs


def test_signature_is_ascii_and_stable():
    specs = (ScalarSpec("i8"), ArraySpec((4,), "int32"))
    sig = spec_signature(specs, "A")
    assert sig == spec_signature(specs, "A")  # deterministic
    sig.decode("ascii")  # wire bytes stay ascii — header-debugger friendly


@pytest.mark.parametrize("bad", [
    b"",                      # empty
    b"Z(scalar[i8])",         # unknown arity
    b"Ascalar[i8]",           # no parens
    b"A(scalar[zz])",         # unknown scalar kind
    b"A(scalar[i8],junk)",    # unparseable leaf => rebuild mismatch
    b"A(scalar[i8])x",        # trailing garbage
])
def test_malformed_signatures_rejected(bad):
    with pytest.raises(MigratableError):
        parse_signature(bad)


# -- cache behaviour ---------------------------------------------------------


def test_hit_miss_and_eviction_counters():
    cache = ShapeCache(maxsize=4)
    # 6 distinct shapes through a 4-entry cache: evictions must fire
    for n in range(6):
        assert cache.for_values((np.zeros(n + 1),), "A") is not None
    stats = cache.stats()
    assert stats["misses"] == 6
    assert stats["evictions"] == 2
    assert stats["send_entries"] == 4
    # the most recent shape is still resident => hit
    assert cache.for_values((np.zeros(6),), "A") is not None
    assert cache.stats()["hits"] == 1
    # the evicted oldest shape re-misses (and re-evicts)
    cache.for_values((np.zeros(1),), "A")
    assert cache.stats()["misses"] == 7


def test_fast_key_and_spec_path_agree_on_signature():
    """Plain int rides the fast key, np.int64 rides the spec_of path; both
    must map onto the same wire signature (they are the same i8 scalar)."""
    cache = ShapeCache()
    sig_fast, _ = cache.for_values((7,), "A")
    sig_spec, _ = cache.for_values((np.int64(7),), "A")
    assert sig_fast == sig_spec


def test_unspeccable_values_fall_back_to_none():
    cache = ShapeCache()
    assert cache.for_values(("a string",), "A") is None
    assert cache.for_values(([1, 2],), "A") is None
    assert cache.for_values((b"bytes",), "A") is None
    # mixed: ONE bad leaf poisons the whole tuple (TLV carries it all)
    assert cache.for_values((1, "x"), "A") is None


def test_for_result_arities():
    cache = ShapeCache()
    assert cache.for_result(None) is None          # None => TLV
    sig_v, _ = cache.for_result(3.5)               # bare value => "V"
    assert sig_v.startswith(b"V")
    sig_t, _ = cache.for_result((1, 2.0))          # tuple => "T"
    assert sig_t.startswith(b"T")


# -- wire parity -------------------------------------------------------------


def test_shaped_payload_packed_section_matches_pack_static():
    """The plan-packed section of a FLAG_SHAPED payload must be
    byte-identical to the legacy ``pack_static`` encoding of the same
    values under the same specs — the receiver's compiled plan and a
    pre-plan decoder must agree on every byte."""
    values = (3, 2.5, np.arange(6, dtype=np.float64).reshape(2, 3))
    specs = tuple(spec_of(v) for v in values)
    cache = ShapeCache()
    sig, plan = cache.for_values(values, "A")
    payload = pack_shaped(sig, plan, values)
    packed_section = bytes(payload[2 + len(sig):])
    assert packed_section == bytes(pack_static(list(values), specs))


def test_unpack_shaped_roundtrip():
    values = (1, 2.0, np.ones((3, 2), dtype=np.int32))
    cache = ShapeCache()
    sig, plan = cache.for_values(values, "A")
    out = cache.unpack_shaped(bytes(pack_shaped(sig, plan, values)),
                              expect_args=True)
    assert out[0] == 1 and out[1] == 2.0
    np.testing.assert_array_equal(out[2], values[2])
    # reply-side arity convention: V unwraps to the bare value
    sig_v, plan_v = cache.for_result(4.25)
    assert cache.unpack_shaped(
        bytes(pack_shaped(sig_v, plan_v, (4.25,))), expect_args=False
    ) == 4.25


# -- end-to-end: cache on vs off ---------------------------------------------


def _domain_result(shape_cache: bool):
    from repro.core.closure import f2f
    from repro.core.registry import default_registry
    from repro.offload.api import OffloadDomain

    reg = default_registry()
    if not reg.initialised:
        reg.init()
    dom = OffloadDomain.local(2, inline_host=True)
    # flip BOTH ends in-process (local domain shares the process)
    dom.host._shape_cache = ShapeCache() if shape_cache else None
    for w in dom._local_workers:
        w._shape_cache = ShapeCache() if shape_cache else None
    try:
        call = f2f("demo/add", np.arange(4.0), np.full(4, 2.0))
        return [dom.sync(1, call) for _ in range(3)]
    finally:
        dom.shutdown()


def test_end_to_end_values_identical_cache_on_and_off():
    on = _domain_result(shape_cache=True)
    off = _domain_result(shape_cache=False)
    for a, b in zip(on, off):
        np.testing.assert_array_equal(a, b)


def test_env_toggle_disables_cache(monkeypatch):
    from repro.core.registry import default_registry
    from repro.offload.api import OffloadDomain

    reg = default_registry()
    if not reg.initialised:
        reg.init()
    monkeypatch.setenv("HAM_SHAPE_CACHE", "0")
    dom = OffloadDomain.local(2, inline_host=True)
    try:
        assert dom.host._shape_cache is None
    finally:
        dom.shutdown()
    monkeypatch.setenv("HAM_SHAPE_CACHE", "1")
    dom = OffloadDomain.local(2, inline_host=True)
    try:
        assert dom.host._shape_cache is not None
    finally:
        dom.shutdown()


# -- concurrency -------------------------------------------------------------


def test_concurrent_shape_churn_keeps_cache_consistent():
    """8 threads hammer a 8-entry cache with 32 distinct shapes: every
    lookup must return a usable (sig, plan) pair that round-trips its own
    values, and the entry counts must never exceed the bound — under
    constant eviction racing with lookups."""
    cache = ShapeCache(maxsize=8)
    shapes = [(i % 32) + 1 for i in range(256)]
    errors: list = []

    def churn(tid: int) -> None:
        try:
            for n in shapes:
                values = (tid, float(n), np.zeros(n))
                ent = cache.for_values(values, "A")
                assert ent is not None
                sig, plan = ent
                out = cache.unpack_shaped(
                    bytes(pack_shaped(sig, plan, values)), expect_args=True
                )
                assert out[0] == tid and out[1] == float(n)
                assert len(out[2]) == n
        except Exception as e:  # noqa: BLE001 — surfaced by the main thread
            errors.append(e)

    threads = [threading.Thread(target=churn, args=(t,)) for t in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert not errors, errors[:3]
    stats = cache.stats()
    assert stats["send_entries"] <= 8
    assert stats["recv_entries"] <= 8
    assert stats["evictions"] > 0
