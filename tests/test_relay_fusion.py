"""Relay-aware fusion: FLAG_SEG_SRC segments carry the true origin of
relayed ``_ham/forward`` inner frames through fused egress batches.

A forwarder re-emits inner frames whose ``src_node`` is the *origin*, not
itself.  Pre-SEG_SRC, such frames could not fold into a fused frame (the
fused header has one src for all segments), so multi-hop topologies lost
the small-call fusion win exactly where it matters — at the fan-in relay.
These tests pin the segment layout (u32 origin prefix), the relay's
fold-at-flush behaviour, and the reply contract: the final target answers
the origin directly, never the relay.
"""

from __future__ import annotations

import pytest

import repro.offload.demo_handlers  # noqa: F401 — registers demo/* at
#                            collection, before any test seals the registry
from repro.comm.local import LocalFabric
from repro.core.closure import f2f
from repro.core.message import (
    FLAG_DYNAMIC,
    FLAG_FUSED,
    FLAG_SEG_SRC,
    FLAG_STATIC,
    HEADER_NBYTES,
    HEADER_STRUCT,
    SEG_SRC_NBYTES,
    SEG_SRC_STRUCT,
    encode_frame,
    iter_fused,
)
from repro.core.registry import default_registry
from repro.offload.api import OffloadDomain
from repro.offload.runtime import FUSE_THRESHOLD, NodeRuntime


def _ready_registry():
    reg = default_registry()
    if not reg.initialised:
        reg.init()
    return reg


def _inline_runtime(node_id: int, num_nodes: int = 3) -> NodeRuntime:
    reg = _ready_registry()
    fab = LocalFabric(num_nodes)
    return NodeRuntime(node_id, fab.endpoint(node_id), reg.table, inline=True)


# -- segment layout ----------------------------------------------------------


def test_fuse_frames_prefixes_foreign_src_segments():
    """A frame whose src_node is not the fusing node becomes a FLAG_SEG_SRC
    segment: u32 true-origin prefix, original flags/msg_id/payload intact.
    Own frames stay plain segments — no prefix tax on the common case."""
    rt = _inline_runtime(node_id=1)
    key = rt.table.key_of("demo/empty_static")
    own = bytes(encode_frame(key, b"", src_node=1, msg_id=0,
                             flags=FLAG_STATIC))
    payload = b"\xaa" * 24
    foreign = bytes(encode_frame(key, payload, src_node=0, msg_id=7,
                                 flags=FLAG_DYNAMIC))

    fused = rt._fuse_frames([own, foreign])
    _, _, flags, _, src, _, _ = HEADER_STRUCT.unpack_from(fused, 0)
    assert flags & FLAG_FUSED
    assert src == 1  # outer header: the fusing node
    segs = list(iter_fused(memoryview(fused)[HEADER_NBYTES:]))
    assert len(segs) == 2

    k0, f0, m0, p0 = segs[0]
    assert (k0, m0) == (key, 0)
    assert not f0 & FLAG_SEG_SRC
    assert len(p0) == 0

    k1, f1, m1, p1 = segs[1]
    assert (k1, m1) == (key, 7)
    assert f1 & FLAG_SEG_SRC and f1 & FLAG_DYNAMIC
    (origin,) = SEG_SRC_STRUCT.unpack_from(p1, 0)
    assert origin == 0
    assert bytes(p1[SEG_SRC_NBYTES:]) == payload
    assert rt.stats["fused"] == 2


def test_fusible_accepts_foreign_src_not_large_or_fused():
    rt = _inline_runtime(node_id=1)
    key = rt.table.key_of("demo/empty_static")
    small_foreign = bytes(encode_frame(key, b"x" * 16, src_node=0,
                                       flags=FLAG_DYNAMIC))
    assert rt._fusible(small_foreign)
    big = bytes(encode_frame(key, b"x" * (FUSE_THRESHOLD + 1), src_node=1))
    assert not rt._fusible(big)
    already_fused = rt._fuse_frames([small_foreign, small_foreign])
    assert not rt._fusible(already_fused)


# -- env toggle --------------------------------------------------------------


def test_fuse_egress_env_toggle(monkeypatch):
    reg = _ready_registry()
    fab = LocalFabric(2)
    monkeypatch.setenv("HAM_FUSE_EGRESS", "0")
    rt = NodeRuntime(0, fab.endpoint(0), reg.table, inline=True)
    assert rt.fuse_egress is False
    monkeypatch.setenv("HAM_FUSE_EGRESS", "1")
    rt2 = NodeRuntime(1, fab.endpoint(1), reg.table, inline=True)
    assert rt2.fuse_egress is True


# -- end to end: host -> relay -> target -------------------------------------


def test_fused_forward_batch_folds_at_relay_and_executes():
    """One fused frame of K ``_ham/forward`` oneways hits the relay; the K
    re-emitted inner frames must leave the relay FUSED (stats['fused']
    grows by >= K there) and every inner call must execute exactly once at
    the target — counted by the mutating chaos/bump probe."""
    dom = OffloadDomain.local(3, inline_host=True)
    token = 918273
    k = 24
    try:
        relay_rt = dom._inproc[1]
        fused_before = relay_rt.stats["fused"]
        base = dom.sync(2, f2f("chaos/counts", token))

        bump = f2f("chaos/bump", token)
        inner = bytes(encode_frame(
            dom._table.key_of(bump.record.stable_name),
            bump.pack_payload(),
            src_node=dom.host_node,
            msg_id=0,  # oneway inner: no reply expected
            flags=FLAG_DYNAMIC,
        ))
        futs = dom.host.send_fused(1, [f2f("_ham/forward", 2, inner)] * k)
        for fut in futs:
            dom.host._inline_wait(fut, 30.0)
        # FIFO completion barrier on the relay->target link: the relayed
        # ping travels 1 -> 2 *behind* the fused inner batch
        dom.host._inline_wait(dom.relay(1, 2, f2f("_ham/ping", 0)), 30.0)

        # thread-fabric nodes share the process-wide counter dict
        assert dom.sync(2, f2f("chaos/counts", token)) == base + k
        assert relay_rt.stats["fused"] - fused_before >= k, (
            "relay re-emitted the inner frames unfused — relay-aware "
            "fusion is not folding foreign-src frames"
        )
    finally:
        dom.sync(2, f2f("chaos/reset", token))
        dom.shutdown()


def test_seg_src_requests_reply_to_true_origin():
    """Relayed inner frames carrying live msg_ids: the target decodes the
    FLAG_SEG_SRC origin and replies to the ORIGIN (host), not the relay —
    every host future resolves with its own call's result."""
    dom = OffloadDomain.local(3, inline_host=True)
    n = 12
    try:
        created = [dom.host.futures.create() for _ in range(n)]
        forwards = []
        for i, (msg_id, _fut) in enumerate(created):
            fn = f2f("demo/add", i, 7)
            inner = bytes(encode_frame(
                dom._table.key_of(fn.record.stable_name),
                fn.pack_payload(),
                src_node=dom.host_node,
                msg_id=msg_id,
                flags=FLAG_DYNAMIC,
            ))
            forwards.append(f2f("_ham/forward", 2, inner))
        outer = dom.host.send_fused(1, forwards)
        results = [dom.host._inline_wait(fut, 30.0) for _, fut in created]
        assert results == [i + 7 for i in range(n)]
        for fut in outer:  # the forward oneway-acks themselves
            dom.host._inline_wait(fut, 30.0)
        assert dom._inproc[1].stats["fused"] >= n
    finally:
        dom.shutdown()


def test_relay_reply_routing_unfused_baseline():
    """The pre-fusion relay contract still holds for singleton forwards:
    request host -> via -> dst, reply dst -> host directly."""
    dom = OffloadDomain.local(3, inline_host=True)
    try:
        futs = [dom.relay(1, 2, f2f("demo/add", i, 100)) for i in range(8)]
        got = [dom.host._inline_wait(f, 30.0) for f in futs]
        assert got == [i + 100 for i in range(8)]
    finally:
        dom.shutdown()


# -- guard: fused relay must not over-execute under retry flags --------------


@pytest.mark.chaos
def test_relayed_fused_bumps_execute_exactly_once():
    """Exactly-once witness at fusion density: 4 fused forward batches of
    the same mutating probe; the cluster-wide counter total must equal the
    number of logical calls (no duplication through the SEG_SRC path)."""
    dom = OffloadDomain.local(3, inline_host=True)
    token = 424242
    batches, per_batch = 4, 16
    try:
        base = dom.sync(2, f2f("chaos/counts", token))
        bump = f2f("chaos/bump", token)
        inner = bytes(encode_frame(
            dom._table.key_of(bump.record.stable_name),
            bump.pack_payload(),
            src_node=dom.host_node,
            msg_id=0,
            flags=FLAG_DYNAMIC,
        ))
        for _ in range(batches):
            futs = dom.host.send_fused(
                1, [f2f("_ham/forward", 2, inner)] * per_batch
            )
            for fut in futs:
                dom.host._inline_wait(fut, 30.0)
        dom.host._inline_wait(dom.relay(1, 2, f2f("_ham/ping", 0)), 30.0)
        total = dom.sync(2, f2f("chaos/counts", token))
        assert total == base + batches * per_batch
    finally:
        dom.sync(2, f2f("chaos/reset", token))
        dom.shutdown()
