"""Offload patterns beyond Fig. 2: reverse offload, relay (offload over
fabric), fire-and-forget, and int8-compressed tensors as message payloads.

    python examples/offload_pipeline.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import repro.core as ham
from repro.core.closure import f2f
from repro.offload.api import OffloadDomain, deref
from repro.offload.runtime import current_node
from repro.optim.compression import CompressedTensor


@ham.handler
def stage_scale(ptr, alpha):
    deref(ptr)[:] *= alpha


@ham.handler
def reverse_report(host_node, value):
    """Worker -> host callback (reverse offload)."""
    node = current_node()
    fut = node.send_async(host_node, f2f("_ham/ping", int(value)))
    return node.wait(fut, 10.0)


@ham.handler
def receive_compressed(ct):
    """Gradient-style payload: int8 + scale on the wire, fp32 at use."""
    x = ct.decompress()
    return float(np.linalg.norm(x))


def main():
    ham.init()
    dom = OffloadDomain.local(num_nodes=3)

    # pipeline: host puts data on node 1, node-hops work 1 -> 2
    data = np.linspace(0, 1, 4096)
    ptr = dom.allocate(1, data.shape, "float64")
    dom.put(data, ptr)
    dom.sync(1, f2f(stage_scale, ptr, 2.0))
    print("stage 1 done; relay stage 2 via node 1 -> node 2")
    fut = dom.relay(via=1, dst=2, function=f2f("_ham/ping", 99))
    print("relay reply:", fut.get(10))

    # reverse offload: the worker calls back into the host mid-handler
    print("reverse offload:", dom.sync(2, f2f(reverse_report, 0, 42)))

    # compressed tensor payload (the migratable<T> hook in action)
    g = np.random.default_rng(0).standard_normal(65536).astype(np.float32)
    ct = CompressedTensor.compress(g)
    remote_norm = dom.sync(1, f2f(receive_compressed, ct))
    print(f"compressed-grad norm on worker: {remote_norm:.2f} "
          f"(exact {np.linalg.norm(g):.2f}; wire {len(ct.encode())/g.nbytes:.0%} of fp32)")

    dom.shutdown()
    print("OK")


if __name__ == "__main__":
    main()
