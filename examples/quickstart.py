"""Quickstart: HAM in 60 lines — the paper's Fig. 2 program.

Registers handlers (static initialisation), seals the key map (init), spins
up an offload domain with one worker, and runs the inner-product offload:

    python examples/quickstart.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import repro.core as ham
from repro.core.closure import f2f
from repro.offload.api import OffloadDomain, deref


# --- static initialisation: register handlers (every process, same source)
@ham.handler
def inner_prod(a_ptr, b_ptr, n):
    a, b = deref(a_ptr), deref(b_ptr)       # valid on the owning node only
    return float(a[:n] @ b[:n])


def main():
    table = ham.init()                       # sort -> keys, no communication
    print(f"handler table: {len(table)} handlers, "
          f"digest {table.digest.hex()[:16]}…")

    dom = OffloadDomain.local(num_nodes=2)   # host + one worker
    target = 1

    # host memory
    n = 1024
    a = np.arange(n, dtype=np.float64)
    b = np.full(n, 0.5)

    # target memory (PGAS buffer_ptr smart pointers)
    a_t = dom.allocate(target, (n,), "float64")
    b_t = dom.allocate(target, (n,), "float64")
    dom.put(a, a_t)
    dom.put(b, b_t)

    # async offload, returns a future
    result = dom.async_(target, f2f(inner_prod, a_t, b_t, n))
    # ... do something in parallel on the host ...
    c = result.get(timeout=10)
    print(f"inner product on worker: {c}   (expected {a @ b})")
    assert c == a @ b

    dom.shutdown()
    print("OK")


if __name__ == "__main__":
    main()
