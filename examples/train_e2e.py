"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred
steps on synthetic Zipf-Markov data, with checkpointing and restart.

    python examples/train_e2e.py [--steps 300] [--restart-demo]
"""

import argparse
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

from repro.configs import get_config
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.train.loop import Trainer

# ~100M params: a llama-family stack scaled to laptop size
CFG_100M = ModelConfig(
    name="demo-100m", family="dense", num_layers=12, d_model=512,
    num_heads=8, num_kv_heads=4, d_ff=2048, vocab_size=50304,
    dtype="float32", param_dtype="float32", remat="none",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="/tmp/ham_train_e2e")
    ap.add_argument("--restart-demo", action="store_true")
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=256)
    args = ap.parse_args()

    from repro.models.counting import count_params
    n = count_params(CFG_100M)
    print(f"model: {CFG_100M.name}  N={n/1e6:.1f}M params")

    tr = Trainer(CFG_100M, AdamWConfig(lr=3e-4, warmup_steps=50),
                 ckpt_dir=args.ckpt_dir, ckpt_every=50,
                 global_batch=args.global_batch, seq_len=args.seq_len)
    if not tr.maybe_restore():
        tr.init()
        print("fresh start")
    else:
        print(f"restored from step {tr.step}")

    while tr.step < args.steps:
        m = tr.run_steps(args.log_every)
        print(f"step {m['step']:5d}  loss {m['loss']:.4f}  "
              f"gnorm {m['grad_norm']:.3f}  lr {m['lr']:.2e}  "
              f"({args.log_every / m['wall_s']:.2f} it/s)")
        if args.restart_demo and tr.step == 100:
            print(">> simulating failure: dropping trainer, restoring from ckpt")
            tr.checkpoint(blocking=True)
            tr = Trainer(CFG_100M, AdamWConfig(lr=3e-4, warmup_steps=50),
                         ckpt_dir=args.ckpt_dir, ckpt_every=50,
                         global_batch=args.global_batch, seq_len=args.seq_len)
            assert tr.maybe_restore()
            print(f">> resumed at step {tr.step}")

    tr.checkpoint(blocking=True)
    print("final loss:", tr.latest_metrics()["loss"])


if __name__ == "__main__":
    main()
