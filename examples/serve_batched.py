"""Serving driver: continuous batching through the HAM device dispatch
table (greedy + sampled requests in one fleet).

    python examples/serve_batched.py [--arch olmoe-1b-7b] [--requests 8]
"""

import argparse
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_reduced
from repro.models.api import build_model
from repro.serve.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-1b-7b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, num_slots=args.slots, max_len=64)
    print(f"arch={cfg.name}  dispatch table: "
          f"{[h.stable_name for h in eng.table.handlers]}")

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(3, 12))
        reqs.append(Request(
            prompt=rng.integers(0, cfg.vocab_size, plen),
            max_new_tokens=int(rng.integers(4, args.max_new)),
            temperature=0.0 if i % 2 == 0 else 0.9,
        ))
    t0 = time.perf_counter()
    out = eng.run(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(v) for v in out.values())
    for rid in sorted(out):
        mode = "greedy" if reqs[rid].temperature == 0 else "sample"
        print(f"req {rid} [{mode:6s}] -> {out[rid]}")
    print(f"{total} tokens in {dt:.2f}s over {eng.steps_dispatched} batched "
          f"steps ({total/dt:.1f} tok/s, {total/eng.steps_dispatched:.2f} "
          f"tokens/step batching efficiency)")


if __name__ == "__main__":
    main()
