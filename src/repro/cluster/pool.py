"""Cluster worker pool: lifecycle + liveness for a set of HAM offload nodes.

HAM-Offload (paper §2) targets one hand-picked node per call; this module
supplies the fleet underneath a :class:`~repro.cluster.scheduler.Scheduler`:

* :class:`ClusterPool` owns one fabric's worth of workers — in-process
  threads (``local``), forked processes over shared-memory rings (``shm``,
  the SCIF/DMA analogue), or fresh interpreters over TCP (``socket``, the
  heterogeneous-binaries case);
* a monitor thread watches liveness and announces deaths to subscribers
  (the scheduler fails that node's in-flight futures and reroutes);
* writes to replicated buffers ride **chain replication** (`put`, and the
  ``_migrate_off``/backfill copies): bytes leave the host once and the
  holders forward them peer-to-peer — see "Replicated data plane" below;
* dead workers can be restarted in place (``auto_restart=True`` or an
  explicit :meth:`ClusterPool.restart`): the fabric drops frames queued
  toward the corpse, the host endpoint forgets stale transport state, and a
  replacement attaches under the same node id;
* :meth:`ClusterPool.close` reaps every child and tears the fabric down —
  together with ``ShmFabric``'s atexit unlink this is the fix for the
  ``/dev/shm`` segment leak when a child dies mid-run.

Fault-injection helpers (``kill``) are first-class: a scheduler that cannot
be tested against a dying worker cannot be trusted with one.

Elastic membership protocol (grow/shrink under live traffic)
------------------------------------------------------------

The paper fixes the node set at MPI startup and names that as a limitation;
here membership is runtime state, in the spirit of HPX's AGAS.  Node ids
are **monotonic and never reused** — a retired id stays invalid forever, so
a straggler frame addressed to it fails fast instead of reaching an
unrelated replacement.

:meth:`ClusterPool.add_node` (host-driven, in order):

1. ``fabric.add_node()`` provisions transport resources (shm ring pairs, a
   port) for the next id;
2. the host endpoint attaches the id (``attach_peer``);
3. every live worker is told ``_cluster/attach_peer`` as a **sync** call —
   when step 4 starts, every survivor can already address the newcomer
   (the same broadcast role ``restart`` plays with ``_cluster/reset_peer``);
4. the worker is spawned (same launch mode as the pool), pinged (startup
   barrier), and its key-map digest is verified against the host table
   (``verify_peer_digest`` — elastic join re-checks the same-source
   assumption that static startup checked implicitly);
5. ``on_join`` subscribers run (the scheduler creates the node's
   credit/in-flight/stats entries atomically under its lock).

:meth:`ClusterPool.remove_node` (the reverse, with a drain fence):

1. ``on_leave`` subscribers run first — the scheduler *fences* the node
   (no new submits route to it) and returns a drain waiter;
2. with ``drain=True`` the waiter blocks until the node's in-flight futures
   finish (the worker is still alive and replying); with ``drain=False``
   the death path fails them immediately;
3. the worker gets ``_ham/terminate`` and is reaped;
4. the host endpoint and every surviving worker ``detach_peer`` the id
   (broadcast ``_cluster/detach_peer``), and ``fabric.remove_node``
   reclaims its resources.

Workers report executor queue depth to the host as ``_cluster/stats``
oneways (see ``NodeRuntime.enable_depth_report``); the scheduler folds the
reports into ``least_outstanding`` so host-side in-flight counts are
corrected by what is actually queued behind each worker.

Replicated data plane (ownership epochs; full protocol in
``repro.offload.dataplane``)
------------------------------------------------------------------------

Every pool owns a :class:`BufferDirectory` and exposes a directory-tracked
data plane: :meth:`allocate` places a buffer's primary on a live worker
(round-robin unless pinned) and installs ``replicas=N`` empty copies under
the SAME global handle on other workers (``_ham/buf_adopt``); :meth:`put`
**writes through every holder by chain replication** — the bytes go to
the primary once (zero-copy chunked pipeline) and the primary streams
them to the replicas over worker->worker links, each write sequenced by a
directory-minted dirty epoch (``repro.offload.dataplane``, "Chain
replication") — so copies never diverge and the host is off the
replication path; :meth:`get`/:meth:`free` resolve stale pointers through
the directory first.  A handler registered ``mutates=True`` writes the
primary in place and :meth:`commit_mutation` restores coherence
(invalidate or chain-refresh the replicas).  The failure/elasticity
contract:

* **crash** — the monitor's death announcement runs the directory's
  metadata-only promotion *before* any external subscriber: each affected
  buffer's lowest-id replica becomes primary, its epoch bumps (old
  pointers are now stale and re-resolve transparently at submit), and
  sessions bound to moved buffers repin onto the node holding their bytes;
  buffers with no replica are recorded lost and resolve loudly;
* **shrink** — ``remove_node(drain=True)`` migrates every primary off the
  leaving node before the scheduler fence (promoting an existing replica
  when one holds the bytes — zero copy — else streaming to a survivor),
  backfills the replicas it held, and detaches it from the directory:
  shrink is lossless.  ``drain=False`` takes the crash path (replicas
  promote, replica-less buffers are lost — that is what drain is for);
* **join/restart** — lazy backfill: buffers left under-replicated by
  earlier deaths copy one replica onto the joiner.

Write-through :meth:`put` (and :meth:`free`) serialise against every
byte-copying holder-set mutation — join/restart backfill and drain
migration — on a handle-striped data-plane lock: a holder created from a
pre-put snapshot of the bytes either finishes registering before the put
(which then writes through it too) or copies after the put and sees the
new bytes, so a promotable holder can never silently hold stale data.
No caller-side write quiescing is required around ``remove_node`` or
``add_node``.

Handler-side buffer writes are write-through only when DECLARED: a
``mutates=True`` handler runs at the primary and its commit
(:meth:`commit_mutation`, driven by the scheduler) bumps the dirty epoch
and invalidates or chain-refreshes the replica holders.  A handler that
is neither ``read_only`` nor ``mutates`` and mutates through ``deref``
leaves the replicas at the last put until the caller re-puts (the routing
contract in ``repro.offload.dataplane``; the scheduler logs a one-shot
warning for such calls — see docs/failure-model.md, "Write visibility
and convergence").
"""

from __future__ import annotations

import threading
import time

import numpy as np

from repro.comm.local import LocalFabric
from repro.core import migratable as mig
from repro.core.closure import Function, f2f
from repro.core.errors import OffloadError, RegistrySealedError
from repro.core.executor import DirectPolicy
from repro.core.registry import default_registry, verify_peer_digest
from repro.offload.api import OffloadDomain
from repro.offload.buffer import BufferPtr
from repro.offload.dataplane import (
    BufferDirectory,
    BufferRecord,
    register_dataplane_handlers,
    tracked_handles,
)
from repro.offload.runtime import NodeRuntime, ReplayCache
from repro.offload.worker import (
    reap,
    spawn_shm_workers,
    spawn_socket_worker_subprocess,
)


# --------------------------------------------------------------------------
# pool-exercisable handlers (registered at import = static initialisation,
# like runtime's _ham/* set) — used by benchmarks and liveness tests
# --------------------------------------------------------------------------


def _h_sleep(seconds):
    """Blocking I/O stand-in: holds a worker busy without burning CPU."""
    time.sleep(float(seconds))
    return float(seconds)


def _h_spin(n):
    """CPU-bound stand-in: a bounded arithmetic loop."""
    x = 0
    for i in range(int(n)):
        x += i
    return x


def _h_touch(ptr):
    """Data-local stand-in: dereference a buffer_ptr and reduce it — only
    executable on the owning node, so it exercises locality routing."""
    from repro.offload.api import deref

    return float(deref(ptr).sum())


def _h_reset_peer(node_id):
    """Drop this node's cached transport toward a restarted peer — relays
    (offload over fabric) cache worker->worker connections the host's own
    reset cannot reach."""
    from repro.offload.runtime import current_node

    current_node().endpoint.reset_peer(int(node_id))


def _h_attach_peer(node_id):
    """Membership broadcast (grow): make ``node_id`` addressable from this
    node.  Called sync so the host knows every survivor attached BEFORE the
    newcomer spawns (protocol step 3 in the module docs)."""
    from repro.offload.runtime import current_node

    current_node().endpoint.attach_peer(int(node_id))


def _h_detach_peer(node_id):
    """Membership broadcast (shrink): retire ``node_id`` on this node —
    drop its transport state; later sends toward it fail fast."""
    from repro.offload.runtime import current_node

    current_node().endpoint.detach_peer(int(node_id))


def _h_stats(node_id, depth):
    """Queue-depth report (oneway): a worker's executor backlog, folded into
    the receiving node's ``peer_depth`` for depth-aware scheduling."""
    from repro.offload.runtime import current_node

    current_node().note_peer_depth(int(node_id), int(depth))


def _h_digest():
    """Key-map digest of this node's handler table (hex) — lets an elastic
    join *verify* the paper's same-source assumption (registry docs)."""
    from repro.offload.runtime import current_node

    return current_node().table.digest.hex()


def register_cluster_handlers(registry=None) -> None:
    """Register the pool's control + demo/probe handlers (plus the
    ``_ham/buf_*`` dataplane control set).  Safe to call repeatedly;
    silently skipped on an already-sealed registry (then callers must have
    registered these before ``init()`` themselves)."""
    reg = registry or default_registry()
    register_dataplane_handlers(reg)
    for name, fn, read_only in (
        ("_cluster/sleep", _h_sleep, False),
        ("_cluster/spin", _h_spin, False),
        # touch only READS through its pointer, so it may be served from
        # any replica (the dataplane's read-only routing contract)
        ("_cluster/touch", _h_touch, True),
        ("_cluster/reset_peer", _h_reset_peer, False),
        ("_cluster/attach_peer", _h_attach_peer, False),
        ("_cluster/detach_peer", _h_detach_peer, False),
        ("_cluster/stats", _h_stats, False),
        ("_cluster/digest", _h_digest, False),
    ):
        try:
            reg.register(fn, name=name, read_only=read_only)
        except RegistrySealedError:
            return


register_cluster_handlers()


# --------------------------------------------------------------------------
# worker handles (one per launch mode)
# --------------------------------------------------------------------------


class _ThreadWorker:
    """In-process worker: a NodeRuntime on its own event-loop thread."""

    def __init__(self, node_id: int, runtime: NodeRuntime, pool: "ClusterPool"):
        self.node_id = node_id
        self.runtime = runtime
        self._pool = pool

    def alive(self) -> bool:
        t = self.runtime._thread
        return t is not None and t.is_alive()

    def kill(self) -> None:
        # closest analogue of a crash for a thread: stop the event loop cold
        self.runtime.request_stop()

    def reap(self, timeout: float = 5.0) -> None:
        self.runtime.stop(timeout)

    def respawn(self) -> "_ThreadWorker":
        pool = self._pool
        rt = NodeRuntime(
            self.node_id,
            pool.fabric.endpoint(self.node_id),
            pool.domain._table,
            policy=pool._policy_factory(),
        ).enable_depth_report(dst=pool.domain.host_node).start()
        pool.domain._inproc[self.node_id] = rt  # direct data plane follows
        return _ThreadWorker(self.node_id, rt, pool)


class _ForkWorker:
    """Forked child over shm rings (spawn_shm_workers)."""

    def __init__(self, node_id: int, proc, pool: "ClusterPool"):
        self.node_id = node_id
        self.proc = proc
        self._pool = pool

    def alive(self) -> bool:
        return self.proc.is_alive()

    def kill(self) -> None:
        self.proc.kill()

    def reap(self, timeout: float = 5.0) -> None:
        reap([self.proc], timeout)

    def respawn(self) -> "_ForkWorker":
        pool = self._pool
        proc = spawn_shm_workers(pool.fabric, [self.node_id],
                                 pool._setup_modules)[0]
        return _ForkWorker(self.node_id, proc, pool)


class _SubprocessWorker:
    """Fresh-interpreter child over TCP (spawn_socket_worker_subprocess)."""

    def __init__(self, node_id: int, popen, pool: "ClusterPool"):
        self.node_id = node_id
        self.proc = popen
        self._pool = pool

    def alive(self) -> bool:
        return self.proc.poll() is None

    def kill(self) -> None:
        self.proc.kill()

    def reap(self, timeout: float = 5.0) -> None:
        reap([self.proc], timeout)

    def respawn(self) -> "_SubprocessWorker":
        pool = self._pool
        popen = spawn_socket_worker_subprocess(
            self.node_id, pool.fabric.num_nodes, pool.fabric.base_port,
            pool._setup_modules,
        )
        return _SubprocessWorker(self.node_id, popen, pool)


# --------------------------------------------------------------------------
# the pool
# --------------------------------------------------------------------------


class ClusterPool:
    """Owns the workers of one offload domain and watches them.

    Subscribers (``on_death`` / ``on_restart``) are called from the monitor
    thread with the node id; the scheduler uses these to fail in-flight
    futures and to re-admit a node into the routing set.  Callbacks must not
    block — they run on the liveness path.
    """

    def __init__(
        self,
        domain: OffloadDomain,
        workers: dict,
        *,
        monitor_interval: float = 0.1,
        auto_restart: bool = False,
        setup_modules=None,
        policy_factory=DirectPolicy,
        mode: str = "local",
        replicas: int = 0,
        mutation_refresh: bool = False,
        restart_backoff: float = 0.5,
        restart_backoff_max: float = 8.0,
        max_restarts: int = 5,
        fail_window: float = 30.0,
        quarantine_probe: float = 5.0,
    ):
        self.domain = domain
        self.fabric = domain.fabric
        self.host = domain.host
        self._mode = mode  # launch mode for elastic spawns (local/shm/socket)
        self._workers = dict(workers)
        self._dead: set[int] = set()
        self._removing: set[int] = set()  # mid-remove: no auto_restart
        self._lock = threading.Lock()
        self._resize_lock = threading.Lock()  # serialises add/remove/restart
        self._death_cbs: list = []
        self._restart_cbs: list = []
        self._join_cbs: list = []
        self._leave_cbs: list = []
        #: replication factor for the directory-tracked data plane (module
        #: docs, "Replicated data plane"); 0 = primaries only
        self.replicas = int(replicas)
        #: after a ``mutates=True`` handler commits: False (default) drops
        #: the replica copies (metadata-only invalidate, lazy re-backfill);
        #: True chain-refreshes them from the primary (commit_mutation docs)
        self.mutation_refresh = bool(mutation_refresh)
        #: thread-local gossip batching (``_gossip_batch``): oneway storms
        #: produced under it coalesce into one FLAG_FUSED frame per dst
        self._gossip_tls = threading.local()
        self.directory = BufferDirectory()
        self.host.buffer_directory = self.directory  # _ham/buf_freed target
        self._alloc_rr = 0  # round-robin primary placement for allocate()
        # serialises write-through puts/frees against holder-set mutation
        # that COPIES bytes (join/restart backfill, drain migration): a
        # holder added from a pre-put snapshot of the bytes must not become
        # promotable without also receiving the put (put's divergence guard).
        # Striped by handle — the invariant is per buffer, and a migration
        # copy can hold its lock across a multi-second network transfer;
        # striping keeps puts/frees to unrelated buffers from stalling
        # behind it except on a (1-in-64) stripe collision, which merely
        # waits, never deadlocks
        self._dataplane_locks = tuple(threading.Lock() for _ in range(64))
        # the directory's failover MUST run before any external death
        # subscriber (the scheduler repins sessions onto post-promotion
        # placement) — subscribe first, before the monitor can announce
        self.on_death(self._dataplane_on_death)
        self.on_join(self._dataplane_on_join)
        self.on_restart(self._dataplane_on_join)
        #: None => auto-derive from the host registry at each spawn
        #: (registered_setup_modules), so restarts track late registrations
        self._setup_modules = (
            None if setup_modules is None else list(setup_modules)
        )
        self._policy_factory = policy_factory
        self.auto_restart = auto_restart
        # -- auto-restart circuit breaker (module docs) --------------------
        #: first-retry delay; doubles per consecutive failure, capped below
        self.restart_backoff = float(restart_backoff)
        self.restart_backoff_max = float(restart_backoff_max)
        #: consecutive failures within ``fail_window`` that trip quarantine
        self.max_restarts = int(max_restarts)
        self.fail_window = float(fail_window)
        #: cool-down before a quarantined worker's first half-open probe
        self.quarantine_probe = float(quarantine_probe)
        self._restart_fails: dict[int, int] = {}
        self._last_fail_t: dict[int, float] = {}
        self._pending_restart: dict[int, float] = {}  # node -> due (monotonic)
        self._quarantined: set[int] = set()
        self._probe_at: dict[int, float] = {}
        self._probe_iv: dict[int, float] = {}
        # -- directory gossip (durable directory; offload.dataplane docs) --
        self.directory.on_change(self._gossip_change)
        self._closed = False
        self._stop = threading.Event()
        self._monitor = threading.Thread(
            target=self._monitor_loop, args=(monitor_interval,),
            name="ham-cluster-monitor", daemon=True,
        )
        self._monitor.start()

    # -- constructors ------------------------------------------------------

    @classmethod
    def local(cls, num_workers: int, *, registry=None,
              policy_factory=DirectPolicy, wrap_fabric=None,
              **kw) -> "ClusterPool":
        """Thread workers in this process (node 0 is the host).

        ``wrap_fabric=`` (all three constructors) wraps the fabric before
        any endpoint is handed out — e.g. ``lambda f:
        ChaosFabric(f, seed=7)`` puts every link under seeded fault
        injection (``repro.comm.chaos``).
        """
        reg = registry or default_registry()
        fabric = LocalFabric(num_workers + 1)
        if wrap_fabric is not None:
            fabric = wrap_fabric(fabric)
        domain = OffloadDomain(fabric, registry=reg,
                               policy_factory=policy_factory)
        pool = cls.__new__(cls)
        workers = {}
        for node in range(1, num_workers + 1):
            rt = NodeRuntime(node, fabric.endpoint(node), domain._table,
                             policy=policy_factory()).enable_depth_report(
                dst=domain.host_node).start()
            domain._inproc[node] = rt  # direct put/get shortcut stays live
            workers[node] = _ThreadWorker(node, rt, pool)
        pool.__init__(domain, workers, policy_factory=policy_factory,
                      mode="local", **kw)
        return pool

    @classmethod
    def shm(cls, num_workers: int, *, registry=None, capacity: int = 1 << 24,
            setup_modules=None, wrap_fabric=None, **kw) -> "ClusterPool":
        """Forked processes over shared-memory rings.

        ``setup_modules=None`` auto-derives the worker import list from the
        host's default registry (same-source key agreement by construction).
        ``wrap_fabric=`` as in :meth:`local` — forked workers inherit the
        wrapper, so both directions of every link are under fault injection.
        """
        from repro.comm.shm import ShmFabric

        reg = registry or default_registry()
        fabric = ShmFabric(num_workers + 1, capacity=capacity)
        if wrap_fabric is not None:
            fabric = wrap_fabric(fabric)
        procs = spawn_shm_workers(fabric, list(range(1, num_workers + 1)),
                                  setup_modules)
        domain = OffloadDomain(fabric, registry=reg)
        pool = cls.__new__(cls)
        workers = {
            node: _ForkWorker(node, proc, pool)
            for node, proc in zip(range(1, num_workers + 1), procs)
        }
        pool.__init__(domain, workers, setup_modules=setup_modules,
                      mode="shm", **kw)
        return pool

    @classmethod
    def socket(cls, num_workers: int, *, registry=None, setup_modules=None,
               wrap_fabric=None, **kw) -> "ClusterPool":
        """Fresh-interpreter workers over loopback TCP (``setup_modules``
        as in :meth:`shm` — None auto-derives from the host registry).
        ``wrap_fabric=`` as in :meth:`local`; socket workers build their own
        endpoints in the child interpreter, so only the HOST side of each
        link is wrapped — chaos recv-side injection (keyed by the frame's
        ``src_node``) still exercises both directions."""
        from repro.comm.socket import SocketFabric

        reg = registry or default_registry()
        fabric = SocketFabric(num_workers + 1)
        if wrap_fabric is not None:
            fabric = wrap_fabric(fabric)
        popens = [
            spawn_socket_worker_subprocess(node, num_workers + 1,
                                           fabric.base_port, setup_modules)
            for node in range(1, num_workers + 1)
        ]
        domain = OffloadDomain(fabric, registry=reg)
        pool = cls.__new__(cls)
        workers = {
            node: _SubprocessWorker(node, popen, pool)
            for node, popen in zip(range(1, num_workers + 1), popens)
        }
        pool.__init__(domain, workers, setup_modules=setup_modules,
                      mode="socket", **kw)
        return pool

    # -- introspection -----------------------------------------------------

    @property
    def worker_nodes(self) -> list[int]:
        return sorted(self._workers)

    def live_nodes(self) -> list[int]:
        with self._lock:
            return sorted(n for n in self._workers if n not in self._dead)

    def is_alive(self, node: int) -> bool:
        with self._lock:
            return node in self._workers and node not in self._dead

    def ping_all(self, timeout: float = 20.0) -> None:
        """Round-trip every worker once (startup barrier for process pools)."""
        for node in self.worker_nodes:
            self.domain.ping(node, node, timeout=timeout)

    # -- liveness ----------------------------------------------------------

    def on_death(self, cb) -> None:
        self._death_cbs.append(cb)

    def on_restart(self, cb) -> None:
        self._restart_cbs.append(cb)

    def on_join(self, cb) -> None:
        """``cb(node)`` after an added worker is up, verified and routable."""
        self._join_cbs.append(cb)

    def on_leave(self, cb) -> None:
        """``cb(node)`` at the *start* of a remove — the fence point: the
        subscriber must stop routing new work to the node immediately.  A
        callable return value is a drain waiter ``waiter(timeout)`` that
        ``remove_node(drain=True)`` blocks on before tearing the worker
        down (the scheduler waits out the node's in-flight futures there).
        """
        self._leave_cbs.append(cb)

    def _monitor_loop(self, interval: float) -> None:
        while not self._stop.wait(interval):
            for node in self.worker_nodes:
                with self._lock:
                    handle = self._workers.get(node)
                    announced = node in self._dead
                if handle is None or announced:
                    continue
                if not handle.alive():
                    self._announce_death(node)
            self._run_due_restarts()

    def _announce_death(self, node: int) -> None:
        with self._lock:
            if node in self._dead:
                return
            self._dead.add(node)
        for cb in self._death_cbs:
            try:
                cb(node)
            except Exception:  # noqa: BLE001 — one bad subscriber must not
                # stop death propagation to the others
                import traceback

                traceback.print_exc()
        with self._lock:
            removing = node in self._removing or node not in self._workers
        if self.auto_restart and not self._closed and not removing:
            self._schedule_restart(node)

    # -- auto-restart circuit breaker ---------------------------------------
    #
    # A crash-looping worker used to restart inline in _announce_death — a
    # tight respawn/crash/respawn loop that burned CPU and kept readmitting
    # a node that could not hold traffic.  Deaths now *schedule* a restart
    # with capped exponential backoff, and ``max_restarts`` consecutive
    # failures inside ``fail_window`` trip a quarantine: the node stays out
    # of the pool (on_death was announced exactly once; the scheduler has
    # already drained it) until a half-open probe — restart + ping after
    # ``quarantine_probe`` seconds, interval doubling per failed probe —
    # succeeds, or an operator calls :meth:`readmit`.

    def _schedule_restart(self, node: int) -> None:
        now = time.monotonic()
        with self._lock:
            fails = self._restart_fails.get(node, 0)
            if now - self._last_fail_t.get(node, 0.0) > self.fail_window:
                fails = 0  # earlier failures aged out of the window
            fails += 1
            self._restart_fails[node] = fails
            self._last_fail_t[node] = now
            if fails > self.max_restarts:
                self._quarantined.add(node)
                self._pending_restart.pop(node, None)
                iv = self._probe_iv.get(node, self.quarantine_probe)
                self._probe_iv[node] = iv
                self._probe_at[node] = now + iv
                return
            delay = min(self.restart_backoff * (2 ** (fails - 1)),
                        self.restart_backoff_max)
            self._pending_restart[node] = now + delay

    def _run_due_restarts(self) -> None:
        """Monitor-loop tail: execute scheduled restarts and half-open
        probes that have come due (restarts never run inline on the death
        announcement path any more)."""
        now = time.monotonic()
        with self._lock:
            due = [n for n, t in self._pending_restart.items() if t <= now]
            for n in due:
                del self._pending_restart[n]
            probes = [n for n, t in self._probe_at.items() if t <= now]
            for n in probes:
                del self._probe_at[n]
        for node in due + probes:
            with self._lock:
                skip = (self._closed or node in self._removing
                        or node not in self._workers)
            if skip:
                continue
            probing = node in self._quarantined
            try:
                self.restart(node)
                if probing:
                    self.domain.ping(node, node, timeout=5.0)
            except Exception:  # noqa: BLE001 — the respawn (or probe ping)
                # failed: count it as another consecutive failure
                import traceback

                traceback.print_exc()
                if probing:
                    with self._lock:
                        iv = min(self._probe_iv.get(
                            node, self.quarantine_probe) * 2, 60.0)
                        self._probe_iv[node] = iv
                        self._probe_at[node] = time.monotonic() + iv
                else:
                    self._schedule_restart(node)
                continue
            with self._lock:
                # the worker came back (and, if probing, answered a ping):
                # close the breaker — but keep the failure timestamp, so an
                # immediate re-crash lands back in the window
                self._quarantined.discard(node)
                self._restart_fails[node] = 0
                self._probe_iv.pop(node, None)

    def is_quarantined(self, node: int) -> bool:
        with self._lock:
            return node in self._quarantined

    def readmit(self, node: int) -> None:
        """Operator override: clear a node's quarantine and restart it now
        (the breaker re-arms — it is not a permanent exemption)."""
        with self._lock:
            self._quarantined.discard(node)
            self._restart_fails[node] = 0
            self._probe_at.pop(node, None)
            self._probe_iv.pop(node, None)
        if self.is_alive(node):
            return
        self.restart(node)

    def kill(self, node: int) -> None:
        """Fault injection: hard-stop a worker (no goodbye on the wire)."""
        self._workers[node].kill()

    # -- replicated data plane (module docs; protocol in offload.dataplane) --

    def allocate(self, shape, dtype, *, node: int | None = None,
                 session=None, replicas: int | None = None,
                 timeout: float = 30.0) -> BufferPtr:
        """Allocate a directory-tracked buffer: primary on ``node`` (or the
        next live worker round-robin), ``replicas`` empty copies installed
        under the same global handle on other live workers (write-through
        ``put`` keeps them coherent).  ``session=`` binds the buffer to a
        sticky-session key: on failover the session repins onto the node
        holding its bytes, and ending the session frees the buffer
        everywhere (``Scheduler.end_session`` / :meth:`release_session`).
        """
        live = self.live_nodes()
        if not live:
            raise OffloadError("no live workers to place a buffer on")
        rr = self._alloc_rr
        self._alloc_rr += 1
        if node is None:
            node = live[rr % len(live)]
        elif node not in live:
            raise OffloadError(f"worker {node} is not live")
        ptr = self.domain.allocate(node, shape, dtype)
        want = self.replicas if replicas is None else int(replicas)
        # rotate replica placement with the same counter as primaries so
        # replicas (and their write-through traffic) spread over the pool
        # instead of piling onto the lowest ids
        others = [n for n in live if n != node]
        reps = [others[(rr + i) % len(others)]
                for i in range(min(want, len(others)))]
        for rep in reps:
            self.domain.sync(
                rep,
                f2f("_ham/buf_adopt", int(ptr.handle),
                    [int(d) for d in shape], str(np.dtype(dtype)),
                    registry=self.domain.registry),
                timeout,
            )
        return self.directory.register(ptr, shape, np.dtype(dtype),
                                       replicas=reps, session=session)

    def _buffer_lock(self, handle: int) -> threading.Lock:
        """The data-plane lock stripe for one buffer (``__init__`` notes);
        everything holding one stripe never takes another, so stripes can
        never deadlock."""
        return self._dataplane_locks[int(handle) % len(self._dataplane_locks)]

    def put(self, src, ptr: BufferPtr, *, offset: int = 0) -> None:
        """Chain-replicated write-through put: the payload goes to the
        primary ONCE (zero-copy chunked pipeline) and the primary streams
        it to the replicas over worker->worker links, forwarding chunk k
        while chunk k+1 is still arriving — the host pays one transfer
        regardless of the replica count (``repro.offload.dataplane``,
        "Chain replication"; contract in docs/failure-model.md).

        Divergence guard: the write is sequenced by a directory-minted
        dirty epoch; a replica that did not confirm the COMPLETE write
        (died, partitioned, or torn mid-chain) is DROPPED from the holder
        set at commit — a copy that may be stale must never be promotable.
        A primary that did not confirm raises (and every holder's
        ``applied_dirty`` watermark keeps the torn state detectable at a
        host rebuild).

        Holds the buffer's data-plane lock so its holder set cannot change
        under it by a byte-copying path: a join/restart backfill (or drain
        migration) that snapshotted the bytes pre-put either completes
        first — and this put then writes through the new holder too — or
        starts after the put and copies the new bytes.  Either way no
        promotable holder misses the write."""
        with self._buffer_lock(ptr.handle):
            rec = self.directory.lookup(ptr.handle)
            if rec is None:  # untracked (or lost — resolve raises diagnosis)
                self.domain.put(src, self.directory.resolve(ptr),
                                offset=offset)
                return
            live_reps = [r for r in rec.replicas if self.is_alive(r)]
            for dead in rec.replicas:
                if dead not in live_reps:
                    self.directory.remove_replica(rec.handle, dead)
            if not live_reps:
                # no chain to drive: the plain single-destination put
                self.domain.put(src, ptr.at(rec.primary, rec.epoch),
                                offset=offset)
                return
            dirty = self.directory.begin_write(rec.handle)
            try:
                confirmed = self.domain.chain_put(
                    src, ptr.at(rec.primary, rec.epoch), live_reps, dirty,
                    offset=offset)
            except Exception:
                # the chain never confirmed (primary unreachable / chunk
                # failed): the primary may hold a torn write at epoch
                # ``dirty`` while the replicas hold the previous write.
                # Keep every holder — the applied_dirty watermarks name
                # the divergence at rebuild — and surface the failure.
                self.directory.commit_write(rec.handle)
                raise
            stale = [r for r in live_reps if r not in confirmed]
            self.directory.commit_write(rec.handle, stale=stale)
            if rec.primary not in confirmed:
                raise OffloadError(
                    f"chain put of buffer {rec.handle:#x} did not confirm "
                    f"on primary {rec.primary} (confirmed: {confirmed}) — "
                    "the write is torn; see docs/failure-model.md"
                )

    def get(self, ptr: BufferPtr, **kw):
        """Directory-resolved get: a stale-epoch pointer is transparently
        rewritten to the current primary before the fetch."""
        return self.domain.get(self.directory.resolve(ptr), **kw)

    def free(self, ptr: BufferPtr, timeout: float = 10.0) -> None:
        """Free the logical buffer everywhere: the record is dropped first
        (a racing worker-side ``_ham/buf_freed`` becomes a no-op), then the
        primary gets a strict ``_ham/free`` and every replica an idempotent
        ``_ham/buf_invalidate`` — ``live_count`` stays truthful cluster-wide
        and no replica outlives its buffer.  The drop takes the data-plane
        lock so a backfill copying this buffer finishes registering its new
        holder first (and is then invalidated with the rest) instead of
        adopting an orphan copy of a freed buffer."""
        with self._buffer_lock(ptr.handle):
            rec = self.directory.drop(ptr.handle)
        if rec is None:
            self.domain.free(ptr)  # untracked: the paper's plain free
            return
        for holder in rec.holders:
            if not self.is_alive(holder):
                continue  # its registry died with it
            try:
                if holder == rec.primary:
                    self.domain.free(ptr.at(holder, rec.epoch))
                else:
                    self.domain.sync(
                        holder,
                        f2f("_ham/buf_invalidate", int(rec.handle),
                            registry=self.domain.registry),
                        timeout,
                    )
            except Exception:  # noqa: BLE001 — a holder dying mid-free is
                # equivalent to it having freed; nothing leaks
                pass

    def release_session(self, session) -> int:
        """Free every buffer bound to ``session`` (the session ended — its
        data plane must not leak replicas); returns the number freed."""
        records = self.directory.session_records(session)
        with self._gossip_batch():  # one fused journal frame per survivor
            for rec in records:
                try:
                    self.free(rec.ptr())
                except Exception:  # noqa: BLE001 — keep releasing the rest
                    import traceback

                    traceback.print_exc()
        return len(records)

    def buffer_count(self, node: int, timeout: float = 10.0) -> int:
        """Live buffers held by ``node``'s registry (cluster-wide hygiene
        checks: replicas freed, nothing leaked)."""
        return int(self.domain.sync(
            node, f2f("_ham/buf_count", registry=self.domain.registry),
            timeout,
        ))

    def _copy_buffer(self, rec, src: int, dst: int,
                     timeout: float = 30.0) -> None:
        """Stream one buffer ``src`` -> ``dst`` under its global handle
        over the worker->worker chain (``_ham/chain_push``): the source
        streams its own bytes — adopt + windowed chunk pipeline + flush —
        and the host never stages the payload (it used to fetch the whole
        buffer and re-put it).  The copy lands stamped with the buffer's
        current dirty epoch, so the new holder's ``applied_dirty``
        watermark matches its peers'."""
        dom = self.domain
        confirmed = dom.sync(
            src,
            f2f("_ham/chain_push", int(rec.handle), [int(dst)],
                int(getattr(rec, "dirty", 0)), int(dom.chunk_nbytes), True,
                registry=dom.registry),
            timeout,
        )
        if int(dst) not in [int(n) for n in confirmed]:
            raise OffloadError(
                f"chain push of buffer {rec.handle:#x} {src}->{dst} did "
                f"not confirm (confirmed: {confirmed})"
            )

    def _dataplane_on_death(self, node: int) -> None:
        """First death subscriber: metadata-only replica promotion (+ lost
        accounting + session repin hooks) — see BufferDirectory.  The
        per-buffer gossip storm is batched: one fused frame per survivor."""
        with self._gossip_batch():
            self.directory.on_node_death(node)

    def _dataplane_on_join(self, node: int) -> None:
        """Join/restart subscriber: lazy backfill — buffers left
        under-replicated by earlier deaths copy one replica onto the
        joiner (data moves here, at join time, not on the death path).

        Each buffer's copy + directory registration runs under the
        buffer's data-plane lock stripe (concurrent puts to buffers on
        other stripes interleave): a write-through put can never land
        between our
        snapshot of the bytes and the joiner becoming a promotable holder
        — it either precedes the copy (we copy the new bytes) or follows
        the registration (it writes through the joiner too).  The record
        is re-read under the lock so a buffer freed or mutated since the
        under-replication scan is skipped, not resurrected."""
        if not self.replicas:
            return
        live = set(self.live_nodes())
        for stale in self.directory.under_replicated(self.replicas, live):
            with self._buffer_lock(stale.handle):
                rec = self.directory.lookup(stale.handle)
                if rec is None or node in rec.holders \
                        or rec.primary not in live:
                    continue
                try:
                    self._copy_buffer(rec, rec.primary, node)
                    self.directory.add_replica(rec.handle, node)
                except Exception:  # noqa: BLE001 — backfill is best-effort;
                    # the buffer stays under-replicated until the next join
                    import traceback

                    traceback.print_exc()

    # -- durable directory: gossip fan-out + host crash recovery ------------
    # (protocol in repro.offload.dataplane, "Directory gossip" section)

    @staticmethod
    def _gossip_entry(handle: int, rec) -> list:
        """Wire form of one directory record (``_ham/dir_gossip`` /
        ``_ham/dir_dump`` share it): ``[handle, primary, replicas, epoch,
        nbytes, shape, dtype, session, dirty]``; ``primary = -1`` is a
        tombstone."""
        if rec is None:
            return [int(handle), -1, [], 0, 0, [], "", None, 0]
        return [int(rec.handle), int(rec.primary),
                [int(r) for r in rec.replicas], int(rec.epoch),
                int(rec.nbytes), [int(d) for d in rec.shape],
                str(rec.dtype), rec.session, int(getattr(rec, "dirty", 0))]

    def _gossip_change(self, handle: int, rec, holders) -> None:
        """Directory-journal subscriber: push the updated record to every
        live worker named in ``holders`` as a best-effort ``_ham/dir_gossip``
        oneway (a lost gossip frame degrades recovery, never correctness —
        the dataplane module docs state the guarantee).  Inside a
        :meth:`_gossip_batch` scope the sends are parked and flushed as one
        ``FLAG_FUSED`` frame per destination — an invalidation storm
        (mutation commit, node death, session release) costs one transport
        publication per worker, not one per buffer."""
        if getattr(self, "_closed", False):
            return
        entry = self._gossip_entry(handle, rec)
        me = self.host.node_id
        batch = getattr(self._gossip_tls, "buf", None)
        for node in holders:
            if node == me or not self.is_alive(node):
                continue
            fn = f2f("_ham/dir_gossip", [entry], registry=self.domain.registry)
            if batch is not None:
                batch.setdefault(int(node), []).append(fn)
                continue
            try:
                self.domain.oneway(node, fn)
            except Exception:  # noqa: BLE001 — best-effort journal
                pass

    def _queue_oneway(self, node: int, fn) -> None:
        """Send ``fn`` to ``node`` as a oneway — parked for the per-dst
        fused flush when inside a :meth:`_gossip_batch` scope."""
        batch = getattr(self._gossip_tls, "buf", None)
        if batch is not None:
            batch.setdefault(int(node), []).append(fn)
            return
        try:
            self.domain.oneway(node, fn)
        except Exception:  # noqa: BLE001 — best-effort control traffic
            pass

    def _gossip_batch(self):
        """Context manager: coalesce every gossip/invalidation oneway
        emitted in this thread while the scope is open into ONE
        ``FLAG_FUSED`` frame per destination (``NodeRuntime.
        send_oneway_fused``).  Nestable — only the outermost scope
        flushes."""
        import contextlib

        @contextlib.contextmanager
        def scope():
            if getattr(self._gossip_tls, "buf", None) is not None:
                yield  # nested: the outer scope owns the flush
                return
            self._gossip_tls.buf = {}
            try:
                yield
            finally:
                buf, self._gossip_tls.buf = self._gossip_tls.buf, None
                for dst, fns in buf.items():
                    if not self.is_alive(dst):
                        continue
                    try:
                        self.host.send_oneway_fused(dst, fns)
                    except Exception:  # noqa: BLE001 — best-effort journal
                        pass

        return scope()

    def commit_mutation(self, handles, *, refresh: bool | None = None,
                        timeout: float = 30.0) -> None:
        """Active-Access write commit: after a ``mutates=True`` handler ran
        at the primary, bump each buffer's dirty epoch and restore replica
        coherence (dataplane module docs, "Mutate-at-data"; contract in
        docs/failure-model.md, "Write visibility and convergence").

        ``refresh=False`` (default from ``mutation_refresh``) **drops** the
        replica copies — a metadata-only invalidate (one fused oneway frame
        per holder), with the copies re-backfilled lazily at the next
        join/restart.  ``refresh=True`` keeps the holder set and
        chain-pushes the new bytes from the primary down the same chain a
        put would use; a replica that does not confirm the refresh is
        dropped instead (never left promotable-but-stale).  Called by the
        scheduler's commit hook after every successful (or failed —
        half-applied mutations invalidate too) mutating call."""
        refresh = self.mutation_refresh if refresh is None else bool(refresh)
        with self._gossip_batch():
            for handle in handles:
                handle = int(handle)
                with self._buffer_lock(handle):
                    rec = self.directory.lookup(handle)
                    if rec is None:
                        continue
                    dirty = self.directory.begin_write(handle)
                    live_reps = [r for r in rec.replicas if self.is_alive(r)]
                    dead_reps = [r for r in rec.replicas
                                 if r not in live_reps]
                    if not live_reps:
                        self.directory.commit_write(handle, stale=dead_reps)
                        continue
                    if refresh:
                        try:
                            confirmed = self.domain.sync(
                                rec.primary,
                                f2f("_ham/chain_push", handle, live_reps,
                                    dirty, int(self.domain.chunk_nbytes),
                                    False, registry=self.domain.registry),
                                timeout,
                            )
                        except Exception:  # noqa: BLE001 — an unreachable
                            # chain degrades to the invalidate outcome for
                            # the unconfirmed holders
                            confirmed = [rec.primary]
                        stale = [r for r in rec.replicas
                                 if r not in {int(n) for n in confirmed}]
                        self.directory.commit_write(handle, stale=stale)
                        for r in stale:
                            if self.is_alive(r):
                                self._queue_oneway(r, f2f(
                                    "_ham/buf_invalidate", handle,
                                    registry=self.domain.registry))
                        continue
                    # invalidate: metadata-only — drop every replica from
                    # the holder set and tell it to free its copy
                    self.directory.commit_write(handle,
                                                stale=list(rec.replicas))
                    for r in live_reps:
                        self._queue_oneway(r, f2f(
                            "_ham/buf_invalidate", handle,
                            registry=self.domain.registry))

    def mutate(self, function, *, timeout: float = 30.0):
        """Active-Access write as a pool primitive: run a ``mutates=True``
        handler AT the primary holding the buffers it references, then
        commit the write (dirty-epoch bump + replica invalidate/refresh,
        :meth:`commit_mutation`) before returning the handler's result.

        This is the bare protocol round trip — one targeted sync call
        plus the commit, nothing else attached.  Routing the same call
        through a :class:`~repro.cluster.scheduler.Scheduler` gives the
        identical write-coherence contract for *scheduled* traffic, with
        queueing, deadlines and retries on top.

        The commit runs on success AND on a raised handler (a handler may
        mutate before raising — replicas must not keep serving the
        half-overwritten bytes); the handler's own error outranks a
        commit failure.  Raises :class:`OffloadError` for a handler not
        declared ``mutates=True``, or one referencing no directory-tracked
        buffer (nothing to route on or commit)."""
        if not getattr(function.record, "mutates", False):
            raise OffloadError(
                f"pool.mutate needs a mutates=True handler; "
                f"{function.record.stable_name!r} is not declared mutating "
                "(docs/failure-model.md, 'Write visibility and "
                "convergence')"
            )
        handles = tracked_handles(self.directory, function.args)
        if not handles:
            raise OffloadError(
                "pool.mutate call references no directory-tracked buffer "
                "— nothing to route on or commit"
            )
        votes = mig.scan_locality(function.args,
                                  resolver=self.directory.primary_resolver)
        live = {n: w for n, w in votes.items() if self.is_alive(n)}
        if not live:
            raise OffloadError(
                "no live primary for the buffers referenced by "
                f"{function.record.stable_name!r} (handles "
                f"{[hex(h) for h in handles]})"
            )
        target = max(live, key=lambda n: live[n])
        new_args, changed = self.directory.resolve_args(function.args,
                                                        target=target)
        if changed:
            function = Function(function.record, new_args)
        try:
            result = self.domain.sync(target, function, timeout)
        except BaseException:
            try:  # half-applied mutations invalidate too
                self.commit_mutation(handles, timeout=timeout)
            except Exception:  # noqa: BLE001 — the call's error outranks
                pass
            raise
        self.commit_mutation(handles, timeout=timeout)
        return result

    def restart_host(self, timeout: float = 30.0) -> dict:
        """Crash-recover the HOST in place (the last unprotected failure
        domain — workers got this in PR 5).

        The host runtime is torn down — every outstanding future fails with
        :class:`NodeDownError`, exactly what a real crash does to callers —
        and a fresh :class:`NodeRuntime` starts on the SAME endpoint with a
        fresh future table and msg_id space.  The :class:`BufferDirectory`
        is rebuilt by sync-calling ``_ham/dir_dump`` on every survivor and
        merging the shards: highest epoch wins, ties prefer the dumper that
        is its own primary; an entry whose primary did not survive promotes
        onto its lowest live replica (epoch bump — the crash-promotion
        rule); an entry with no live holder counts ``lost``.  Finally every
        survivor's replay cache is flushed (``_ham/replay_ack`` with a
        max sentinel): the new host's msg_id counter restarts at 1, so a
        cached reply keyed by an old id could otherwise alias a new call.

        Schedulers bound to the old host runtime must be recreated after
        this returns (their future table and credit state died with it).
        Returns ``{"recovered": n, "lost": m, "seconds": s}``.
        """
        t0 = time.monotonic()
        with self._resize_lock:
            old = self.host
            host_node = old.node_id
            old.stop(2.0)  # fails outstanding futures; endpoint stays open
            new = NodeRuntime(host_node, old.endpoint, self.domain._table)
            new.start()
            self.host = new
            self.domain.host = new
            self.domain._inproc[host_node] = new
            survivors = self.live_nodes()
            # merge the survivors' shards (docstring: epoch-max, dumper-is-
            # primary tiebreak — a node serving a buffer has the freshest
            # view of it)
            best: dict[int, tuple] = {}
            #: handle -> {dumper node -> applied_dirty watermark} — the
            #: chain protocol's stale-tail evidence (dump element 10)
            applied_by: dict[int, dict[int, int]] = {}
            for node in survivors:
                try:
                    entries = self.domain.sync(
                        node,
                        f2f("_ham/dir_dump", registry=self.domain.registry),
                        timeout,
                    )
                except Exception:  # noqa: BLE001 — a survivor dying during
                    # recovery just shrinks the merge set
                    continue
                for e in entries:
                    h, p = int(e[0]), int(e[1])
                    rank = (int(e[3]), 1 if p == node else 0)
                    cur = best.get(h)
                    if cur is None or rank > cur[0]:
                        best[h] = (rank, e)
                    if len(e) > 9:
                        applied_by.setdefault(h, {})[node] = int(e[9])
            live = set(survivors)
            records: list[BufferRecord] = []
            promoted: list[BufferRecord] = []
            lost_map: dict[int, str] = {}
            for h, (_rank, e) in sorted(best.items()):
                _, p, reps, epoch, nbytes, shape, dtype, session = e[:8]
                dirty = int(e[8]) if len(e) > 8 else 0
                p, epoch = int(p), int(epoch)
                reps = sorted({int(r) for r in reps} & live - {p})
                # stale-tail filter (chain write protocol): a holder whose
                # bytes reflect an older write epoch than a surviving
                # peer's was cut off mid-chain — it must not be promotable.
                # Holders that never reported a watermark (pre-v2 peers)
                # get the benefit of the doubt; all-equal watermarks keep
                # every holder (the torn-primary residual — the failed
                # write already raised at the caller).
                amap = applied_by.get(h, {})
                maxa = max(amap.values(), default=0)
                stale_tail = [r for r in reps
                              if amap.get(r, maxa) < maxa]
                reps = [r for r in reps if r not in stale_tail]
                was_promoted = False
                if p not in live:
                    if not reps:
                        lost_map[h] = "no holder survived the host crash"
                        continue
                    p = reps.pop(0)  # lowest live replica, as on_node_death
                    epoch += 1
                    was_promoted = True
                elif amap.get(p, maxa) < maxa and reps:
                    # the primary itself missed the newest write some
                    # replica holds complete: promote the freshest holder
                    # (ties lowest-id) — the old primary's copy is stale
                    p = min(reps, key=lambda r: (-amap.get(r, maxa), r))
                    reps = [r for r in reps if r != p]
                    epoch += 1
                    was_promoted = True
                rec = BufferRecord(
                    handle=h, primary=p, replicas=tuple(reps), epoch=epoch,
                    nbytes=int(nbytes), shape=tuple(int(d) for d in shape),
                    dtype=str(dtype), session=session,
                    dirty=max(dirty, maxa),
                )
                records.append(rec)
                if was_promoted:
                    promoted.append(rec)
            directory = BufferDirectory()
            directory.install(records, lost=lost_map)
            directory.on_change(self._gossip_change)
            self.directory = directory
            new.buffer_directory = directory
            # push the rebuild-time promotions back out (install itself does
            # not re-gossip — but these entries CHANGED during the merge)
            for rec in promoted:
                self._gossip_change(rec.handle, rec, rec.holders)
            # flush worker replay caches: the old host's msg_id space is
            # dead, and the new counter would alias its low ids
            for node in survivors:
                try:
                    self.domain.oneway(node, f2f(
                        "_ham/replay_ack", host_node, ReplayCache.FLUSH,
                        registry=self.domain.registry,
                    ))
                except Exception:  # noqa: BLE001 — the FIFO cap still bounds
                    pass
            return {"recovered": len(records), "lost": len(lost_map),
                    "seconds": time.monotonic() - t0}

    def _migrate_off(self, node: int, timeout: float = 30.0) -> None:
        """Lossless-shrink half of ``remove_node(drain=True)``: move every
        primary off ``node`` — promote a surviving replica when one already
        holds the bytes (zero copy), else stream to a survivor — backfill
        the replicas it held, detach it from the directory, and repin the
        sessions whose buffers moved.

        Each buffer moves under the data-plane lock (copy + epoch bump
        atomic w.r.t. write-through puts): a concurrent put either lands
        before the copy — and the copy carries it — or after the bump, when
        the directory already names the new primary.  The record is
        re-read under the lock so a buffer freed since the scan is
        skipped."""
        live = [n for n in self.live_nodes() if n != node]
        if not live:
            # shrinking to zero workers: there is nowhere to move the data —
            # take the crash path so the loss is *recorded*, not silent
            self.directory.on_node_death(node)
            return
        moved: list[int] = []
        rr = 0
        for stale in self.directory.primaries_on(node):
            with self._buffer_lock(stale.handle):
                rec = self.directory.lookup(stale.handle)
                if rec is None or rec.primary != node:
                    continue  # freed or already moved since the scan
                reps = [r for r in rec.replicas if r in live]
                if reps:
                    dst = min(reps)  # the bytes are already there
                else:
                    dst = live[rr % len(live)]
                    rr += 1
                    try:
                        self._copy_buffer(rec, node, dst, timeout)
                    except Exception:  # noqa: BLE001 — an unreadable buffer
                        # at migration time degrades to the crash outcome for
                        # this buffer only (recorded LOST, resolves raise the
                        # diagnosis); the removal itself must proceed
                        import traceback

                        traceback.print_exc()
                        self.directory.mark_lost(
                            rec.handle,
                            f"migration off node {node} failed at its "
                            "removal",
                        )
                        continue
                self.directory.set_primary(rec.handle, dst)
                moved.append(rec.handle)
        if self.replicas:
            for stale in self.directory.replicas_on(node):
                with self._buffer_lock(stale.handle):
                    rec = self.directory.lookup(stale.handle)
                    if rec is None or node not in rec.replicas:
                        continue  # freed or re-placed since the scan
                    candidates = [n for n in live if n not in rec.holders]
                    if not candidates or rec.primary not in live:
                        continue
                    try:
                        self._copy_buffer(rec, rec.primary, candidates[0],
                                          timeout)
                        self.directory.add_replica(rec.handle, candidates[0])
                    except Exception:  # noqa: BLE001
                        import traceback

                        traceback.print_exc()
        self.directory.detach_node(node)
        if moved:
            self.directory.repin_sessions_moved(moved)

    # -- elastic membership ------------------------------------------------

    def _spawn_worker(self, node: int):
        """Launch a worker for ``node`` in this pool's launch mode (the
        fabric must already have the node's transport resources)."""
        if self._mode == "local":
            rt = NodeRuntime(
                node, self.fabric.endpoint(node), self.domain._table,
                policy=self._policy_factory(),
            ).enable_depth_report(dst=self.domain.host_node).start()
            self.domain._inproc[node] = rt  # direct data plane follows
            return _ThreadWorker(node, rt, self)
        if self._mode == "shm":
            proc = spawn_shm_workers(self.fabric, [node],
                                     self._setup_modules)[0]
            return _ForkWorker(node, proc, self)
        if self._mode == "socket":
            popen = spawn_socket_worker_subprocess(
                node, self.fabric.num_nodes, self.fabric.base_port,
                self._setup_modules,
            )
            return _SubprocessWorker(node, popen, self)
        raise OffloadError(f"unknown pool mode {self._mode!r}")

    def add_node(self, *, timeout: float = 30.0) -> int:
        """Grow the pool by one worker under live traffic; returns its node
        id.  Protocol (ordering contract in the module docs): provision the
        fabric, attach the host, sync-broadcast ``_cluster/attach_peer`` to
        every live worker, spawn, barrier-ping, verify the newcomer's
        key-map digest, then announce ``on_join``.
        """
        if self._closed:
            raise OffloadError("pool is closed")
        with self._resize_lock:
            node = self.fabric.add_node()
            handle = None
            try:
                self.host.endpoint.attach_peer(node)
                for peer in self.live_nodes():
                    self.domain.sync(
                        peer,
                        f2f("_cluster/attach_peer", node,
                            registry=self.domain.registry),
                        timeout,
                    )
                handle = self._spawn_worker(node)
                with self._lock:
                    self._workers[node] = handle
                    self._dead.discard(node)
                self.domain.ping(node, node, timeout=timeout)
                digest = self.domain.sync(
                    node,
                    f2f("_cluster/digest", registry=self.domain.registry),
                    timeout,
                )
                verify_peer_digest(self.domain._table, bytes.fromhex(digest))
            except Exception:
                # full rollback — a worker that failed its barrier ping or
                # digest check must NOT stay a routable member: reap it,
                # undo the attach broadcasts, reclaim the fabric resources
                with self._lock:
                    self._removing.add(node)  # no auto_restart interference
                    self._workers.pop(node, None)
                    self._dead.discard(node)
                try:
                    if handle is not None:
                        handle.reap(5.0)
                finally:
                    for peer in self.live_nodes():
                        try:
                            self.domain.sync(
                                peer,
                                f2f("_cluster/detach_peer", node,
                                    registry=self.domain.registry),
                                5.0,
                            )
                        except Exception:  # noqa: BLE001 — best effort
                            pass
                    self.host.endpoint.detach_peer(node)
                    self.fabric.remove_node(node)
                    self.domain._inproc.pop(node, None)
                    with self._lock:
                        self._removing.discard(node)
                raise
            # announce INSIDE the resize lock: a concurrent remove_node of
            # this id serialises behind us, so a subscriber can never admit
            # a node that another thread already finished retiring
            for cb in self._join_cbs:
                try:
                    cb(node)
                except Exception:  # noqa: BLE001 — one bad subscriber must
                    # not block the others from admitting the node
                    import traceback

                    traceback.print_exc()
        return node

    def remove_node(self, node: int, *, drain: bool = True,
                    timeout: float = 30.0) -> None:
        """Retire one worker.  ``drain=True`` fences new submits (via
        ``on_leave``) and waits up to ``timeout`` for the node's in-flight
        calls to finish before terminating it — calls still running at the
        deadline are failed (as on death) so the removal always completes;
        ``drain=False`` fails them immediately.  Either way the id is never
        reused and every surviving endpoint detaches it (module docs,
        shrink protocol).
        """
        with self._resize_lock:
            with self._lock:
                if node not in self._workers:
                    raise OffloadError(f"no worker with node id {node}")
                self._removing.add(node)
                handle = self._workers[node]
            try:
                if drain:
                    # lossless shrink: primaries migrate off while the node
                    # still serves gets — BEFORE the scheduler fence, so the
                    # directory never routes at a fenced node (module docs);
                    # the per-buffer gossip batches into fused frames
                    with self._gossip_batch():
                        self._migrate_off(node, timeout)
                waiters = []
                for cb in self._leave_cbs:
                    try:
                        w = cb(node)
                    except Exception:  # noqa: BLE001
                        import traceback

                        traceback.print_exc()
                        continue
                    if callable(w):
                        waiters.append(w)
                if drain:
                    try:
                        for w in waiters:
                            w(timeout)
                    except TimeoutError:
                        # a handler outlived the drain budget: removal must
                        # still complete (a half-removed node — fenced but
                        # alive and attached — is worse than a failed call),
                        # so fail the stragglers through the death path and
                        # re-run the waiters, which now return immediately
                        self._announce_death(node)
                        for w in waiters:
                            w(5.0)
                else:
                    # fail the node's in-flight work through the normal
                    # death path (subscribers already fenced new submits),
                    # then run the waiters anyway — the rejected futures
                    # resolve instantly and subscribers retire node state
                    self._announce_death(node)
                    for w in waiters:
                        w(min(timeout, 5.0))
                if self.is_alive(node):
                    try:
                        self.domain.oneway(
                            node,
                            f2f("_ham/terminate",
                                registry=self.domain.registry),
                        )
                    except Exception:  # noqa: BLE001 — best-effort goodbye
                        pass
                handle.reap(min(timeout, 5.0))
                with self._lock:
                    self._workers.pop(node, None)
                    self._dead.discard(node)
                self.host.endpoint.detach_peer(node)
                for peer in self.live_nodes():
                    try:
                        self.domain.sync(
                            peer,
                            f2f("_cluster/detach_peer", node,
                                registry=self.domain.registry),
                            5.0,
                        )
                    except Exception:  # noqa: BLE001 — advisory: a peer that
                        # never talked to the node has nothing to detach
                        pass
                self.fabric.remove_node(node)
                self.domain._inproc.pop(node, None)
            finally:
                with self._lock:
                    self._removing.discard(node)

    def restart(self, node: int) -> None:
        """Replace a dead worker in place under the same node id.

        Order matters: reap the corpse, purge fabric state addressed to it
        (queued frames belong to already-failed calls), drop the host's
        cached transport toward it, then attach the replacement and announce.
        Serialised with add/remove under ``_resize_lock``: a respawn reads
        the fabric's member set, which a concurrent resize is mutating.
        """
        with self._resize_lock:
            self._restart_locked(node)

    def _restart_locked(self, node: int) -> None:
        with self._lock:
            handle = self._workers[node]
        handle.reap(1.0)
        self.fabric.prepare_restart(node)
        self.host.endpoint.reset_peer(node)
        # surviving workers may cache worker->worker transport toward the
        # corpse (relay paths); tell them to forget it too
        for peer in self.live_nodes():
            if peer != node:
                try:
                    self.domain.oneway(
                        peer,
                        f2f("_cluster/reset_peer", node,
                            registry=self.domain.registry),
                    )
                except Exception:  # noqa: BLE001 — advisory; a peer that
                    # never cached a connection has nothing to reset
                    pass
        replacement = handle.respawn()
        with self._lock:
            self._workers[node] = replacement
            self._dead.discard(node)
        for cb in self._restart_cbs:
            try:
                cb(node)
            except Exception:  # noqa: BLE001
                import traceback

                traceback.print_exc()

    # -- teardown ----------------------------------------------------------

    def close(self, timeout: float = 5.0) -> None:
        """Stop monitoring, terminate + reap every worker, tear down the
        domain/fabric (unlinking shm segments).  Idempotent."""
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        self._monitor.join(timeout=2.0)
        for node in self.live_nodes():
            try:
                self.domain.oneway(
                    node, f2f("_ham/terminate", registry=self.domain.registry)
                )
            except Exception:  # noqa: BLE001 — best-effort on teardown
                pass
        for handle in self._workers.values():
            try:
                handle.reap(timeout)
            except Exception:  # noqa: BLE001
                pass
        self.domain.shutdown(timeout)

    def __enter__(self) -> "ClusterPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
