"""Cluster worker pool: lifecycle + liveness for a set of HAM offload nodes.

HAM-Offload (paper §2) targets one hand-picked node per call; this module
supplies the fleet underneath a :class:`~repro.cluster.scheduler.Scheduler`:

* :class:`ClusterPool` owns one fabric's worth of workers — in-process
  threads (``local``), forked processes over shared-memory rings (``shm``,
  the SCIF/DMA analogue), or fresh interpreters over TCP (``socket``, the
  heterogeneous-binaries case);
* a monitor thread watches liveness and announces deaths to subscribers
  (the scheduler fails that node's in-flight futures and reroutes);
* dead workers can be restarted in place (``auto_restart=True`` or an
  explicit :meth:`ClusterPool.restart`): the fabric drops frames queued
  toward the corpse, the host endpoint forgets stale transport state, and a
  replacement attaches under the same node id;
* :meth:`ClusterPool.close` reaps every child and tears the fabric down —
  together with ``ShmFabric``'s atexit unlink this is the fix for the
  ``/dev/shm`` segment leak when a child dies mid-run.

Fault-injection helpers (``kill``) are first-class: a scheduler that cannot
be tested against a dying worker cannot be trusted with one.
"""

from __future__ import annotations

import threading
import time

from repro.comm.local import LocalFabric
from repro.core.closure import f2f
from repro.core.errors import RegistrySealedError
from repro.core.executor import DirectPolicy
from repro.core.registry import default_registry
from repro.offload.api import OffloadDomain
from repro.offload.runtime import NodeRuntime
from repro.offload.worker import (
    reap,
    spawn_shm_workers,
    spawn_socket_worker_subprocess,
)


# --------------------------------------------------------------------------
# pool-exercisable handlers (registered at import = static initialisation,
# like runtime's _ham/* set) — used by benchmarks and liveness tests
# --------------------------------------------------------------------------


def _h_sleep(seconds):
    """Blocking I/O stand-in: holds a worker busy without burning CPU."""
    time.sleep(float(seconds))
    return float(seconds)


def _h_spin(n):
    """CPU-bound stand-in: a bounded arithmetic loop."""
    x = 0
    for i in range(int(n)):
        x += i
    return x


def _h_touch(ptr):
    """Data-local stand-in: dereference a buffer_ptr and reduce it — only
    executable on the owning node, so it exercises locality routing."""
    from repro.offload.api import deref

    return float(deref(ptr).sum())


def _h_reset_peer(node_id):
    """Drop this node's cached transport toward a restarted peer — relays
    (offload over fabric) cache worker->worker connections the host's own
    reset cannot reach."""
    from repro.offload.runtime import current_node

    current_node().endpoint.reset_peer(int(node_id))
    return None


def register_cluster_handlers(registry=None) -> None:
    """Register the pool's demo/probe handlers.  Safe to call repeatedly;
    silently skipped on an already-sealed registry (then callers must have
    registered these before ``init()`` themselves)."""
    reg = registry or default_registry()
    for name, fn in (
        ("_cluster/sleep", _h_sleep),
        ("_cluster/spin", _h_spin),
        ("_cluster/touch", _h_touch),
        ("_cluster/reset_peer", _h_reset_peer),
    ):
        try:
            reg.register(fn, name=name)
        except RegistrySealedError:
            return


register_cluster_handlers()


# --------------------------------------------------------------------------
# worker handles (one per launch mode)
# --------------------------------------------------------------------------


class _ThreadWorker:
    """In-process worker: a NodeRuntime on its own event-loop thread."""

    def __init__(self, node_id: int, runtime: NodeRuntime, pool: "ClusterPool"):
        self.node_id = node_id
        self.runtime = runtime
        self._pool = pool

    def alive(self) -> bool:
        t = self.runtime._thread
        return t is not None and t.is_alive()

    def kill(self) -> None:
        # closest analogue of a crash for a thread: stop the event loop cold
        self.runtime.request_stop()

    def reap(self, timeout: float = 5.0) -> None:
        self.runtime.stop(timeout)

    def respawn(self) -> "_ThreadWorker":
        pool = self._pool
        rt = NodeRuntime(
            self.node_id,
            pool.fabric.endpoint(self.node_id),
            pool.domain._table,
            policy=pool._policy_factory(),
        ).start()
        pool.domain._inproc[self.node_id] = rt  # direct data plane follows
        return _ThreadWorker(self.node_id, rt, pool)


class _ForkWorker:
    """Forked child over shm rings (spawn_shm_workers)."""

    def __init__(self, node_id: int, proc, pool: "ClusterPool"):
        self.node_id = node_id
        self.proc = proc
        self._pool = pool

    def alive(self) -> bool:
        return self.proc.is_alive()

    def kill(self) -> None:
        self.proc.kill()

    def reap(self, timeout: float = 5.0) -> None:
        reap([self.proc], timeout)

    def respawn(self) -> "_ForkWorker":
        pool = self._pool
        proc = spawn_shm_workers(pool.fabric, [self.node_id],
                                 pool._setup_modules)[0]
        return _ForkWorker(self.node_id, proc, pool)


class _SubprocessWorker:
    """Fresh-interpreter child over TCP (spawn_socket_worker_subprocess)."""

    def __init__(self, node_id: int, popen, pool: "ClusterPool"):
        self.node_id = node_id
        self.proc = popen
        self._pool = pool

    def alive(self) -> bool:
        return self.proc.poll() is None

    def kill(self) -> None:
        self.proc.kill()

    def reap(self, timeout: float = 5.0) -> None:
        reap([self.proc], timeout)

    def respawn(self) -> "_SubprocessWorker":
        pool = self._pool
        popen = spawn_socket_worker_subprocess(
            self.node_id, pool.fabric.num_nodes, pool.fabric.base_port,
            pool._setup_modules,
        )
        return _SubprocessWorker(self.node_id, popen, pool)


# --------------------------------------------------------------------------
# the pool
# --------------------------------------------------------------------------


class ClusterPool:
    """Owns the workers of one offload domain and watches them.

    Subscribers (``on_death`` / ``on_restart``) are called from the monitor
    thread with the node id; the scheduler uses these to fail in-flight
    futures and to re-admit a node into the routing set.  Callbacks must not
    block — they run on the liveness path.
    """

    def __init__(
        self,
        domain: OffloadDomain,
        workers: dict,
        *,
        monitor_interval: float = 0.1,
        auto_restart: bool = False,
        setup_modules=None,
        policy_factory=DirectPolicy,
    ):
        self.domain = domain
        self.fabric = domain.fabric
        self.host = domain.host
        self._workers = dict(workers)
        self._dead: set[int] = set()
        self._lock = threading.Lock()
        self._death_cbs: list = []
        self._restart_cbs: list = []
        #: None => auto-derive from the host registry at each spawn
        #: (registered_setup_modules), so restarts track late registrations
        self._setup_modules = (
            None if setup_modules is None else list(setup_modules)
        )
        self._policy_factory = policy_factory
        self.auto_restart = auto_restart
        self._closed = False
        self._stop = threading.Event()
        self._monitor = threading.Thread(
            target=self._monitor_loop, args=(monitor_interval,),
            name="ham-cluster-monitor", daemon=True,
        )
        self._monitor.start()

    # -- constructors ------------------------------------------------------

    @classmethod
    def local(cls, num_workers: int, *, registry=None,
              policy_factory=DirectPolicy, **kw) -> "ClusterPool":
        """Thread workers in this process (node 0 is the host)."""
        reg = registry or default_registry()
        fabric = LocalFabric(num_workers + 1)
        domain = OffloadDomain(fabric, registry=reg,
                               policy_factory=policy_factory)
        pool = cls.__new__(cls)
        workers = {}
        for node in range(1, num_workers + 1):
            rt = NodeRuntime(node, fabric.endpoint(node), domain._table,
                             policy=policy_factory()).start()
            domain._inproc[node] = rt  # direct put/get shortcut stays live
            workers[node] = _ThreadWorker(node, rt, pool)
        pool.__init__(domain, workers, policy_factory=policy_factory, **kw)
        return pool

    @classmethod
    def shm(cls, num_workers: int, *, registry=None, capacity: int = 1 << 24,
            setup_modules=None, **kw) -> "ClusterPool":
        """Forked processes over shared-memory rings.

        ``setup_modules=None`` auto-derives the worker import list from the
        host's default registry (same-source key agreement by construction).
        """
        from repro.comm.shm import ShmFabric

        reg = registry or default_registry()
        fabric = ShmFabric(num_workers + 1, capacity=capacity)
        procs = spawn_shm_workers(fabric, list(range(1, num_workers + 1)),
                                  setup_modules)
        domain = OffloadDomain(fabric, registry=reg)
        pool = cls.__new__(cls)
        workers = {
            node: _ForkWorker(node, proc, pool)
            for node, proc in zip(range(1, num_workers + 1), procs)
        }
        pool.__init__(domain, workers, setup_modules=setup_modules, **kw)
        return pool

    @classmethod
    def socket(cls, num_workers: int, *, registry=None, setup_modules=None,
               **kw) -> "ClusterPool":
        """Fresh-interpreter workers over loopback TCP (``setup_modules``
        as in :meth:`shm` — None auto-derives from the host registry)."""
        from repro.comm.socket import SocketFabric

        reg = registry or default_registry()
        fabric = SocketFabric(num_workers + 1)
        popens = [
            spawn_socket_worker_subprocess(node, num_workers + 1,
                                           fabric.base_port, setup_modules)
            for node in range(1, num_workers + 1)
        ]
        domain = OffloadDomain(fabric, registry=reg)
        pool = cls.__new__(cls)
        workers = {
            node: _SubprocessWorker(node, popen, pool)
            for node, popen in zip(range(1, num_workers + 1), popens)
        }
        pool.__init__(domain, workers, setup_modules=setup_modules, **kw)
        return pool

    # -- introspection -----------------------------------------------------

    @property
    def worker_nodes(self) -> list[int]:
        return sorted(self._workers)

    def live_nodes(self) -> list[int]:
        with self._lock:
            return sorted(n for n in self._workers if n not in self._dead)

    def is_alive(self, node: int) -> bool:
        with self._lock:
            return node in self._workers and node not in self._dead

    def ping_all(self, timeout: float = 20.0) -> None:
        """Round-trip every worker once (startup barrier for process pools)."""
        for node in self.worker_nodes:
            self.domain.ping(node, node, timeout=timeout)

    # -- liveness ----------------------------------------------------------

    def on_death(self, cb) -> None:
        self._death_cbs.append(cb)

    def on_restart(self, cb) -> None:
        self._restart_cbs.append(cb)

    def _monitor_loop(self, interval: float) -> None:
        while not self._stop.wait(interval):
            for node in self.worker_nodes:
                with self._lock:
                    handle = self._workers.get(node)
                    announced = node in self._dead
                if handle is None or announced:
                    continue
                if not handle.alive():
                    self._announce_death(node)

    def _announce_death(self, node: int) -> None:
        with self._lock:
            if node in self._dead:
                return
            self._dead.add(node)
        for cb in self._death_cbs:
            try:
                cb(node)
            except Exception:  # noqa: BLE001 — one bad subscriber must not
                # stop death propagation to the others
                import traceback

                traceback.print_exc()
        if self.auto_restart and not self._closed:
            try:
                self.restart(node)
            except Exception:  # noqa: BLE001
                import traceback

                traceback.print_exc()

    def kill(self, node: int) -> None:
        """Fault injection: hard-stop a worker (no goodbye on the wire)."""
        self._workers[node].kill()

    def restart(self, node: int) -> None:
        """Replace a dead worker in place under the same node id.

        Order matters: reap the corpse, purge fabric state addressed to it
        (queued frames belong to already-failed calls), drop the host's
        cached transport toward it, then attach the replacement and announce.
        """
        with self._lock:
            handle = self._workers[node]
        handle.reap(1.0)
        self.fabric.prepare_restart(node)
        self.host.endpoint.reset_peer(node)
        # surviving workers may cache worker->worker transport toward the
        # corpse (relay paths); tell them to forget it too
        for peer in self.live_nodes():
            if peer != node:
                try:
                    self.domain.oneway(
                        peer,
                        f2f("_cluster/reset_peer", node,
                            registry=self.domain.registry),
                    )
                except Exception:  # noqa: BLE001 — advisory; a peer that
                    # never cached a connection has nothing to reset
                    pass
        replacement = handle.respawn()
        with self._lock:
            self._workers[node] = replacement
            self._dead.discard(node)
        for cb in self._restart_cbs:
            try:
                cb(node)
            except Exception:  # noqa: BLE001
                import traceback

                traceback.print_exc()

    # -- teardown ----------------------------------------------------------

    def close(self, timeout: float = 5.0) -> None:
        """Stop monitoring, terminate + reap every worker, tear down the
        domain/fabric (unlinking shm segments).  Idempotent."""
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        self._monitor.join(timeout=2.0)
        for node in self.live_nodes():
            try:
                self.domain.oneway(
                    node, f2f("_ham/terminate", registry=self.domain.registry)
                )
            except Exception:  # noqa: BLE001 — best-effort on teardown
                pass
        for handle in self._workers.values():
            try:
                handle.reap(timeout)
            except Exception:  # noqa: BLE001
                pass
        self.domain.shutdown(timeout)

    def __enter__(self) -> "ClusterPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
