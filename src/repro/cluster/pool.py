"""Cluster worker pool: lifecycle + liveness for a set of HAM offload nodes.

HAM-Offload (paper §2) targets one hand-picked node per call; this module
supplies the fleet underneath a :class:`~repro.cluster.scheduler.Scheduler`:

* :class:`ClusterPool` owns one fabric's worth of workers — in-process
  threads (``local``), forked processes over shared-memory rings (``shm``,
  the SCIF/DMA analogue), or fresh interpreters over TCP (``socket``, the
  heterogeneous-binaries case);
* a monitor thread watches liveness and announces deaths to subscribers
  (the scheduler fails that node's in-flight futures and reroutes);
* dead workers can be restarted in place (``auto_restart=True`` or an
  explicit :meth:`ClusterPool.restart`): the fabric drops frames queued
  toward the corpse, the host endpoint forgets stale transport state, and a
  replacement attaches under the same node id;
* :meth:`ClusterPool.close` reaps every child and tears the fabric down —
  together with ``ShmFabric``'s atexit unlink this is the fix for the
  ``/dev/shm`` segment leak when a child dies mid-run.

Fault-injection helpers (``kill``) are first-class: a scheduler that cannot
be tested against a dying worker cannot be trusted with one.

Elastic membership protocol (grow/shrink under live traffic)
------------------------------------------------------------

The paper fixes the node set at MPI startup and names that as a limitation;
here membership is runtime state, in the spirit of HPX's AGAS.  Node ids
are **monotonic and never reused** — a retired id stays invalid forever, so
a straggler frame addressed to it fails fast instead of reaching an
unrelated replacement.

:meth:`ClusterPool.add_node` (host-driven, in order):

1. ``fabric.add_node()`` provisions transport resources (shm ring pairs, a
   port) for the next id;
2. the host endpoint attaches the id (``attach_peer``);
3. every live worker is told ``_cluster/attach_peer`` as a **sync** call —
   when step 4 starts, every survivor can already address the newcomer
   (the same broadcast role ``restart`` plays with ``_cluster/reset_peer``);
4. the worker is spawned (same launch mode as the pool), pinged (startup
   barrier), and its key-map digest is verified against the host table
   (``verify_peer_digest`` — elastic join re-checks the same-source
   assumption that static startup checked implicitly);
5. ``on_join`` subscribers run (the scheduler creates the node's
   credit/in-flight/stats entries atomically under its lock).

:meth:`ClusterPool.remove_node` (the reverse, with a drain fence):

1. ``on_leave`` subscribers run first — the scheduler *fences* the node
   (no new submits route to it) and returns a drain waiter;
2. with ``drain=True`` the waiter blocks until the node's in-flight futures
   finish (the worker is still alive and replying); with ``drain=False``
   the death path fails them immediately;
3. the worker gets ``_ham/terminate`` and is reaped;
4. the host endpoint and every surviving worker ``detach_peer`` the id
   (broadcast ``_cluster/detach_peer``), and ``fabric.remove_node``
   reclaims its resources.

Workers report executor queue depth to the host as ``_cluster/stats``
oneways (see ``NodeRuntime.enable_depth_report``); the scheduler folds the
reports into ``least_outstanding`` so host-side in-flight counts are
corrected by what is actually queued behind each worker.
"""

from __future__ import annotations

import threading
import time

from repro.comm.local import LocalFabric
from repro.core.closure import f2f
from repro.core.errors import OffloadError, RegistrySealedError
from repro.core.executor import DirectPolicy
from repro.core.registry import default_registry, verify_peer_digest
from repro.offload.api import OffloadDomain
from repro.offload.runtime import NodeRuntime
from repro.offload.worker import (
    reap,
    spawn_shm_workers,
    spawn_socket_worker_subprocess,
)


# --------------------------------------------------------------------------
# pool-exercisable handlers (registered at import = static initialisation,
# like runtime's _ham/* set) — used by benchmarks and liveness tests
# --------------------------------------------------------------------------


def _h_sleep(seconds):
    """Blocking I/O stand-in: holds a worker busy without burning CPU."""
    time.sleep(float(seconds))
    return float(seconds)


def _h_spin(n):
    """CPU-bound stand-in: a bounded arithmetic loop."""
    x = 0
    for i in range(int(n)):
        x += i
    return x


def _h_touch(ptr):
    """Data-local stand-in: dereference a buffer_ptr and reduce it — only
    executable on the owning node, so it exercises locality routing."""
    from repro.offload.api import deref

    return float(deref(ptr).sum())


def _h_reset_peer(node_id):
    """Drop this node's cached transport toward a restarted peer — relays
    (offload over fabric) cache worker->worker connections the host's own
    reset cannot reach."""
    from repro.offload.runtime import current_node

    current_node().endpoint.reset_peer(int(node_id))
    return None


def _h_attach_peer(node_id):
    """Membership broadcast (grow): make ``node_id`` addressable from this
    node.  Called sync so the host knows every survivor attached BEFORE the
    newcomer spawns (protocol step 3 in the module docs)."""
    from repro.offload.runtime import current_node

    current_node().endpoint.attach_peer(int(node_id))
    return None


def _h_detach_peer(node_id):
    """Membership broadcast (shrink): retire ``node_id`` on this node —
    drop its transport state; later sends toward it fail fast."""
    from repro.offload.runtime import current_node

    current_node().endpoint.detach_peer(int(node_id))
    return None


def _h_stats(node_id, depth):
    """Queue-depth report (oneway): a worker's executor backlog, folded into
    the receiving node's ``peer_depth`` for depth-aware scheduling."""
    from repro.offload.runtime import current_node

    current_node().note_peer_depth(int(node_id), int(depth))
    return None


def _h_digest():
    """Key-map digest of this node's handler table (hex) — lets an elastic
    join *verify* the paper's same-source assumption (registry docs)."""
    from repro.offload.runtime import current_node

    return current_node().table.digest.hex()


def register_cluster_handlers(registry=None) -> None:
    """Register the pool's control + demo/probe handlers.  Safe to call
    repeatedly; silently skipped on an already-sealed registry (then callers
    must have registered these before ``init()`` themselves)."""
    reg = registry or default_registry()
    for name, fn in (
        ("_cluster/sleep", _h_sleep),
        ("_cluster/spin", _h_spin),
        ("_cluster/touch", _h_touch),
        ("_cluster/reset_peer", _h_reset_peer),
        ("_cluster/attach_peer", _h_attach_peer),
        ("_cluster/detach_peer", _h_detach_peer),
        ("_cluster/stats", _h_stats),
        ("_cluster/digest", _h_digest),
    ):
        try:
            reg.register(fn, name=name)
        except RegistrySealedError:
            return


register_cluster_handlers()


# --------------------------------------------------------------------------
# worker handles (one per launch mode)
# --------------------------------------------------------------------------


class _ThreadWorker:
    """In-process worker: a NodeRuntime on its own event-loop thread."""

    def __init__(self, node_id: int, runtime: NodeRuntime, pool: "ClusterPool"):
        self.node_id = node_id
        self.runtime = runtime
        self._pool = pool

    def alive(self) -> bool:
        t = self.runtime._thread
        return t is not None and t.is_alive()

    def kill(self) -> None:
        # closest analogue of a crash for a thread: stop the event loop cold
        self.runtime.request_stop()

    def reap(self, timeout: float = 5.0) -> None:
        self.runtime.stop(timeout)

    def respawn(self) -> "_ThreadWorker":
        pool = self._pool
        rt = NodeRuntime(
            self.node_id,
            pool.fabric.endpoint(self.node_id),
            pool.domain._table,
            policy=pool._policy_factory(),
        ).enable_depth_report(dst=pool.domain.host_node).start()
        pool.domain._inproc[self.node_id] = rt  # direct data plane follows
        return _ThreadWorker(self.node_id, rt, pool)


class _ForkWorker:
    """Forked child over shm rings (spawn_shm_workers)."""

    def __init__(self, node_id: int, proc, pool: "ClusterPool"):
        self.node_id = node_id
        self.proc = proc
        self._pool = pool

    def alive(self) -> bool:
        return self.proc.is_alive()

    def kill(self) -> None:
        self.proc.kill()

    def reap(self, timeout: float = 5.0) -> None:
        reap([self.proc], timeout)

    def respawn(self) -> "_ForkWorker":
        pool = self._pool
        proc = spawn_shm_workers(pool.fabric, [self.node_id],
                                 pool._setup_modules)[0]
        return _ForkWorker(self.node_id, proc, pool)


class _SubprocessWorker:
    """Fresh-interpreter child over TCP (spawn_socket_worker_subprocess)."""

    def __init__(self, node_id: int, popen, pool: "ClusterPool"):
        self.node_id = node_id
        self.proc = popen
        self._pool = pool

    def alive(self) -> bool:
        return self.proc.poll() is None

    def kill(self) -> None:
        self.proc.kill()

    def reap(self, timeout: float = 5.0) -> None:
        reap([self.proc], timeout)

    def respawn(self) -> "_SubprocessWorker":
        pool = self._pool
        popen = spawn_socket_worker_subprocess(
            self.node_id, pool.fabric.num_nodes, pool.fabric.base_port,
            pool._setup_modules,
        )
        return _SubprocessWorker(self.node_id, popen, pool)


# --------------------------------------------------------------------------
# the pool
# --------------------------------------------------------------------------


class ClusterPool:
    """Owns the workers of one offload domain and watches them.

    Subscribers (``on_death`` / ``on_restart``) are called from the monitor
    thread with the node id; the scheduler uses these to fail in-flight
    futures and to re-admit a node into the routing set.  Callbacks must not
    block — they run on the liveness path.
    """

    def __init__(
        self,
        domain: OffloadDomain,
        workers: dict,
        *,
        monitor_interval: float = 0.1,
        auto_restart: bool = False,
        setup_modules=None,
        policy_factory=DirectPolicy,
        mode: str = "local",
    ):
        self.domain = domain
        self.fabric = domain.fabric
        self.host = domain.host
        self._mode = mode  # launch mode for elastic spawns (local/shm/socket)
        self._workers = dict(workers)
        self._dead: set[int] = set()
        self._removing: set[int] = set()  # mid-remove: no auto_restart
        self._lock = threading.Lock()
        self._resize_lock = threading.Lock()  # serialises add/remove/restart
        self._death_cbs: list = []
        self._restart_cbs: list = []
        self._join_cbs: list = []
        self._leave_cbs: list = []
        #: None => auto-derive from the host registry at each spawn
        #: (registered_setup_modules), so restarts track late registrations
        self._setup_modules = (
            None if setup_modules is None else list(setup_modules)
        )
        self._policy_factory = policy_factory
        self.auto_restart = auto_restart
        self._closed = False
        self._stop = threading.Event()
        self._monitor = threading.Thread(
            target=self._monitor_loop, args=(monitor_interval,),
            name="ham-cluster-monitor", daemon=True,
        )
        self._monitor.start()

    # -- constructors ------------------------------------------------------

    @classmethod
    def local(cls, num_workers: int, *, registry=None,
              policy_factory=DirectPolicy, **kw) -> "ClusterPool":
        """Thread workers in this process (node 0 is the host)."""
        reg = registry or default_registry()
        fabric = LocalFabric(num_workers + 1)
        domain = OffloadDomain(fabric, registry=reg,
                               policy_factory=policy_factory)
        pool = cls.__new__(cls)
        workers = {}
        for node in range(1, num_workers + 1):
            rt = NodeRuntime(node, fabric.endpoint(node), domain._table,
                             policy=policy_factory()).enable_depth_report(
                dst=domain.host_node).start()
            domain._inproc[node] = rt  # direct put/get shortcut stays live
            workers[node] = _ThreadWorker(node, rt, pool)
        pool.__init__(domain, workers, policy_factory=policy_factory,
                      mode="local", **kw)
        return pool

    @classmethod
    def shm(cls, num_workers: int, *, registry=None, capacity: int = 1 << 24,
            setup_modules=None, **kw) -> "ClusterPool":
        """Forked processes over shared-memory rings.

        ``setup_modules=None`` auto-derives the worker import list from the
        host's default registry (same-source key agreement by construction).
        """
        from repro.comm.shm import ShmFabric

        reg = registry or default_registry()
        fabric = ShmFabric(num_workers + 1, capacity=capacity)
        procs = spawn_shm_workers(fabric, list(range(1, num_workers + 1)),
                                  setup_modules)
        domain = OffloadDomain(fabric, registry=reg)
        pool = cls.__new__(cls)
        workers = {
            node: _ForkWorker(node, proc, pool)
            for node, proc in zip(range(1, num_workers + 1), procs)
        }
        pool.__init__(domain, workers, setup_modules=setup_modules,
                      mode="shm", **kw)
        return pool

    @classmethod
    def socket(cls, num_workers: int, *, registry=None, setup_modules=None,
               **kw) -> "ClusterPool":
        """Fresh-interpreter workers over loopback TCP (``setup_modules``
        as in :meth:`shm` — None auto-derives from the host registry)."""
        from repro.comm.socket import SocketFabric

        reg = registry or default_registry()
        fabric = SocketFabric(num_workers + 1)
        popens = [
            spawn_socket_worker_subprocess(node, num_workers + 1,
                                           fabric.base_port, setup_modules)
            for node in range(1, num_workers + 1)
        ]
        domain = OffloadDomain(fabric, registry=reg)
        pool = cls.__new__(cls)
        workers = {
            node: _SubprocessWorker(node, popen, pool)
            for node, popen in zip(range(1, num_workers + 1), popens)
        }
        pool.__init__(domain, workers, setup_modules=setup_modules,
                      mode="socket", **kw)
        return pool

    # -- introspection -----------------------------------------------------

    @property
    def worker_nodes(self) -> list[int]:
        return sorted(self._workers)

    def live_nodes(self) -> list[int]:
        with self._lock:
            return sorted(n for n in self._workers if n not in self._dead)

    def is_alive(self, node: int) -> bool:
        with self._lock:
            return node in self._workers and node not in self._dead

    def ping_all(self, timeout: float = 20.0) -> None:
        """Round-trip every worker once (startup barrier for process pools)."""
        for node in self.worker_nodes:
            self.domain.ping(node, node, timeout=timeout)

    # -- liveness ----------------------------------------------------------

    def on_death(self, cb) -> None:
        self._death_cbs.append(cb)

    def on_restart(self, cb) -> None:
        self._restart_cbs.append(cb)

    def on_join(self, cb) -> None:
        """``cb(node)`` after an added worker is up, verified and routable."""
        self._join_cbs.append(cb)

    def on_leave(self, cb) -> None:
        """``cb(node)`` at the *start* of a remove — the fence point: the
        subscriber must stop routing new work to the node immediately.  A
        callable return value is a drain waiter ``waiter(timeout)`` that
        ``remove_node(drain=True)`` blocks on before tearing the worker
        down (the scheduler waits out the node's in-flight futures there).
        """
        self._leave_cbs.append(cb)

    def _monitor_loop(self, interval: float) -> None:
        while not self._stop.wait(interval):
            for node in self.worker_nodes:
                with self._lock:
                    handle = self._workers.get(node)
                    announced = node in self._dead
                if handle is None or announced:
                    continue
                if not handle.alive():
                    self._announce_death(node)

    def _announce_death(self, node: int) -> None:
        with self._lock:
            if node in self._dead:
                return
            self._dead.add(node)
        for cb in self._death_cbs:
            try:
                cb(node)
            except Exception:  # noqa: BLE001 — one bad subscriber must not
                # stop death propagation to the others
                import traceback

                traceback.print_exc()
        with self._lock:
            removing = node in self._removing or node not in self._workers
        if self.auto_restart and not self._closed and not removing:
            try:
                self.restart(node)
            except Exception:  # noqa: BLE001
                import traceback

                traceback.print_exc()

    def kill(self, node: int) -> None:
        """Fault injection: hard-stop a worker (no goodbye on the wire)."""
        self._workers[node].kill()

    # -- elastic membership ------------------------------------------------

    def _spawn_worker(self, node: int):
        """Launch a worker for ``node`` in this pool's launch mode (the
        fabric must already have the node's transport resources)."""
        if self._mode == "local":
            rt = NodeRuntime(
                node, self.fabric.endpoint(node), self.domain._table,
                policy=self._policy_factory(),
            ).enable_depth_report(dst=self.domain.host_node).start()
            self.domain._inproc[node] = rt  # direct data plane follows
            return _ThreadWorker(node, rt, self)
        if self._mode == "shm":
            proc = spawn_shm_workers(self.fabric, [node],
                                     self._setup_modules)[0]
            return _ForkWorker(node, proc, self)
        if self._mode == "socket":
            popen = spawn_socket_worker_subprocess(
                node, self.fabric.num_nodes, self.fabric.base_port,
                self._setup_modules,
            )
            return _SubprocessWorker(node, popen, self)
        raise OffloadError(f"unknown pool mode {self._mode!r}")

    def add_node(self, *, timeout: float = 30.0) -> int:
        """Grow the pool by one worker under live traffic; returns its node
        id.  Protocol (ordering contract in the module docs): provision the
        fabric, attach the host, sync-broadcast ``_cluster/attach_peer`` to
        every live worker, spawn, barrier-ping, verify the newcomer's
        key-map digest, then announce ``on_join``.
        """
        if self._closed:
            raise OffloadError("pool is closed")
        with self._resize_lock:
            node = self.fabric.add_node()
            handle = None
            try:
                self.host.endpoint.attach_peer(node)
                for peer in self.live_nodes():
                    self.domain.sync(
                        peer,
                        f2f("_cluster/attach_peer", node,
                            registry=self.domain.registry),
                        timeout,
                    )
                handle = self._spawn_worker(node)
                with self._lock:
                    self._workers[node] = handle
                    self._dead.discard(node)
                self.domain.ping(node, node, timeout=timeout)
                digest = self.domain.sync(
                    node,
                    f2f("_cluster/digest", registry=self.domain.registry),
                    timeout,
                )
                verify_peer_digest(self.domain._table, bytes.fromhex(digest))
            except Exception:
                # full rollback — a worker that failed its barrier ping or
                # digest check must NOT stay a routable member: reap it,
                # undo the attach broadcasts, reclaim the fabric resources
                with self._lock:
                    self._removing.add(node)  # no auto_restart interference
                    self._workers.pop(node, None)
                    self._dead.discard(node)
                try:
                    if handle is not None:
                        handle.reap(5.0)
                finally:
                    for peer in self.live_nodes():
                        try:
                            self.domain.sync(
                                peer,
                                f2f("_cluster/detach_peer", node,
                                    registry=self.domain.registry),
                                5.0,
                            )
                        except Exception:  # noqa: BLE001 — best effort
                            pass
                    self.host.endpoint.detach_peer(node)
                    self.fabric.remove_node(node)
                    self.domain._inproc.pop(node, None)
                    with self._lock:
                        self._removing.discard(node)
                raise
            # announce INSIDE the resize lock: a concurrent remove_node of
            # this id serialises behind us, so a subscriber can never admit
            # a node that another thread already finished retiring
            for cb in self._join_cbs:
                try:
                    cb(node)
                except Exception:  # noqa: BLE001 — one bad subscriber must
                    # not block the others from admitting the node
                    import traceback

                    traceback.print_exc()
        return node

    def remove_node(self, node: int, *, drain: bool = True,
                    timeout: float = 30.0) -> None:
        """Retire one worker.  ``drain=True`` fences new submits (via
        ``on_leave``) and waits up to ``timeout`` for the node's in-flight
        calls to finish before terminating it — calls still running at the
        deadline are failed (as on death) so the removal always completes;
        ``drain=False`` fails them immediately.  Either way the id is never
        reused and every surviving endpoint detaches it (module docs,
        shrink protocol).
        """
        with self._resize_lock:
            with self._lock:
                if node not in self._workers:
                    raise OffloadError(f"no worker with node id {node}")
                self._removing.add(node)
                handle = self._workers[node]
            try:
                waiters = []
                for cb in self._leave_cbs:
                    try:
                        w = cb(node)
                    except Exception:  # noqa: BLE001
                        import traceback

                        traceback.print_exc()
                        continue
                    if callable(w):
                        waiters.append(w)
                if drain:
                    try:
                        for w in waiters:
                            w(timeout)
                    except TimeoutError:
                        # a handler outlived the drain budget: removal must
                        # still complete (a half-removed node — fenced but
                        # alive and attached — is worse than a failed call),
                        # so fail the stragglers through the death path and
                        # re-run the waiters, which now return immediately
                        self._announce_death(node)
                        for w in waiters:
                            w(5.0)
                else:
                    # fail the node's in-flight work through the normal
                    # death path (subscribers already fenced new submits),
                    # then run the waiters anyway — the rejected futures
                    # resolve instantly and subscribers retire node state
                    self._announce_death(node)
                    for w in waiters:
                        w(min(timeout, 5.0))
                if self.is_alive(node):
                    try:
                        self.domain.oneway(
                            node,
                            f2f("_ham/terminate",
                                registry=self.domain.registry),
                        )
                    except Exception:  # noqa: BLE001 — best-effort goodbye
                        pass
                handle.reap(min(timeout, 5.0))
                with self._lock:
                    self._workers.pop(node, None)
                    self._dead.discard(node)
                self.host.endpoint.detach_peer(node)
                for peer in self.live_nodes():
                    try:
                        self.domain.sync(
                            peer,
                            f2f("_cluster/detach_peer", node,
                                registry=self.domain.registry),
                            5.0,
                        )
                    except Exception:  # noqa: BLE001 — advisory: a peer that
                        # never talked to the node has nothing to detach
                        pass
                self.fabric.remove_node(node)
                self.domain._inproc.pop(node, None)
            finally:
                with self._lock:
                    self._removing.discard(node)

    def restart(self, node: int) -> None:
        """Replace a dead worker in place under the same node id.

        Order matters: reap the corpse, purge fabric state addressed to it
        (queued frames belong to already-failed calls), drop the host's
        cached transport toward it, then attach the replacement and announce.
        Serialised with add/remove under ``_resize_lock``: a respawn reads
        the fabric's member set, which a concurrent resize is mutating.
        """
        with self._resize_lock:
            self._restart_locked(node)

    def _restart_locked(self, node: int) -> None:
        with self._lock:
            handle = self._workers[node]
        handle.reap(1.0)
        self.fabric.prepare_restart(node)
        self.host.endpoint.reset_peer(node)
        # surviving workers may cache worker->worker transport toward the
        # corpse (relay paths); tell them to forget it too
        for peer in self.live_nodes():
            if peer != node:
                try:
                    self.domain.oneway(
                        peer,
                        f2f("_cluster/reset_peer", node,
                            registry=self.domain.registry),
                    )
                except Exception:  # noqa: BLE001 — advisory; a peer that
                    # never cached a connection has nothing to reset
                    pass
        replacement = handle.respawn()
        with self._lock:
            self._workers[node] = replacement
            self._dead.discard(node)
        for cb in self._restart_cbs:
            try:
                cb(node)
            except Exception:  # noqa: BLE001
                import traceback

                traceback.print_exc()

    # -- teardown ----------------------------------------------------------

    def close(self, timeout: float = 5.0) -> None:
        """Stop monitoring, terminate + reap every worker, tear down the
        domain/fabric (unlinking shm segments).  Idempotent."""
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        self._monitor.join(timeout=2.0)
        for node in self.live_nodes():
            try:
                self.domain.oneway(
                    node, f2f("_ham/terminate", registry=self.domain.registry)
                )
            except Exception:  # noqa: BLE001 — best-effort on teardown
                pass
        for handle in self._workers.values():
            try:
                handle.reap(timeout)
            except Exception:  # noqa: BLE001
                pass
        self.domain.shutdown(timeout)

    def __enter__(self) -> "ClusterPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
