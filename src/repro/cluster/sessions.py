"""Sticky-session routing: rendezvous (HRW) hashing over live workers.

Long-running services route *sessions* (a serving request's KV cache, a
user's conversation, a shard of state) rather than independent calls: every
message of a session must land on the worker holding its state.  This
module supplies that affinity layer for the scheduler, generalising the
admission-time stickiness ``ClusterServingEngine`` used to hand-roll.

Why rendezvous hashing (highest random weight)
----------------------------------------------

For each session key the router scores every candidate node with a stable
64-bit hash of ``(key, node)`` and picks the maximum.  Two properties make
this the right tool for an *elastic* pool:

* **Minimal disruption** — adding a node remaps only the keys whose new
  top-scorer is that node (an expected ``1/n`` share); removing a node
  remaps only the keys it owned.  Every other key's winner is untouched,
  with no token ring to rebalance and no state to migrate.
* **Determinism without coordination** — scores depend only on (key, node
  id), so any process with the same live set derives the same placement;
  nothing needs to be broadcast when a session is first seen.

Stickiness contract (the routing table on top of HRW)
-----------------------------------------------------

``route(key)`` consults a pinned-placement table first; HRW only runs for
keys with no live pin.  The resulting invariants, which the tests assert:

* a session stays on its worker across *unrelated* membership changes —
  resizes never move a pinned live session (HRW alone would remap its fair
  share; the pin table is what turns "minimal disruption" into "zero
  disruption for established sessions");
* a pin *survives worker restart*: the table maps to the node id, and a
  restarted worker rejoins under the same id (callers re-establish any
  node-local state, as with restarts generally);
* a session is **re-placed only when its own worker leaves the live set**
  (death or removal): the next ``route`` falls back to HRW over the
  survivors and re-pins — the fallback-on-death contract.

Node ids are never reused (pool invariant), so a stale pin can never
accidentally match an unrelated future worker.
"""

from __future__ import annotations

import hashlib
import struct
import threading
from typing import Callable, Hashable, Iterable

__all__ = ["SessionRouter", "rendezvous_hash"]

_U64 = struct.Struct(">Q")


def _score(key_bytes: bytes, node: int) -> int:
    h = hashlib.blake2b(key_bytes, digest_size=8, salt=_U64.pack(node))
    return _U64.unpack(h.digest())[0]


def _key_bytes(key: Hashable) -> bytes:
    if isinstance(key, bytes):
        return key
    if isinstance(key, str):
        return key.encode("utf-8")
    return repr(key).encode("utf-8")


def rendezvous_hash(key: Hashable, nodes: Iterable[int]) -> int | None:
    """Highest-random-weight winner for ``key`` among ``nodes`` (None when
    empty).  Stable across processes and runs: blake2b, not Python hash."""
    kb = _key_bytes(key)
    best, best_score = None, -1
    for node in sorted(nodes):
        s = _score(kb, node)
        if s > best_score:
            best, best_score = node, s
    return best


class SessionRouter:
    """Pin table + HRW fallback over a live-node view (module docs define
    the stickiness contract).

    ``live_nodes`` is a callable returning the current routable node ids —
    normally ``Scheduler.live_nodes``, so fencing a node for removal
    immediately stops new placements on it.
    """

    def __init__(self, live_nodes: Callable[[], Iterable[int]]):
        self._live_nodes = live_nodes
        self._pins: dict[Hashable, int] = {}
        self._lock = threading.Lock()
        self.stats = {"placed": 0, "replaced": 0, "hits": 0, "recovered": 0,
                      "ended": 0}

    def route(self, key: Hashable, *, eligible: Iterable[int] | None = None) -> int | None:
        """Worker for ``key``: the live pin if one exists, else a fresh HRW
        placement (re-placement when the pinned worker left the live set).

        ``eligible`` restricts *fresh* placements (e.g. to workers with free
        serving slots); a live pin always wins over it — stickiness is the
        point.  Returns None when no candidate node is live.
        """
        live = set(self._live_nodes())
        with self._lock:
            pinned = self._pins.get(key)
            if pinned is not None and pinned in live:
                self.stats["hits"] += 1
                return pinned
            candidates = live if eligible is None else live & set(eligible)
            node = rendezvous_hash(key, candidates)
            if node is None:
                return None
            if pinned is None:
                self.stats["placed"] += 1
            else:
                self.stats["replaced"] += 1  # fallback-on-death re-placement
            self._pins[key] = node
            return node

    def repin(self, key: Hashable, node: int) -> None:
        """Data-directed re-placement: force ``key``'s pin to ``node``.

        The crash-recovery override of the HRW fallback: when a session's
        worker dies but a replica of its buffers survives elsewhere, the
        BufferDirectory (through the scheduler) repins the session onto the
        node now holding its bytes — the session follows its data, not the
        hash.  Also used by drain migration on ``remove_node``.
        """
        with self._lock:
            if self._pins.get(key) != node:
                self._pins[key] = node
                self.stats["recovered"] += 1

    def lookup(self, key: Hashable) -> int | None:
        """Current pin (may point at a dead node — ``route`` re-places)."""
        with self._lock:
            return self._pins.get(key)

    def end_session(self, key: Hashable) -> None:
        with self._lock:
            if self._pins.pop(key, None) is not None:
                self.stats["ended"] += 1

    def sessions_on(self, node: int) -> list:
        with self._lock:
            return [k for k, n in self._pins.items() if n == node]

    def evict_node(self, node: int) -> list:
        """Drop every pin on ``node`` (worker retired — its state is gone);
        returns the evicted keys.  Their next ``route`` re-places them."""
        with self._lock:
            evicted = [k for k, n in self._pins.items() if n == node]
            for k in evicted:
                del self._pins[k]
        return evicted

    def __len__(self) -> int:
        with self._lock:
            return len(self._pins)
