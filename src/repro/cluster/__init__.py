"""Cluster layer: worker pools + policy scheduling over the offload runtime.

``ClusterPool`` owns worker lifecycle (spawn/attach, liveness, restart,
reap); ``Scheduler`` routes ``async_offload`` calls by policy with
credit-based flow control and fails over on worker death.  See the module
docstrings for the policy and backpressure contracts.
"""

from repro.cluster.pool import ClusterPool, register_cluster_handlers
from repro.cluster.scheduler import POLICIES, Scheduler, as_completed, gather

__all__ = [
    "ClusterPool",
    "Scheduler",
    "POLICIES",
    "as_completed",
    "gather",
    "register_cluster_handlers",
]
