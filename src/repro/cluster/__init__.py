"""Cluster layer: worker pools + policy scheduling over the offload runtime.

``ClusterPool`` owns worker lifecycle (spawn/attach, liveness, restart,
reap, elastic add/remove under traffic); ``Scheduler`` routes
``async_offload`` calls by policy with credit-based flow control, sticky
``session=`` affinity (``SessionRouter``), and fails over on worker death.
See the module docstrings for the policy, backpressure and membership
contracts.
"""

from repro.cluster.pool import ClusterPool, register_cluster_handlers
from repro.cluster.scheduler import POLICIES, Scheduler, as_completed, gather
from repro.cluster.sessions import SessionRouter, rendezvous_hash
from repro.offload.dataplane import BufferDirectory, BufferRecord

__all__ = [
    "BufferDirectory",
    "BufferRecord",
    "ClusterPool",
    "Scheduler",
    "SessionRouter",
    "POLICIES",
    "as_completed",
    "gather",
    "register_cluster_handlers",
    "rendezvous_hash",
]
