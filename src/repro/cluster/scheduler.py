"""Policy-driven scheduler with credit-based flow control over a ClusterPool.

The paper's ``offload::async`` takes an explicit target node; this layer
picks the node, keeps many calls in flight per worker, and survives worker
death — the futurized, load-balanced dispatch direction of HPX ("Closing the
Performance Gap with Modern C++") and the data-centric routing of Active
Access (Besta et al.), built on HAM's unchanged message layer.

Scheduling policies
-------------------

``policy=`` selects how :meth:`Scheduler.submit` routes a call whose target
was not pinned with ``node=``:

* ``"round_robin"`` — cycle through live workers in node order.  Stateless
  and fair for uniform work; degrades when call costs vary (a slow call
  holds up its node while the cycle keeps loading it evenly).
* ``"least_outstanding"`` — pick the live worker with the lowest *load
  estimate*: host-side in-flight calls **plus** the worker's last reported
  executor queue depth (``_cluster/stats`` oneways — see
  ``NodeRuntime.enable_depth_report``).  Ties break toward the lowest node
  id.  The default: it is adaptive join-shortest-queue, and the depth term
  also covers load the host did not submit (worker-to-worker traffic,
  another scheduler sharing the pool).
* ``"locality"`` — scan the call's arguments for migratable values with a
  registered locality hook (``buffer_ptr`` reports its owning node; see
  ``migratable.register_migratable(locality=...)``) and prefer the live
  node owning the most referenced buffer *bytes* (votes are weighted by
  ``nbytes``: one node holding a 100 MB buffer outweighs one holding three
  8-byte scalars — moving the call is cheap, moving the data is not);
  calls with no locality votes (or whose owner is dead) fall back to
  least-outstanding.  This routes compute to data instead of data to
  compute.  With a pool :class:`BufferDirectory` attached, a replicated
  buffer votes for EVERY live holder — any copy can serve a read, so
  locality routing survives the primary's death — but only for handlers
  registered ``read_only=True``; a call without the declaration votes for
  (and is pinned to) the buffer's primary, because serving it from a
  replica could mutate that copy behind the write-through protocol's back
  (the read-only routing contract in ``repro.offload.dataplane``).

Location-transparent pointers (the data-plane refactor)
-------------------------------------------------------

When the pool carries a ``BufferDirectory`` (it always does; see
``repro.offload.dataplane``), every submit rewrites its ``BufferPtr``
arguments against the directory *before* the frame is packed: a pointer
carrying a stale ownership epoch (its buffer's primary moved — crash
promotion or drain migration) is transparently re-resolved to the current
primary, and — for handlers declared ``read_only`` — a pointer whose
chosen target holds a replica is retargeted at that copy.  Callers keep using pointers minted before a failover; they
never see a dangling-handle error for a buffer that still exists (a buffer
that is genuinely *lost* — died with no replica — raises a diagnosis at
submit).  The scheduler also subscribes to the directory's repin hooks:
when a dead worker's buffers promote onto a replica holder, the sessions
bound to them are re-pinned onto that node, so a session resumes WITH its
data rather than wherever the rendezvous hash points.

Sticky sessions
---------------

``submit(fn, session=key)`` routes through a :class:`SessionRouter`
(``Scheduler.sessions``): the first call of a session places it on a live
worker by rendezvous hash, subsequent calls stick to that worker, and the
session is re-placed only when its worker leaves the live set (see
``repro.cluster.sessions`` for the invariants).  A session submit behaves
like a pinned submit for flow control — it waits on its worker's credit —
but re-routes instead of failing when the worker dies mid-wait.

Elastic resize
--------------

The scheduler subscribes to the pool's ``on_join``/``on_leave``:

* **join** (added or restarted worker): its credit pool, in-flight map and
  stats entries are created atomically under the scheduler lock, then the
  node enters the routing set;
* **leave** (``ClusterPool.remove_node``): the node leaves the routing set
  *immediately* (the fence — new submits can no longer pick it) and the
  pool receives a drain waiter; with ``drain=True`` the waiter blocks until
  the node's tracked in-flight futures resolve, then retires its
  credit/in-flight/depth state and evicts its sessions.  In-flight calls
  complete normally during a drain because the worker is only terminated
  after the waiter returns.

Small-call fusion
-----------------

``fuse_window=`` (seconds; default ``None`` = off) turns on submit-side
small-call fusion: sub-threshold static-spec calls (payload <=
``FUSE_THRESHOLD`` bytes) are parked per target and shipped as ONE
``FLAG_FUSED`` multi-call frame (see ``core/message.py``) when the batch
reaches ``fuse_max``, when a non-fusible call to the same target must not
overtake them, on an explicit :meth:`flush`, or at the latest after the
window elapses (a daemon flusher thread bounds the added latency).  Each
fused call keeps its own credit, in-flight entry and future — error/death
semantics are per call, identical to unfused submits; only the wire
framing and the worker's dispatch pass are shared.

**Adaptive window** (``fuse_adaptive=True``, the default): the batch also
closes the moment batching stops paying — when the target has nothing
else in flight (an *idle* worker gains nothing from a parked call; holding
it for the timer is pure added latency, so a lone call to an idle target
ships immediately and a burst fuses everything behind its first call), and
when the target's credit pool drains (every credit consumed: no future
submit can join the batch, so the timer buys nothing).  The drain edge is
watched from the completion path too: when a target's wire in-flight sinks
to its parked batch, the batch ships.  The fixed window remains only as
the backstop for the in-between regime.  ``fuse_adaptive=False`` restores
the pure timer (useful for measuring the window itself).

Credit-based flow control (the backpressure contract)
-----------------------------------------------------

Every worker has ``max_inflight`` *credits*.  ``submit`` consumes one
credit on its target before the frame is sent and the credit is returned
when the call's future completes (result, remote error, or node death) —
so per-node in-flight frames are bounded by construction:

* a slow worker saturates its credits and ``submit`` **blocks** the caller
  (bounded by ``submit_timeout``, then :class:`OffloadError`) instead of
  ballooning the transport queue / shm ring behind the worker;
* policy routing only considers nodes with a free credit when any exists,
  so one stuck worker does not stall traffic that other workers could
  absorb — blocking happens only when the whole pool is saturated (or the
  call is pinned);
* credits are per-scheduler state, not a wire protocol: the transport's own
  bounded rings remain the hard backstop underneath.

Failure semantics
-----------------

The pool's monitor announces a dead worker; the scheduler then (1) removes
the node from the routing set, (2) fails every tracked in-flight future on
that node with :class:`RemoteExecutionError` *through the host's future
table* — popping the table entry, so a straggler reply from a restarted
node id is dropped rather than resurrecting a failed future — and (3)
routes subsequent submits to the survivors.  On restart the node rejoins
with a fresh credit pool.

Deadlines, retries and exactly-once replay (docs/failure-model.md)
------------------------------------------------------------------

``deadline=`` (per attempt, seconds) arms a watchdog for every submit: a
call whose reply has not arrived when its attempt expires is either
**retransmitted** (up to ``retries=`` times, attempt timeouts growing by
``retry_backoff=`` per attempt and capped at ``retry_cap=`` seconds) or
**failed** with an :class:`OffloadError` diagnosis — never silently
stranded.  Both knobs have per-call overrides on :meth:`submit`.

A retransmission reuses the SAME ``msg_id`` toward the SAME worker and
carries ``FLAG_RETRYABLE`` (as did the first attempt), so the worker's
:class:`~repro.offload.runtime.ReplayCache` can dedup: a duplicate of a
call still executing is dropped, a duplicate of a completed call gets the
cached reply resent — mutating handlers execute exactly once no matter how
many attempts the fabric forced.  Rerouting a retry to a *different*
worker would break that guarantee, so retries are target-sticky; a worker
death while attempts remain fails the call through the normal death path.
The watchdog also piggybacks cumulative ``_ham/replay_ack`` oneways (the
highest msg_id below every outstanding retryable call) so workers can
evict cached replies that can no longer be asked for.  Retryable calls
bypass small-call fusion — a fused segment cannot be retransmitted alone.

Fault-free cost: calls submitted without a deadline skip all of this
(no tracking entry, no flag bits, no watchdog thread until the first
deadlined submit).

Mutate-at-data (Active Access writes)
-------------------------------------

A handler registered ``mutates=True`` declares that it writes buffers
through ``deref`` in place — the Active Access write direction (Besta et
al.): ship the mutation to the data instead of round-tripping the bytes
through ``get``/modify/``put``.  The scheduler closes the coherence loop:

* **routing** — a mutating call is routed at the primary of the buffers it
  references (under EVERY policy, not just ``locality``), and its pointers
  stay pinned there, so the write lands on the authoritative copy;
* **commit** — when the call completes (success OR remote error — a
  handler may have partially mutated before raising), the scheduler calls
  ``pool.commit_mutation`` on the referenced handles from a dedicated
  commit thread: the buffer's *dirty epoch* advances and every replica
  holder is invalidated (dropped for lazy re-backfill, or refreshed down
  the replication chain when the pool was built ``mutation_refresh=True``).
  The future :meth:`submit` returns resolves only after the commit, so
  ``fut.get()`` == "replicas can no longer serve the overwritten bytes"
  (docs/failure-model.md, "Write visibility and convergence").  The commit
  runs on its own thread because completion callbacks fire on the event
  loop — a synchronous invalidation send from there would deadlock.
* **oneways are uncommitted** — :meth:`oneway` has no completion edge, so
  a mutating oneway updates the primary without invalidating replicas;
  use ``submit`` for mutations that must converge.

A handler declared neither ``read_only`` nor ``mutates`` that dereferences
a *replicated* buffer gets a one-shot warning naming the missing
declaration (hamlint HAM001 finds the same statically) — its replicas are
not invalidated and a replica-served read may observe stale bytes.
"""

from __future__ import annotations

import logging
import queue as _queue
import threading
from typing import Iterable

from repro.core import migratable as mig
from repro.core.closure import Function, f2f
from repro.core.errors import NodeDownError, OffloadError
from repro.core.future import Future, as_completed, gather
from repro.core.message import FLAG_RETRYABLE
from repro.cluster.pool import ClusterPool
from repro.cluster.sessions import SessionRouter
from repro.offload.runtime import FUSE_THRESHOLD

__all__ = ["Scheduler", "as_completed", "gather"]

POLICIES = ("round_robin", "least_outstanding", "locality")

_log = logging.getLogger("repro.cluster.scheduler")


class Scheduler:
    """Routes ``submit`` calls across a :class:`ClusterPool` (module docs
    define the policy and flow-control contracts)."""

    def __init__(
        self,
        pool: ClusterPool,
        *,
        policy: str = "least_outstanding",
        max_inflight: int = 32,
        submit_timeout: float | None = 30.0,
        fuse_window: float | None = None,
        fuse_max: int = 16,
        fuse_adaptive: bool = True,
        deadline: float | None = None,
        retries: int = 0,
        retry_backoff: float = 2.0,
        retry_cap: float = 8.0,
    ):
        if policy not in POLICIES:
            raise OffloadError(f"unknown policy {policy!r}; one of {POLICIES}")
        self.pool = pool
        self.host = pool.host
        self.policy = policy
        self.max_inflight = int(max_inflight)
        self.submit_timeout = submit_timeout
        # -- deadline / retry defaults (module docs) -----------------------
        self.deadline = deadline
        self.retries = int(retries)
        self.retry_backoff = float(retry_backoff)
        self.retry_cap = float(retry_cap)
        #: msg_id -> [node, function, expires, attempts_left, timeout, retryable]
        self._tracked: dict[int, list] = {}
        #: per-node replay-ack state: [last_acked_upto, last_sent_monotonic]
        self._ack_state: dict[int, list] = {}
        #: per-node highest COMPLETED retryable msg_id (ack high-water mark)
        self._retry_hwm: dict[int, int] = {}
        self._watchdog: threading.Thread | None = None
        self._watchdog_stop = threading.Event()
        self._lock = threading.Lock()
        #: the pool's location-transparent buffer namespace (module docs);
        #: None only for pool-likes that predate the directory
        self._directory = getattr(pool, "directory", None)
        # -- small-call fusion state (module docs: Small-call fusion) ------
        self.fuse_window = fuse_window
        self.fuse_max = int(fuse_max)
        self.fuse_adaptive = bool(fuse_adaptive)
        self._fuse_pending: dict[int, list[tuple[Function, int]]] = {}
        # per-target send serialisation: every pop-and-send (and every
        # non-fusible send that must not overtake a parked batch) runs
        # under the target's send lock, so concurrent submitters and the
        # flusher thread cannot reorder frames toward one worker.  Lock
        # order: send lock, THEN self._lock — never the reverse.  Reentrant:
        # the adaptive close may flush from a completion callback that runs
        # inside a failed flush's rejection cascade (same thread).
        self._send_locks: dict[int, threading.RLock] = {}
        self._fuse_stop = threading.Event()
        self._fuse_thread: threading.Thread | None = None
        if fuse_window is not None:
            self._fuse_thread = threading.Thread(
                target=self._fuse_flusher, name="ham-sched-fuse", daemon=True
            )
            self._fuse_thread.start()
        self._live: set[int] = set(pool.worker_nodes)
        self._inflight: dict[int, dict[int, Future]] = {
            n: {} for n in pool.worker_nodes
        }
        self._credits: dict[int, threading.Semaphore] = {
            n: threading.Semaphore(self.max_inflight) for n in pool.worker_nodes
        }
        self._rr = 0
        self.stats = {
            "submitted": 0,
            "completed": 0,
            "failed_inflight": 0,
            "locality_hits": 0,
            "session_routed": 0,
            "fused_calls": 0,
            "retries": 0,
            "deadline_failed": 0,
            "replay_acks": 0,
            "oneways": 0,
            "mutations_committed": 0,
            "routed": {n: 0 for n in pool.worker_nodes},
        }
        # -- mutate-at-data state (module docs) ----------------------------
        #: handlers already warned for undeclared replicated-buffer access
        self._warned: set[str] = set()
        #: lazily-started commit pipeline: completion callbacks run on the
        #: event-loop thread, where a synchronous invalidation send would
        #: deadlock — commits hop to this daemon thread instead
        self._commit_q: _queue.SimpleQueue | None = None
        self._commit_thread: threading.Thread | None = None
        #: sticky-session affinity over this scheduler's live set
        self.sessions = SessionRouter(self.live_nodes)
        if self._directory is not None:
            # crash failover / drain migration re-pin: a session whose
            # buffers moved follows its data (fires from the directory's
            # promotion, which the pool runs BEFORE our death callback)
            self._directory.on_repin(self.sessions.repin)
        pool.on_death(self._on_worker_death)
        pool.on_restart(self._on_worker_join)
        pool.on_join(self._on_worker_join)
        pool.on_leave(self._on_worker_leave)
        # reconcile deaths announced BEFORE we subscribed (e.g. a worker
        # that crashed during pool startup): _on_worker_death is idempotent,
        # so racing a concurrent announcement is harmless
        for n in pool.worker_nodes:
            if not pool.is_alive(n):
                self._on_worker_death(n)

    # -- routing -----------------------------------------------------------

    def _load(self, node: int) -> int:
        """Load estimate: host-side in-flight plus the worker's last
        reported queue depth (0 until a report arrives).  The two overlap —
        a call the host counts may also sit in the worker's queue — but the
        estimate is monotone in both, which is all ranking needs."""
        return len(self._inflight[node]) + self.host.peer_depth.get(node, 0)

    def _pick(self, function: Function) -> int | None:
        """Choose a live target under the active policy (caller holds no
        lock; this takes it).  Returns None when no workers are live."""
        with self._lock:
            live = sorted(self._live)
            if not live:
                return None
            d = self._directory
            if d is not None and len(d) \
                    and getattr(function.record, "mutates", False):
                # mutate-at-data routing (module docs): a declared-mutating
                # call executes WHERE its buffers live, under every policy —
                # the primary holds the authoritative copy the write must
                # land on.  nbytes-weighted like locality voting.
                votes = mig.scan_locality(
                    function.args, resolver=d.primary_resolver
                )
                alive_votes = {
                    n: c for n, c in votes.items() if n in self._live
                }
                if alive_votes:
                    self.stats["locality_hits"] += 1
                    return max(
                        alive_votes,
                        key=lambda n: (alive_votes[n], -self._load(n)),
                    )
            # prefer nodes with a free credit so one saturated worker does
            # not block traffic the others could take (flow-control contract)
            uncongested = [
                n for n in live
                if len(self._inflight[n]) < self.max_inflight
            ]
            candidates = uncongested or live
            if self.policy == "locality":
                # votes are nbytes-weighted: route to where the bulk of the
                # referenced data lives, not to whoever owns the most ptrs.
                # Directory-tracked buffers vote for EVERY live holder only
                # when the handler is declared read_only (any copy can serve
                # a read); an undeclared call votes for — and will have its
                # pointers pinned to — the primary, so a buffer-mutating
                # handler can never be routed at a replica and diverge it
                d = self._directory
                resolver = None
                if d is not None and len(d):
                    resolver = (
                        d.locality_resolver if function.record.read_only
                        else d.primary_resolver
                    )
                votes = mig.scan_locality(function.args, resolver=resolver)
                alive_votes = {n: c for n, c in votes.items() if n in self._live}
                if alive_votes:
                    self.stats["locality_hits"] += 1
                    # most bytes win; break ties toward the shorter queue
                    return max(
                        alive_votes,
                        key=lambda n: (alive_votes[n], -self._load(n)),
                    )
            if self.policy == "round_robin":
                self._rr += 1
                return candidates[self._rr % len(candidates)]
            return min(candidates, key=lambda n: (self._load(n), n))

    def submit(self, function: Function, *, node: int | None = None,
               session=None, deadline: float | None = None,
               retries: int | None = None) -> Future:
        """Route ``function`` to a worker and return its future.

        ``node=`` pins the target (raises :class:`NodeDownError` if it is
        dead — pinned calls are not rerouted; reroute-on-death applies to
        policy-routed traffic).  ``session=`` routes through the sticky
        :class:`SessionRouter` instead of the policy: same worker for the
        session's lifetime, re-placed only if that worker leaves the live
        set.  Blocks for a credit when the target is saturated;
        :class:`OffloadError` after ``submit_timeout``.

        ``deadline=`` / ``retries=`` override the scheduler-wide defaults
        for this call (module docs: Deadlines, retries and exactly-once
        replay).  A deadlined call whose reply never arrives is
        retransmitted up to ``retries`` times (same msg_id, same worker,
        ``FLAG_RETRYABLE`` — the worker's replay cache keeps mutating
        handlers exactly-once), then failed with an OffloadError diagnosis
        instead of stranding its future.

        A *pinned* submit waits on its node's credit for the whole timeout
        (that node is the request).  A *policy-routed* submit must not get
        stuck behind one slow worker while another frees up, so it waits in
        short slices and re-picks between them — it blocks for the full
        timeout only when the entire pool stays saturated.  A *session*
        submit waits like a pinned one (its worker is the session), but a
        death during the wait re-places the session rather than failing.
        """
        import time

        if node is not None and session is not None:
            raise OffloadError("submit takes node= or session=, not both")
        # mutate-at-data bookkeeping (module docs): collect the directory
        # handles a declared-mutating call references — its future commits
        # their dirty epochs on completion — and warn ONCE per handler for
        # undeclared replicated-buffer access.  Cost when the directory is
        # empty or the handler is declared read_only: one attribute check.
        mutate_handles: tuple[int, ...] = ()
        d = self._directory
        if d is not None and not d.empty() and not function.record.read_only:
            if getattr(function.record, "mutates", False):
                mutate_handles = self._tracked_handles(function.args)
            else:
                self._warn_undeclared(function)
        call_deadline = self.deadline if deadline is None else deadline
        call_retries = self.retries if retries is None else int(retries)
        # the flag rides EVERY attempt including the first: the worker must
        # enter the call into its replay cache before any duplicate can land
        extra_flags = (
            FLAG_RETRYABLE
            if call_deadline is not None and call_retries > 0 else 0
        )
        bp_deadline = (
            None if self.submit_timeout is None
            else time.monotonic() + self.submit_timeout
        )
        while True:
            if node is not None:
                if not self._is_live(node):
                    raise NodeDownError(f"worker {node} is down")
                target = node
            elif session is not None:
                # data-affine first placement: a session with buffers bound
                # in the directory starts life on the node holding its
                # bytes (later failover repins keep it there); sessions
                # without bound buffers place by plain rendezvous hash
                eligible = None
                if self._directory is not None and len(self._directory) \
                        and self.sessions.lookup(session) is None:
                    home = self._directory.session_home(session)
                    if home is not None and self._is_live(home):
                        eligible = (home,)
                target = self.sessions.route(session, eligible=eligible)
                if target is None:
                    raise OffloadError("no live workers in the pool")
            else:
                target = self._pick(function)
                if target is None:
                    raise OffloadError("no live workers in the pool")
            # location transparency (module docs): rewrite stale-epoch
            # BufferPtr hints against the directory and retarget pointers
            # at the chosen node when it holds a copy — BEFORE a credit is
            # spent, so a genuinely lost buffer raises cleanly here
            function = self._resolve_for(function, target)
            sem = self._credits.get(target)
            if sem is None:
                continue  # node retired between route and credit lookup
            remaining = (
                None if bp_deadline is None
                else max(0.0, bp_deadline - time.monotonic())
            )
            if node is None:
                # policy AND session submits wait in slices: a session stays
                # on its pinned worker while it lives (route keeps returning
                # the pin), but a death mid-wait is noticed within a slice
                # and re-placed instead of burning the whole timeout
                slice_s = 0.05 if remaining is None else min(0.05, remaining)
                acquired = sem.acquire(timeout=slice_s)
            elif remaining is not None:
                acquired = sem.acquire(timeout=remaining)
            else:
                acquired = sem.acquire()
            if not acquired:
                if bp_deadline is None or time.monotonic() < bp_deadline:
                    continue  # slice expired: re-pick with fresh queue state
                raise OffloadError(
                    f"backpressure timeout: worker {target} held "
                    f"{self.max_inflight} in-flight calls for "
                    f"{self.submit_timeout}s"
                )
            # reserve the in-flight slot ATOMICALLY with the liveness check:
            # a fence (remove_node) or death between "target is live" and
            # the insert would otherwise miss this call — the drain waiter
            # would not wait for it, or a drained removal would spuriously
            # fail a call its still-alive worker was about to serve
            msg_id, fut = self.host.futures.create()
            with self._lock:
                live_now = target in self._live and target in self._inflight
                if live_now:
                    self._inflight[target][msg_id] = fut
                    self.stats["submitted"] += 1
                    if session is not None:
                        self.stats["session_routed"] += 1
                    self.stats["routed"][target] = (
                        self.stats["routed"].get(target, 0) + 1
                    )
            if live_now:
                break
            # target fenced/died between pick and credit grant: put the
            # credit back, drop the unused future, and re-route (or fail a
            # pinned call; a session submit re-places on the next iteration)
            self.host.futures.discard(msg_id)
            sem.release()
            if node is not None:
                raise NodeDownError(f"worker {node} is down")
        if call_deadline is not None:
            # armed BEFORE the send so a reply can never race an untracked
            # call; a reply that beats the insert is reconciled by _on_done's
            # pop (and the watchdog's discard() losing to the resolve)
            self._track(msg_id, target, function, call_deadline,
                        call_retries, bool(extra_flags))
        if self.fuse_window is not None and not extra_flags \
                and self._fusible(function):
            # park for fusion: the credit/in-flight reservation above holds,
            # the done-callback is registered NOW (a death or a failed fused
            # send rejects the future, which releases the credit), and the
            # flusher/batch-full/ordering/adaptive triggers ship the frame
            fut.add_done_callback(lambda f, n=target: self._on_done(n, f))
            with self._lock:
                pend = self._fuse_pending.setdefault(target, [])
                pend.append((function, msg_id))
                self.stats["fused_calls"] += 1
                full = len(pend) >= self.fuse_max
                # adaptive close (module docs): ship NOW when the target has
                # nothing in flight beyond this parked batch (an idle worker
                # gains nothing from waiting) or when its credit pool just
                # drained (no future submit can join the batch)
                inflight = len(self._inflight.get(target, ()))
                adaptive = self.fuse_adaptive and (
                    inflight <= len(pend) or inflight >= self.max_inflight
                )
            if full or adaptive:
                self._flush_target(target)
            if mutate_handles:
                return self._wrap_mutating(fut, mutate_handles)
            return fut
        if self.fuse_window is not None:
            # a non-fusible frame must not overtake parked calls to the
            # same target: drain them and send THIS frame under the same
            # send lock, so per-target submission order is preserved even
            # against the flusher thread and concurrent submitters
            with self._send_lock(target):
                self._pop_and_send(target)
                self._send_single(target, function, msg_id, sem, extra_flags)
        else:
            self._send_single(target, function, msg_id, sem, extra_flags)
        # registered after the send: if a death handler already rejected
        # the future, the callback runs immediately and returns the credit
        fut.add_done_callback(lambda f, n=target: self._on_done(n, f))
        if mutate_handles:
            return self._wrap_mutating(fut, mutate_handles)
        return fut

    def _resolve_for(self, function: Function, target: int) -> Function:
        """Directory pass over a call's arguments: stale-epoch pointers are
        rewritten to the current primary, and — for handlers declared
        ``read_only`` — pointers whose buffer has a copy ON ``target`` are
        retargeted there (the receiving node's own-address-space deref
        check must see itself).  A call NOT declared read-only keeps its
        pointers pinned to the primary even when ``target`` holds a
        replica: a handler that writes through ``deref`` must never update
        a replica copy behind the write-through protocol's back (dataplane
        module docs) — routed at a non-holder it fails the deref check
        loudly instead of diverging silently.  A no-op without a directory
        or when nothing is tracked."""
        d = self._directory
        if d is None or d.empty():
            return function
        new_args, changed = d.resolve_args(
            function.args, target if function.record.read_only else None
        )
        if not changed:
            return function
        return Function(function.record, new_args)

    # -- mutate-at-data plumbing (module docs) ------------------------------

    def _tracked_handles(self, args) -> tuple[int, ...]:
        """Directory-tracked buffer handles referenced by ``args`` (the
        handles a mutating call's commit must invalidate) — the shared
        dataplane walk, same depth bound as ``resolve_args``."""
        from repro.offload.dataplane import tracked_handles

        return tracked_handles(self._directory, args)

    def _warn_undeclared(self, function: Function) -> None:
        """One-shot warning (module docs): a handler declared neither
        ``read_only`` nor ``mutates`` is touching a *replicated* buffer —
        if it writes through deref, replicas are never invalidated and a
        replica-served read may observe stale bytes.  Cost after the first
        warning: one set lookup."""
        name = function.record.stable_name
        if name in self._warned:
            return
        d = self._directory
        for h in self._tracked_handles(function.args):
            rec = d.lookup(h)
            if rec is not None and rec.replicas:
                self._warned.add(name)
                _log.warning(
                    "handler %r dereferences replicated buffer %#x but "
                    "declares neither read_only=True nor mutates=True: an "
                    "in-place write would NOT invalidate the buffer's "
                    "replicas, and a replica-served read could observe "
                    "stale bytes.  Declare the handler's intent (see "
                    "docs/failure-model.md, 'Write visibility and "
                    "convergence'; hamlint HAM001 finds this statically).",
                    name, h,
                )
                return

    def _wrap_mutating(self, fut: Future, handles: tuple[int, ...]) -> Future:
        """Outer future for a declared-mutating call: resolves with the
        inner call's result/error only AFTER ``pool.commit_mutation`` ran
        for ``handles`` on the commit thread (module docs — the commit runs
        on success AND error, because a handler may mutate before raising).
        """
        outer = Future()
        outer.msg_id = fut.msg_id
        fut.add_done_callback(
            lambda f: self._commit_enqueue(f, outer, handles)
        )
        return outer

    def _commit_enqueue(self, inner: Future, outer: Future,
                        handles: tuple[int, ...]) -> None:
        with self._lock:
            if self._commit_q is None:
                self._commit_q = _queue.SimpleQueue()
                self._commit_thread = threading.Thread(
                    target=self._commit_loop, name="ham-sched-commit",
                    daemon=True,
                )
                self._commit_thread.start()
            q = self._commit_q
        q.put((inner, outer, handles))

    def _commit_loop(self) -> None:
        while True:
            inner, outer, handles = self._commit_q.get()
            commit_error: BaseException | None = None
            try:
                commit = getattr(self.pool, "commit_mutation", None)
                if commit is not None and handles:
                    commit(handles)
                    with self._lock:
                        self.stats["mutations_committed"] += 1
            except BaseException as e:  # noqa: BLE001 — surfaces on outer
                commit_error = e
            exc = inner.exception()
            if exc is not None:
                # the call's own failure outranks a commit failure (the
                # commit still ran first — replicas are not left serving
                # a partial write)
                outer.set_exception(exc)
            elif commit_error is not None:
                outer.set_exception(OffloadError(
                    f"mutation committed on the primary but replica "
                    f"invalidation failed for handles "
                    f"{[hex(h) for h in handles]}: "
                    f"{type(commit_error).__name__}: {commit_error} — "
                    f"replicas may serve stale bytes until the next "
                    f"backfill (docs/failure-model.md, 'Write visibility "
                    f"and convergence')"
                ))
            else:
                outer.set_result(inner.get(0))

    def oneway(self, function: Function, *, node: int | None = None,
               session=None) -> None:
        """Fire-and-forget control send: no future, no credit, no reply —
        the cluster-level twin of ``NodeRuntime.send_oneway`` with this
        scheduler's routing applied.  ``session=`` follows the sticky pin
        (a cancel must land on the worker decoding the session), ``node=``
        pins, otherwise the policy picks.  Raises :class:`NodeDownError` /
        :class:`OffloadError` when no target is live; delivery past the
        send is best-effort (docs/failure-model.md: oneways are
        at-most-once)."""
        if node is not None and session is not None:
            raise OffloadError("oneway takes node= or session=, not both")
        if node is not None:
            if not self._is_live(node):
                raise NodeDownError(f"worker {node} is down")
            target = node
        elif session is not None:
            target = self.sessions.route(session)
            if target is None:
                raise OffloadError("no live workers in the pool")
        else:
            target = self._pick(function)
            if target is None:
                raise OffloadError("no live workers in the pool")
        function = self._resolve_for(function, target)
        domain = getattr(self.pool, "domain", None)
        if domain is None:
            raise OffloadError("pool exposes no oneway transport")
        if self.fuse_window is not None:
            # must not overtake calls parked for fusion toward this target
            with self._send_lock(target):
                self._pop_and_send(target)
                domain.oneway(target, function)
        else:
            domain.oneway(target, function)
        with self._lock:
            self.stats["oneways"] += 1

    def end_session(self, key) -> None:
        """End a sticky session: drop its routing pin AND free the buffers
        bound to it cluster-wide (replicas invalidated, ``live_count``
        truthful — the dataplane hygiene contract)."""
        self.sessions.end_session(key)
        release = getattr(self.pool, "release_session", None)
        if release is not None:
            release(key)

    def _send_single(self, target: int, function: Function, msg_id: int,
                     sem, extra_flags: int = 0) -> None:
        try:
            self.host._send_request(target, function, msg_id, extra_flags)
        except Exception:
            # the frame never left: withdraw the reservation.  If a death
            # handler raced us it already rejected the future (discard is
            # then a no-op) — either way no reply can arrive for the id.
            with self._lock:
                d = self._inflight.get(target)
                if d is not None:
                    d.pop(msg_id, None)
                self._tracked.pop(msg_id, None)
            self.host.futures.discard(msg_id)
            sem.release()
            raise

    # -- deadlines / retries (module docs) ----------------------------------

    def _track(self, msg_id: int, node: int, function: Function,
               timeout: float, retries: int, retryable: bool) -> None:
        """Arm the watchdog for one call.  Entry layout:
        ``[node, function, expires, attempts_left, attempt_timeout,
        retryable]`` — mutated in place by the watchdog on retransmit."""
        import time

        entry = [node, function, time.monotonic() + float(timeout),
                 int(retries), float(timeout), retryable]
        with self._lock:
            self._tracked[msg_id] = entry
            if self._watchdog is None:
                self._watchdog = threading.Thread(
                    target=self._watchdog_loop, name="ham-sched-watchdog",
                    daemon=True,
                )
                self._watchdog.start()

    def _watchdog_loop(self) -> None:
        import time

        while not self._watchdog_stop.wait(0.02):
            now = time.monotonic()
            with self._lock:
                expired = [
                    (msg_id, e) for msg_id, e in self._tracked.items()
                    if e[2] <= now
                ]
            for msg_id, e in expired:
                node = e[0]
                if e[5] and e[3] > 0 and self._is_live(node):
                    with self._lock:
                        if self._tracked.get(msg_id) is not e:
                            continue  # completed while we scanned
                        e[3] -= 1
                        # capped exponential backoff on the attempt timeout
                        e[4] = min(e[4] * self.retry_backoff, self.retry_cap)
                        e[2] = time.monotonic() + e[4]
                    try:
                        # same msg_id, same worker, FLAG_RETRYABLE: the
                        # replay cache makes this exactly-once (module docs)
                        self.host._send_request(node, e[1], msg_id,
                                                FLAG_RETRYABLE)
                        self.stats["retries"] += 1
                    except Exception:  # noqa: BLE001 — transport refused the
                        # retransmit (peer fenced/partitioned): fail now, the
                        # remaining attempts could not leave either
                        self._fail_tracked(msg_id, e, "retransmit failed")
                else:
                    self._fail_tracked(msg_id, e, "deadline exhausted")
            self._send_replay_acks(now)

    def _fail_tracked(self, msg_id: int, entry: list, why: str) -> None:
        node, function = entry[0], entry[1]
        with self._lock:
            if self._tracked.get(msg_id) is not entry:
                return
            del self._tracked[msg_id]
            fut = self._inflight.get(node, {}).get(msg_id)
        # discard() pops the table entry: winning this race means no reply
        # can resolve the future behind us AND a straggler reply is dropped
        if fut is not None and self.host.futures.discard(msg_id):
            self.stats["deadline_failed"] += 1
            fut.set_exception(OffloadError(
                f"call {function.record.stable_name!r} to worker {node} "
                f"{why}: no reply within {entry[4]:.3g}s (attempts "
                f"exhausted).  The worker may be overloaded, partitioned, "
                f"or its reply was lost — delivery guarantees per path are "
                f"in docs/failure-model.md"
            ))

    def _send_replay_acks(self, now: float) -> None:
        """Piggybacked cumulative acks: tell each worker the highest msg_id
        below every outstanding retryable call — its replay cache can evict
        everything at or below.  Best-effort oneways, at most ~1/s/worker
        (the cache's FIFO cap bounds memory even if these never arrive)."""
        domain = getattr(self.pool, "domain", None)
        if domain is None:
            return
        pending = []
        with self._lock:
            floor: dict[int, int] = {}
            for msg_id, e in self._tracked.items():
                if e[5] and (e[0] not in floor or msg_id < floor[e[0]]):
                    floor[e[0]] = msg_id
            for node, hwm in self._retry_hwm.items():
                upto = hwm if node not in floor else min(floor[node] - 1, hwm)
                st = self._ack_state.setdefault(node, [0, 0.0])
                if upto > st[0] and now - st[1] >= 1.0 and node in self._live:
                    st[0], st[1] = upto, now
                    pending.append((node, upto))
        for node, upto in pending:
            try:
                domain.oneway(node, f2f(
                    "_ham/replay_ack", self.host.node_id, upto,
                    registry=domain.registry,
                ))
                self.stats["replay_acks"] += 1
            except Exception:  # noqa: BLE001 — ack loss only delays eviction
                pass

    # -- small-call fusion (module docs) -----------------------------------

    def _fusible(self, function: Function) -> bool:
        try:
            key = self.host.table.key_of(function.record.stable_name)
        except Exception:  # noqa: BLE001 — let _send_request raise properly
            return False
        plan = self.host._arg_plans[key]
        if plan is not None:
            return plan.nbytes <= FUSE_THRESHOLD
        # dynamic handler: a shape-cacheable call packs through a cached
        # WirePlan (FLAG_SHAPED segment) with known size — fuse it under the
        # same threshold; non-speccable shapes stay unfused (size unknown
        # without a TLV measuring walk, which defeats the point)
        cache = self.host._shape_cache
        if cache is None:
            return False
        shaped = cache.for_values(function.args, "A")
        return (shaped is not None
                and shaped[1].nbytes + len(shaped[0]) <= FUSE_THRESHOLD)

    def _send_lock(self, target: int) -> threading.RLock:
        with self._lock:
            lock = self._send_locks.get(target)
            if lock is None:
                lock = self._send_locks[target] = threading.RLock()
            return lock

    def _send_fused(self, target: int, entries: list) -> None:
        """Ship one parked batch; a failed send fails exactly its calls."""
        try:
            self.host._send_fused_request(target, entries)
        except Exception as e:  # noqa: BLE001 — reject -> done-callback
            # returns each credit and pops each in-flight entry
            for _, msg_id in entries:
                self.host.futures.reject(
                    msg_id, f"fused send to worker {target} failed: "
                    f"{type(e).__name__}: {e}", ""
                )

    def _pop_and_send(self, target: int) -> None:
        """Pop and ship a parked batch; caller holds the target's send lock
        (pop and send must be atomic per target, or two flushers could
        reorder batches between the pop and the wire)."""
        with self._lock:
            entries = self._fuse_pending.pop(target, None)
        if entries:
            self._send_fused(target, entries)

    def _flush_target(self, target: int) -> None:
        with self._send_lock(target):
            self._pop_and_send(target)

    def flush(self) -> None:
        """Ship every parked fused batch now (also runs on the window)."""
        with self._lock:
            targets = list(self._fuse_pending)
        for target in targets:
            self._flush_target(target)

    def _fuse_flusher(self) -> None:
        while not self._fuse_stop.wait(self.fuse_window):
            self.flush()

    def close(self) -> None:
        """Stop the fusion flusher and deadline watchdog, and ship any
        parked calls.  Idempotent; only needed when the scheduler was built
        with ``fuse_window=`` or has submitted deadlined calls."""
        self._fuse_stop.set()
        if self._fuse_thread is not None:
            self._fuse_thread.join(timeout=2.0)
            self._fuse_thread = None
        self._watchdog_stop.set()
        if self._watchdog is not None:
            self._watchdog.join(timeout=2.0)
            self._watchdog = None
        self.flush()

    def map(self, functions: Iterable[Function]) -> list[Future]:
        """Submit a batch; completions pipeline (harvest via as_completed)."""
        return [self.submit(fn) for fn in functions]

    def drain(self, timeout: float | None = 60.0) -> None:
        """Block until every tracked in-flight call completes."""
        with self._lock:
            futs = [f for d in self._inflight.values() for f in d.values()]
        for _ in as_completed(futs, timeout):
            pass

    # -- introspection -----------------------------------------------------

    def _is_live(self, node: int) -> bool:
        with self._lock:
            return node in self._live

    def live_nodes(self) -> list[int]:
        with self._lock:
            return sorted(self._live)

    def outstanding(self, node: int | None = None) -> int:
        with self._lock:
            if node is not None:
                return len(self._inflight.get(node, ()))
            return sum(len(d) for d in self._inflight.values())

    # -- completion / failure plumbing ------------------------------------

    def _on_done(self, node: int, fut: Future) -> None:
        with self._lock:
            d = self._inflight.get(node)
            if d is not None:
                d.pop(fut.msg_id, None)
            entry = self._tracked.pop(fut.msg_id, None)
            if entry is not None and entry[5] \
                    and fut.msg_id > self._retry_hwm.get(node, 0):
                # completed retryable call: raise the replay-ack HWM so the
                # worker's cached reply for it becomes evictable
                self._retry_hwm[node] = fut.msg_id
            sem = self._credits.get(node)
            self.stats["completed"] += 1
        if sem is not None:
            sem.release()
        if self.fuse_window is not None and self.fuse_adaptive:
            # adaptive close, completion edge: the target's wire in-flight
            # just sank to (at most) its parked batch — the worker is about
            # to go idle, so holding the batch for the timer is pure latency
            with self._lock:
                pend = self._fuse_pending.get(node)
                drained = bool(pend) and \
                    len(self._inflight.get(node, ())) <= len(pend)
            if drained:
                self._flush_target(node)

    def _on_worker_death(self, node: int) -> None:
        """Pool monitor callback: fail this node's in-flight calls and stop
        routing to it (failure-semantics contract in the module docs).
        Sessions pinned to the node re-place lazily on their next submit."""
        with self._lock:
            self._live.discard(node)
            stale = self._inflight.get(node, {})
            if node in self._inflight:
                self._inflight[node] = {}
            self.stats["failed_inflight"] += len(stale)
            self.host.peer_depth.pop(node, None)  # stale busy signal
        for msg_id in list(stale):
            # reject -> RemoteExecutionError at every waiter, and the popped
            # table entry drops any straggler reply for this msg_id
            self.host.futures.reject(
                msg_id, f"worker {node} died with this call in flight", ""
            )

    def _on_worker_join(self, node: int) -> None:
        """Pool callback for an added *or restarted* worker: create (or
        reset) its routing state atomically, then admit it (resize contract
        in the module docs)."""
        with self._lock:
            self._inflight[node] = {}
            self._credits[node] = threading.Semaphore(self.max_inflight)
            self.stats["routed"].setdefault(node, 0)
            self.host.peer_depth.pop(node, None)
            self._live.add(node)

    def _on_worker_leave(self, node: int):
        """Pool callback at the start of ``remove_node``: fence the node
        (out of the routing set immediately) and hand back a drain waiter
        that retires its state once its in-flight futures resolve."""
        with self._lock:
            self._live.discard(node)

        def _drain_and_retire(timeout: float | None = 30.0) -> None:
            with self._lock:
                futs = list(self._inflight.get(node, {}).values())
            for _ in as_completed(futs, timeout):
                pass
            self._retire_node(node)

        return _drain_and_retire

    def _retire_node(self, node: int) -> None:
        """Atomically drop a removed node's credit/in-flight/depth state and
        evict its sessions (their next submit re-places them).  The id is
        never reused, so nothing can resurrect the entries."""
        with self._lock:
            self._live.discard(node)
            self._inflight.pop(node, None)
            self._credits.pop(node, None)
            self.host.peer_depth.pop(node, None)
        self.sessions.evict_node(node)
