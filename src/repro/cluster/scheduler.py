"""Policy-driven scheduler with credit-based flow control over a ClusterPool.

The paper's ``offload::async`` takes an explicit target node; this layer
picks the node, keeps many calls in flight per worker, and survives worker
death — the futurized, load-balanced dispatch direction of HPX ("Closing the
Performance Gap with Modern C++") and the data-centric routing of Active
Access (Besta et al.), built on HAM's unchanged message layer.

Scheduling policies
-------------------

``policy=`` selects how :meth:`Scheduler.submit` routes a call whose target
was not pinned with ``node=``:

* ``"round_robin"`` — cycle through live workers in node order.  Stateless
  and fair for uniform work; degrades when call costs vary (a slow call
  holds up its node while the cycle keeps loading it evenly).
* ``"least_outstanding"`` — pick the live worker with the fewest in-flight
  calls (ties break toward the lowest node id).  The default: it is
  adaptive join-shortest-queue — slow workers accumulate outstanding calls
  and automatically shed new load to faster ones.
* ``"locality"`` — scan the call's arguments for migratable values with a
  registered locality hook (``buffer_ptr`` reports its owning node; see
  ``migratable.register_migratable(locality=...)``) and prefer the live
  node holding the most referenced buffers; calls with no locality votes
  (or whose owner is dead) fall back to least-outstanding.  This routes
  compute to data instead of data to compute.

Credit-based flow control (the backpressure contract)
-----------------------------------------------------

Every worker has ``max_inflight`` *credits*.  ``submit`` consumes one
credit on its target before the frame is sent and the credit is returned
when the call's future completes (result, remote error, or node death) —
so per-node in-flight frames are bounded by construction:

* a slow worker saturates its credits and ``submit`` **blocks** the caller
  (bounded by ``submit_timeout``, then :class:`OffloadError`) instead of
  ballooning the transport queue / shm ring behind the worker;
* policy routing only considers nodes with a free credit when any exists,
  so one stuck worker does not stall traffic that other workers could
  absorb — blocking happens only when the whole pool is saturated (or the
  call is pinned);
* credits are per-scheduler state, not a wire protocol: the transport's own
  bounded rings remain the hard backstop underneath.

Failure semantics
-----------------

The pool's monitor announces a dead worker; the scheduler then (1) removes
the node from the routing set, (2) fails every tracked in-flight future on
that node with :class:`RemoteExecutionError` *through the host's future
table* — popping the table entry, so a straggler reply from a restarted
node id is dropped rather than resurrecting a failed future — and (3)
routes subsequent submits to the survivors.  On restart the node rejoins
with a fresh credit pool.
"""

from __future__ import annotations

import threading
from typing import Iterable

from repro.core import migratable as mig
from repro.core.closure import Function
from repro.core.errors import NodeDownError, OffloadError
from repro.core.future import Future, as_completed, gather
from repro.cluster.pool import ClusterPool

__all__ = ["Scheduler", "as_completed", "gather"]

POLICIES = ("round_robin", "least_outstanding", "locality")


class Scheduler:
    """Routes ``submit`` calls across a :class:`ClusterPool` (module docs
    define the policy and flow-control contracts)."""

    def __init__(
        self,
        pool: ClusterPool,
        *,
        policy: str = "least_outstanding",
        max_inflight: int = 32,
        submit_timeout: float | None = 30.0,
    ):
        if policy not in POLICIES:
            raise OffloadError(f"unknown policy {policy!r}; one of {POLICIES}")
        self.pool = pool
        self.host = pool.host
        self.policy = policy
        self.max_inflight = int(max_inflight)
        self.submit_timeout = submit_timeout
        self._lock = threading.Lock()
        self._live: set[int] = set(pool.worker_nodes)
        self._inflight: dict[int, dict[int, Future]] = {
            n: {} for n in pool.worker_nodes
        }
        self._credits: dict[int, threading.Semaphore] = {
            n: threading.Semaphore(self.max_inflight) for n in pool.worker_nodes
        }
        self._rr = 0
        self.stats = {
            "submitted": 0,
            "completed": 0,
            "failed_inflight": 0,
            "locality_hits": 0,
            "routed": {n: 0 for n in pool.worker_nodes},
        }
        pool.on_death(self._on_worker_death)
        pool.on_restart(self._on_worker_restart)
        # reconcile deaths announced BEFORE we subscribed (e.g. a worker
        # that crashed during pool startup): _on_worker_death is idempotent,
        # so racing a concurrent announcement is harmless
        for n in pool.worker_nodes:
            if not pool.is_alive(n):
                self._on_worker_death(n)

    # -- routing -----------------------------------------------------------

    def _pick(self, function: Function) -> int | None:
        """Choose a live target under the active policy (caller holds no
        lock; this takes it).  Returns None when no workers are live."""
        with self._lock:
            live = sorted(self._live)
            if not live:
                return None
            # prefer nodes with a free credit so one saturated worker does
            # not block traffic the others could take (flow-control contract)
            uncongested = [
                n for n in live
                if len(self._inflight[n]) < self.max_inflight
            ]
            candidates = uncongested or live
            if self.policy == "locality":
                votes = mig.scan_locality(function.args)
                alive_votes = {n: c for n, c in votes.items() if n in self._live}
                if alive_votes:
                    self.stats["locality_hits"] += 1
                    # most buffers win; break ties toward the shorter queue
                    return max(
                        alive_votes,
                        key=lambda n: (alive_votes[n], -len(self._inflight[n])),
                    )
            if self.policy == "round_robin":
                self._rr += 1
                return candidates[self._rr % len(candidates)]
            return min(candidates, key=lambda n: (len(self._inflight[n]), n))

    def submit(self, function: Function, *, node: int | None = None) -> Future:
        """Route ``function`` to a worker and return its future.

        ``node=`` pins the target (raises :class:`NodeDownError` if it is
        dead — pinned calls are not rerouted; reroute-on-death applies to
        policy-routed traffic).  Blocks for a credit when the target is
        saturated; :class:`OffloadError` after ``submit_timeout``.

        A *pinned* submit waits on its node's credit for the whole timeout
        (that node is the request).  A *policy-routed* submit must not get
        stuck behind one slow worker while another frees up, so it waits in
        short slices and re-picks between them — it blocks for the full
        timeout only when the entire pool stays saturated.
        """
        import time

        deadline = (
            None if self.submit_timeout is None
            else time.monotonic() + self.submit_timeout
        )
        while True:
            if node is not None:
                if not self._is_live(node):
                    raise NodeDownError(f"worker {node} is down")
                target = node
            else:
                target = self._pick(function)
                if target is None:
                    raise OffloadError("no live workers in the pool")
            sem = self._credits[target]
            remaining = (
                None if deadline is None else max(0.0, deadline - time.monotonic())
            )
            if node is None:
                slice_s = 0.05 if remaining is None else min(0.05, remaining)
                acquired = sem.acquire(timeout=slice_s)
            elif remaining is not None:
                acquired = sem.acquire(timeout=remaining)
            else:
                acquired = sem.acquire()
            if not acquired:
                if deadline is None or time.monotonic() < deadline:
                    continue  # slice expired: re-pick with fresh queue state
                raise OffloadError(
                    f"backpressure timeout: worker {target} held "
                    f"{self.max_inflight} in-flight calls for "
                    f"{self.submit_timeout}s"
                )
            if self._is_live(target):
                break
            # target died between pick and credit grant: put the credit
            # back and re-route (or fail a pinned call)
            sem.release()
            if node is not None:
                raise NodeDownError(f"worker {node} is down")
        try:
            fut = self.host.send_async(target, function)
        except Exception:
            sem.release()  # no future exists to return the credit later
            raise
        with self._lock:
            self.stats["submitted"] += 1
            self.stats["routed"][target] = self.stats["routed"].get(target, 0) + 1
            still_live = target in self._live
            if still_live:
                self._inflight[target][fut.msg_id] = fut
        fut.add_done_callback(lambda f, n=target: self._on_done(n, f))
        if not still_live:
            # death raced the send: the death handler never saw this future,
            # so fail it here (reject pops the table entry — a stray reply
            # from a restarted node id is dropped, not delivered)
            self.host.futures.reject(
                fut.msg_id, f"worker {target} died with this call in flight", ""
            )
        return fut

    def map(self, functions: Iterable[Function]) -> list[Future]:
        """Submit a batch; completions pipeline (harvest via as_completed)."""
        return [self.submit(fn) for fn in functions]

    def drain(self, timeout: float | None = 60.0) -> None:
        """Block until every tracked in-flight call completes."""
        with self._lock:
            futs = [f for d in self._inflight.values() for f in d.values()]
        for _ in as_completed(futs, timeout):
            pass

    # -- introspection -----------------------------------------------------

    def _is_live(self, node: int) -> bool:
        with self._lock:
            return node in self._live

    def live_nodes(self) -> list[int]:
        with self._lock:
            return sorted(self._live)

    def outstanding(self, node: int | None = None) -> int:
        with self._lock:
            if node is not None:
                return len(self._inflight.get(node, ()))
            return sum(len(d) for d in self._inflight.values())

    # -- completion / failure plumbing ------------------------------------

    def _on_done(self, node: int, fut: Future) -> None:
        with self._lock:
            d = self._inflight.get(node)
            if d is not None:
                d.pop(fut.msg_id, None)
            sem = self._credits.get(node)
            self.stats["completed"] += 1
        if sem is not None:
            sem.release()

    def _on_worker_death(self, node: int) -> None:
        """Pool monitor callback: fail this node's in-flight calls and stop
        routing to it (failure-semantics contract in the module docs)."""
        with self._lock:
            self._live.discard(node)
            stale = self._inflight.get(node, {})
            self._inflight[node] = {}
            self.stats["failed_inflight"] += len(stale)
        for msg_id in list(stale):
            # reject -> RemoteExecutionError at every waiter, and the popped
            # table entry drops any straggler reply for this msg_id
            self.host.futures.reject(
                msg_id, f"worker {node} died with this call in flight", ""
            )

    def _on_worker_restart(self, node: int) -> None:
        with self._lock:
            self._live.add(node)
            self._inflight[node] = {}
            self._credits[node] = threading.Semaphore(self.max_inflight)
