"""Grouped (per-expert) matmul Pallas TPU kernel for the MoE layer.

Computes out[e] = x[e] @ w[e] for every expert e over the capacity-padded
dispatch layout (E, C, d) × (E, d, f) → (E, C, f) — the exact contraction
``moe_apply`` issues twice per layer (up/gate) plus once transposed (down).

MXU-aligned tiling: (bc × bd) · (bd × bf) accumulated in fp32 VMEM scratch
over the inner-d grid dim (sequential), output written on the last d-step.
Expert weights stream tile-by-tile — each expert's weights are read once
per step regardless of how many tokens routed to it, which is the memory
behaviour that makes the capacity layout the right one for decode too
(see DESIGN.md §Roofline discussion of MoE).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, nd):
    kd = pl.program_id(3)

    @pl.when(kd == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32)
    )

    @pl.when(kd == nd - 1)
    def _emit():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_c", "block_f", "block_d", "interpret")
)
def grouped_matmul(x, w, *, block_c=128, block_f=128, block_d=512,
                   interpret=False):
    """x: (E, C, d); w: (E, d, f) -> (E, C, f)."""
    E, C, d = x.shape
    f = w.shape[-1]
    bc, bf, bd = min(block_c, C), min(block_f, f), min(block_d, d)
    nc, nf, nd = pl.cdiv(C, bc), pl.cdiv(f, bf), pl.cdiv(d, bd)

    kernel = functools.partial(_gmm_kernel, nd=nd)
    return pl.pallas_call(
        kernel,
        grid=(E, nc, nf, nd),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, ic, jf, kd: (e, ic, kd)),
            pl.BlockSpec((1, bd, bf), lambda e, ic, jf, kd: (e, kd, jf)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, ic, jf, kd: (e, ic, jf)),
        out_shape=jax.ShapeDtypeStruct((E, C, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
        name="ham_grouped_matmul",
    )(x, w)
