"""Version-compatibility shims for Pallas-TPU APIs.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (and grew
``jax.sharding.AxisType``) across 0.4 -> 0.5; the kernels support both so the
suite runs on whichever jax the image bakes in.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)
