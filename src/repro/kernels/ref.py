"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each function is the mathematical specification its kernel must match
(asserted with ``assert_allclose`` over shape/dtype sweeps in
``tests/test_kernels.py``).  No tiling, no VMEM reasoning — just the math.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -0.7 * float(np.finfo(np.float32).max)


def attention_ref(q, k, v, *, causal=True, q_per_kv=1):
    """Oracle for flash_attention.  q: (BH,S,d), k/v: (BKV,Skv,d)."""
    BH, S, d = q.shape
    BKV = k.shape[0]
    kk = jnp.repeat(k, q_per_kv, axis=0)
    vv = jnp.repeat(v, q_per_kv, axis=0)
    s = jnp.einsum("htd,hsd->hts", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / np.sqrt(d)
    if causal:
        mask = jnp.tril(jnp.ones((S, k.shape[1]), bool))
        s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hts,hsd->htd", p, vv.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k, v, lengths, *, q_per_kv=1):
    """Oracle for decode_attention.  q: (B, H, d) one token per sequence;
    k/v: (B, Hkv, S, d); lengths: (B,) valid cache length per sequence."""
    B, H, d = q.shape
    S = k.shape[2]
    kk = jnp.repeat(k, q_per_kv, axis=1)   # (B, H, S, d)
    vv = jnp.repeat(v, q_per_kv, axis=1)
    s = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / np.sqrt(d)
    valid = jnp.arange(S)[None, None, :] < lengths[:, None, None]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", p, vv.astype(jnp.float32)).astype(q.dtype)


def mlstm_chunk_ref(q, k, v, i_pre, f_pre, state=None, *, chunk):
    """Oracle for the mlstm kernel: the models.xlstm chunked formulation
    (itself validated against the exact recurrence)."""
    from repro.models.xlstm import mlstm_chunked

    return mlstm_chunked(q, k, v, i_pre, f_pre, state, chunk=chunk)


def mlstm_recurrent_ref(q, k, v, i_pre, f_pre, state=None):
    from repro.models.xlstm import mlstm_recurrent

    return mlstm_recurrent(q, k, v, i_pre, f_pre, state)


def ssd_chunk_ref(x, dt, A, Bm, Cm, D, state=None, *, chunk):
    from repro.models.mamba2 import ssd_chunked

    return ssd_chunked(x, dt, A, Bm, Cm, D, state, chunk=chunk)


def ssd_recurrent_ref(x, dt, A, Bm, Cm, D, state=None):
    from repro.models.mamba2 import ssd_recurrent

    return ssd_recurrent(x, dt, A, Bm, Cm, D, state)


def grouped_matmul_ref(x, w):
    """Oracle for grouped_matmul: per-expert batched GEMM.
    x: (E, C, d), w: (E, d, f) -> (E, C, f), fp32 accumulation."""
    return jnp.einsum(
        "ecd,edf->ecf", x.astype(jnp.float32), w.astype(jnp.float32)
    ).astype(x.dtype)
