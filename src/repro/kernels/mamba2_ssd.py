"""Mamba2 SSD chunked-scan Pallas TPU kernel.

Same TPU shape as the mLSTM kernel: grid = (batch·head, chunks) with the
chunk axis sequential and the SSM state h ∈ R^{N×P} carried in VMEM
scratch.  The within-chunk cumulative log-decay is a lower-triangular
matmul; the quadratic intra-chunk branch is two MXU matmuls
((C·Bᵀ)-tile and the (L,L)×(L,P) apply); the inter-chunk branch is a
(L,N)×(N,P) matmul against the carried state.

Inputs (pre-chunked, B/C pre-expanded to heads):
    x (BH, nc, L, P); dt, loglam (BH, nc, L); Bm, Cm (BH, nc, L, N);
    h0 (BH, N, P).
Outputs: y (BH, nc, L, P) and the final state h (BH, N, P).
The D·x skip connection is applied by the ops wrapper (elementwise).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat


def _ssd_kernel(x_ref, dt_ref, ll_ref, b_ref, c_ref, h0_ref, y_ref, hN_ref,
                h_ref, *, L, nc):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)       # (L, P)
    dt = dt_ref[0, 0].astype(jnp.float32)     # (L,)
    ll = ll_ref[0, 0].astype(jnp.float32)     # (L,) log lambda (negative)
    Bm = b_ref[0, 0].astype(jnp.float32)      # (L, N)
    Cm = c_ref[0, 0].astype(jnp.float32)      # (L, N)

    tril = jnp.tril(jnp.ones((L, L), jnp.float32))
    Lc = jnp.dot(tril, ll[:, None])[:, 0]     # inclusive cumsum (L,)

    # intra-chunk: S(t,s) = (C_t·B_s) exp(Lc_t - Lc_s) dt_s, s <= t
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # (L, L)
    decay = jnp.exp(Lc[:, None] - Lc[None, :])
    s_mat = jnp.where(tril > 0, cb * decay * dt[None, :], 0.0)
    y = jnp.dot(s_mat, x)

    # inter-chunk: exp(Lc_t) C_t · h_prev
    y = y + jnp.exp(Lc)[:, None] * jnp.dot(Cm, h_ref[...])
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update: h = exp(LL) h + Σ_s exp(LL - Lc_s) dt_s B_s ⊗ x_s
    LL = Lc[L - 1]
    w = jnp.exp(LL - Lc) * dt                 # (L,)
    h_ref[...] = jnp.exp(LL) * h_ref[...] + jax.lax.dot_general(
        Bm * w[:, None], x, (((0,), (0,)), ((), ()))
    )

    @pl.when(ic == nc - 1)
    def _emit():
        hN_ref[0] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked_kernel(x, dt, loglam, Bm, Cm, h0=None, *, chunk=256,
                       interpret=False):
    """x: (BH, S, P); dt/loglam: (BH, S); Bm/Cm: (BH, S, N);
    h0: (BH, N, P).  Returns (y (BH, S, P), h (BH, N, P))."""
    BH, S, P = x.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    assert S % L == 0
    nc = S // L
    if h0 is None:
        h0 = jnp.zeros((BH, N, P), jnp.float32)

    rc = lambda a, last: a.reshape(BH, nc, L, last)
    kernel = functools.partial(_ssd_kernel, L=L, nc=nc)
    y, hN = pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, 1, L, P), lambda bh, ic: (bh, ic, 0, 0)),
            pl.BlockSpec((1, 1, L), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, 1, L), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, 1, L, N), lambda bh, ic: (bh, ic, 0, 0)),
            pl.BlockSpec((1, 1, L, N), lambda bh, ic: (bh, ic, 0, 0)),
            pl.BlockSpec((1, N, P), lambda bh, ic: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, P), lambda bh, ic: (bh, ic, 0, 0)),
            pl.BlockSpec((1, N, P), lambda bh, ic: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, nc, L, P), x.dtype),
            jax.ShapeDtypeStruct((BH, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="ham_mamba2_ssd",
    )(rc(x, P), dt.reshape(BH, nc, L), loglam.reshape(BH, nc, L),
      rc(Bm, N), rc(Cm, N), h0)
    return y.reshape(BH, S, P), hN
