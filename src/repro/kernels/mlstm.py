"""Chunkwise-parallel mLSTM Pallas TPU kernel (TFLA-style).

One grid row = one (batch, head); the chunk axis is the innermost grid dim
with *arbitrary* (sequential) semantics, carrying the matrix memory
(C ∈ R^{dk×dv}), normaliser (n ∈ R^{dk}) and max-stabiliser (m) in VMEM
scratch across chunks — the TPU-shaped replacement for the GPU kernel's
inter-block state passing through HBM.

Everything inside a chunk is matmuls and elementwise VPU work:
* the within-chunk cumulative log-forget F = tril·f̃ is computed as a
  lower-triangular MATMUL (MXU) instead of a sequential cumsum;
* the running max g_t = max(m_prev, cummax a) is a masked row-max over the
  (L, L) tile — no scan primitives, Mosaic-friendly;
* the (t,s) decay weights multiply the (q·kᵀ) score tile elementwise.

Inputs (pre-chunked): q, k (BH, nc, L, dk); v (BH, nc, L, dv);
i_pre, f_pre (BH, nc, L); initial state C0 (BH, dk, dv), n0 (BH, dk),
m0 (BH, 1).  Outputs: h (BH, nc, L, dv) and the final (C, n, m).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat


def _mlstm_kernel(q_ref, k_ref, v_ref, i_ref, f_ref, c0_ref, n0_ref, m0_ref,
                  h_ref, cN_ref, nN_ref, mN_ref, C_ref, n_ref, m_ref, *,
                  L, scale, nc):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        C_ref[...] = c0_ref[0].astype(jnp.float32)
        n_ref[...] = n0_ref[0:1].astype(jnp.float32)   # (1, dk)
        m_ref[...] = m0_ref[0:1].astype(jnp.float32)   # (1, 1)

    q = q_ref[0, 0].astype(jnp.float32) * scale         # (L, dk)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)                 # (L, dv)
    i_pre = i_ref[0, 0].astype(jnp.float32)             # (L,)
    f_log = jax.nn.log_sigmoid(f_ref[0, 0].astype(jnp.float32))

    tril = jnp.tril(jnp.ones((L, L), jnp.float32))      # includes diagonal
    F = jnp.dot(tril, f_log[:, None])[:, 0]             # inclusive cumsum (L,)
    a = i_pre - F                                       # (L,)

    m_prev = m_ref[0, 0]
    # running max: g_t = max(m_prev, max_{s<=t} a_s) via masked row-max
    big_neg = jnp.float32(-1e30)
    a_mat = jnp.where(tril > 0, a[None, :], big_neg)    # (t, s)
    g = jnp.maximum(m_prev, jnp.max(a_mat, axis=1))     # (L,)

    # intra-chunk decay-weighted scores
    w_ts = jnp.exp(jnp.where(tril > 0, a[None, :] - g[:, None], big_neg))
    s_mat = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * w_ts

    # inter-chunk contribution
    scale_t = jnp.exp(m_prev - g)                       # (L,)
    num = jnp.dot(s_mat, v) + scale_t[:, None] * jnp.dot(q, C_ref[...])
    den = jnp.sum(s_mat, axis=1) + scale_t * jnp.dot(q, n_ref[0])
    m_t = F + g
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[:, None]
    h_ref[0, 0] = h.astype(h_ref.dtype)

    # state update
    gL = g[L - 1]
    FL = F[L - 1]
    decay_src = jnp.exp(a - gL)                         # (L,)
    C_ref[...] = jnp.exp(m_prev - gL) * C_ref[...] + jax.lax.dot_general(
        k * decay_src[:, None], v, (((0,), (0,)), ((), ()))
    )
    n_ref[...] = jnp.exp(m_prev - gL) * n_ref[...] + jnp.dot(
        decay_src[None, :], k
    )
    m_ref[...] = jnp.full_like(m_ref, FL + gL)

    @pl.when(ic == nc - 1)
    def _emit_state():
        cN_ref[0] = C_ref[...]
        nN_ref[0] = n_ref[0]
        mN_ref[0] = m_ref[0]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunked_kernel(q, k, v, i_pre, f_pre, state=None, *, chunk=256,
                         interpret=False):
    """q,k: (BH, S, dk); v: (BH, S, dv); gates: (BH, S).
    Returns (h (BH, S, dv), (C, n, m))."""
    BH, S, dk = q.shape
    dv = v.shape[-1]
    L = min(chunk, S)
    assert S % L == 0
    nc = S // L

    rc = lambda a, last: a.reshape(BH, nc, L, last)
    qs, ks_, vs = rc(q, dk), rc(k, dk), rc(v, dv)
    is_, fs = i_pre.reshape(BH, nc, L), f_pre.reshape(BH, nc, L)
    if state is None:
        C0 = jnp.zeros((BH, dk, dv), jnp.float32)
        n0 = jnp.zeros((BH, dk), jnp.float32)
        m0 = jnp.full((BH, 1), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state
        m0 = m0.reshape(BH, 1)

    kernel = functools.partial(_mlstm_kernel, L=L, scale=1.0 / np.sqrt(dk),
                               nc=nc)
    h, cN, nN, mN = pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, 1, L, dk), lambda bh, ic: (bh, ic, 0, 0)),
            pl.BlockSpec((1, 1, L, dk), lambda bh, ic: (bh, ic, 0, 0)),
            pl.BlockSpec((1, 1, L, dv), lambda bh, ic: (bh, ic, 0, 0)),
            pl.BlockSpec((1, 1, L), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, 1, L), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, dk, dv), lambda bh, ic: (bh, 0, 0)),
            pl.BlockSpec((1, dk), lambda bh, ic: (bh, 0)),
            pl.BlockSpec((1, 1), lambda bh, ic: (bh, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, dv), lambda bh, ic: (bh, ic, 0, 0)),
            pl.BlockSpec((1, dk, dv), lambda bh, ic: (bh, 0, 0)),
            pl.BlockSpec((1, dk), lambda bh, ic: (bh, 0)),
            pl.BlockSpec((1, 1), lambda bh, ic: (bh, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, nc, L, dv), v.dtype),
            jax.ShapeDtypeStruct((BH, dk, dv), jnp.float32),
            jax.ShapeDtypeStruct((BH, dk), jnp.float32),
            jax.ShapeDtypeStruct((BH, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((dk, dv), jnp.float32),
            pltpu.VMEM((1, dk), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="ham_mlstm_chunked",
    )(qs, ks_, vs, is_, fs, C0, n0, m0)
    return h.reshape(BH, S, dv), (cN, nN, mN.reshape(BH))
