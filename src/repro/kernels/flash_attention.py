"""Flash attention (tiled online-softmax) Pallas TPU kernel, GQA-aware.

TPU adaptation notes (DESIGN.md §2: adapt, don't port):
* Tiling is chosen for VMEM + MXU: q/k tiles are multiples of 128 on the
  matmul dims; the (bq, bk) score tile stays in VMEM/VREGs.
* The kv-block axis is the innermost grid dim with *arbitrary* semantics —
  TPU grids execute it sequentially per core, so the online-softmax running
  state (m, l, acc) lives in VMEM scratch across grid steps (no atomics, no
  shared-memory reductions — the GPU mechanics that do NOT transfer).
* GQA: the kv head index is derived in the index_map (h // q_per_kv), so
  repeated KV heads are never materialised.
* Causal masking skips whole tiles above the diagonal via ``pl.when``.

Layouts: q (BH, S, d), k/v (BKV, S, d) with BH = B*H, BKV = B*Hkv.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat

NEG_INF = -0.7 * float(np.finfo(np.float32).max)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq, bk, causal, scale, nk):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    run = True
    if causal:
        # tile fully above the diagonal -> skip
        run = (ik * bk) <= (iq * bq + bq - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        if causal:
            qi = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kj = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kj <= qi, s, NEG_INF)
        m_prev = m_ref[...]                                # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                             # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                    # (bq, 1)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)                   # (bk, d)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot(p, v)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "q_per_kv", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q, k, v, *, causal=True, q_per_kv=1, block_q=256, block_k=512,
    interpret=False,
):
    """q: (BH, S, d); k, v: (BKV, S, d) with BH = BKV * q_per_kv
    (head-major: q head g*q_per_kv+j reads kv head g).  Returns (BH, S, d).
    """
    BH, S, d = q.shape
    bq = min(block_q, S)
    bk = min(block_k, k.shape[1])
    nq = pl.cdiv(S, bq)
    nk = pl.cdiv(k.shape[1], bk)
    scale = 1.0 / np.sqrt(d)

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, causal=causal, scale=scale, nk=nk
    )
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, iq, ik: (bh // q_per_kv, ik, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, iq, ik: (bh // q_per_kv, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="ham_flash_attention",
    )(q, k, v)
