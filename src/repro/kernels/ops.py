"""jit'd public wrappers over the Pallas kernels.

Backend policy: on TPU the compiled kernels run natively; elsewhere (this
CPU container, unit tests) they run in ``interpret=True`` mode so the exact
kernel bodies are validated against the ``ref.py`` oracles.  The model code
selects kernels via ``ModelConfig.attn_impl`` — the XLA reference path stays
the default for the dry-run (kernels are opaque custom-calls to
``cost_analysis``, which would blind the roofline).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.grouped_matmul import grouped_matmul as _gmm
from repro.kernels.mamba2_ssd import ssd_chunked_kernel as _ssd
from repro.kernels.mlstm import mlstm_chunked_kernel as _mlstm


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention_bhsd(q, k, v, *, causal=True, interpret=None):
    """Model-layout wrapper: q (B, S, H, hd); k/v (B, S, Hkv, hd)."""
    interpret = _interpret_default() if interpret is None else interpret
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    qpk = H // Hkv
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, hd)
    out = _flash(qf, kf, vf, causal=causal, q_per_kv=qpk, interpret=interpret)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


def decode_attention_bhsd(q, k, v, lengths, *, interpret=None):
    """q (B, 1, H, hd); k/v caches (B, S, Hkv, hd); lengths (B,)."""
    interpret = _interpret_default() if interpret is None else interpret
    B, _, H, hd = q.shape
    Hkv = k.shape[2]
    qpk = H // Hkv
    q4 = q[:, 0].reshape(B, Hkv, qpk, hd)
    kf = k.transpose(0, 2, 1, 3)
    vf = v.transpose(0, 2, 1, 3)
    out = _decode(q4, kf, vf, lengths, interpret=interpret)
    return out.reshape(B, 1, H, hd)


def mlstm_chunked(q, k, v, i_pre, f_pre, state=None, *, chunk=256,
                  interpret=None):
    """Model layout: q,k (B, S, H, dk); v (B, S, H, dv); gates (B, S, H)."""
    interpret = _interpret_default() if interpret is None else interpret
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    fl = lambda a, last: a.transpose(0, 2, 1, 3).reshape(B * H, S, last)
    g = lambda a: a.transpose(0, 2, 1).reshape(B * H, S)
    st = None
    if state is not None:
        C, n, m = state
        st = (C.reshape(B * H, *C.shape[2:]), n.reshape(B * H, -1),
              m.reshape(B * H))
    h, (C, n, m) = _mlstm(fl(q, dk), fl(k, dk), fl(v, dv), g(i_pre), g(f_pre),
                          st, chunk=chunk, interpret=interpret)
    h = h.reshape(B, H, S, dv).transpose(0, 2, 1, 3)
    return h, (C.reshape(B, H, dk, dv), n.reshape(B, H, dk), m.reshape(B, H))


def ssd_chunked(x, dt, A, Bm, Cm, D, state=None, *, chunk=256,
                interpret=None):
    """Model layout: x (B,S,H,P); dt (B,S,H); A (H,); Bm/Cm (B,S,G,N)."""
    interpret = _interpret_default() if interpret is None else interpret
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = H // G
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, S)
    loglam = (A[None, None, :] * dt).transpose(0, 2, 1).reshape(B * H, S)
    Bh = jnp.repeat(Bm, hpg, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, N)
    Ch = jnp.repeat(Cm, hpg, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, N)
    h0 = None if state is None else state.reshape(B * H, N, P)
    y, hN = _ssd(xf, dtf, loglam, Bh, Ch, h0, chunk=chunk, interpret=interpret)
    y = y.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    y = y + x.astype(jnp.float32) * D.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), hN.reshape(B, H, N, P)


def grouped_matmul(x, w, *, interpret=None, **blocks):
    interpret = _interpret_default() if interpret is None else interpret
    return _gmm(x, w, interpret=interpret, **blocks)


# re-export the oracles so kernels/<name> + ops + ref travel together
attention_ref = ref.attention_ref
decode_attention_ref = ref.decode_attention_ref
grouped_matmul_ref = ref.grouped_matmul_ref
