"""Single-token GQA decode attention over a KV cache (Pallas TPU kernel).

Decode attention is **memory-bound**: the entire KV cache streams HBM→VMEM
once per step while compute is a sliver of the MXU.  The kernel therefore:

* processes one (batch, kv-head) pair per grid row with ALL its q_per_kv
  query heads at once (the GQA trick: one KV read amortised over the whole
  query group — q_per_kv × fewer cache bytes than head-by-head);
* streams the cache in (block_k, d) tiles along an *arbitrary* innermost
  grid dim with the online-softmax running state in VMEM scratch;
* masks invalid slots per-sequence from a ``lengths`` vector (continuous
  batching: slots decode at different positions).

Layouts: q (B, Hkv, q_per_kv, d); k/v (B, Hkv, S, d); lengths (B,).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat

NEG_INF = -0.7 * float(np.finfo(np.float32).max)


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                   *, bk, scale, nk):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[0]

    @pl.when(ik * bk < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (qpk, d)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (qpk, bk)
        kj = ik * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kj < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot(p, v)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k, v, lengths, *, block_k=512, interpret=False):
    """q: (B, Hkv, qpk, d); k/v: (B, Hkv, S, d); lengths: (B,) int32.
    Returns (B, Hkv, qpk, d)."""
    B, Hkv, qpk, d = q.shape
    S = k.shape[2]
    bk = min(block_k, S)
    nk = pl.cdiv(S, bk)
    scale = 1.0 / np.sqrt(d)

    kernel = functools.partial(_decode_kernel, bk=bk, scale=scale, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ik: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, qpk, d), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qpk, d), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qpk, d), jnp.float32),
            pltpu.VMEM((qpk, 1), jnp.float32),
            pltpu.VMEM((qpk, 1), jnp.float32),
        ],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="ham_decode_attention",
    )(lengths.astype(jnp.int32), q, k, v)
