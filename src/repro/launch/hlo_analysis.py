"""Static analyzer for post-optimization (SPMD-partitioned) HLO text.

Why this exists: ``compiled.cost_analysis()`` visits each ``while`` body
**once** — scanned-layer models (all of ours) would be undercounted by a
factor of num_layers.  This module parses ``compiled.as_text()`` into
computations, recovers loop **trip counts** from the ``while`` condition's
compare-against-constant, and attributes costs recursively::

    cost(comp) = Σ instruction costs
               + Σ cost(called comp) × trip_count      (while)
               + Σ cost(called comp) × 1               (fusion/call)
               + max over branches                      (conditional)

Per-device accounting (shapes in partitioned HLO are shard shapes):

* ``flops``        — 2·M·N·K for dots (contraction sizes read from operand
  shapes), kernel_elems·2·out for convolutions, 1/elem for elementwise
  arithmetic.  MXU + VPU work.
* ``hbm_bytes``    — Σ over *top-level* (post-fusion) instructions of
  operand+output buffer bytes: fusion boundaries are exactly where XLA
  reads/writes HBM, so this is the canonical traffic estimate.
* ``collective_bytes`` — Σ over all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute of the max participating buffer size,
  with an op factor (all-reduce ≈ 2× in ring implementations).
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "logistic", "sign", "floor", "ceil", "cosine", "sine", "select",
    "compare", "and", "or", "xor", "not", "clamp", "remainder",
    "exponential-minus-one", "log-plus-one", "atan2", "cbrt",
    "round-nearest-afz", "round-nearest-even",
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        nb = _DTYPE_BYTES.get(dtype)
        if nb is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nb
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instruction:
    name: str
    type_str: str
    opcode: str
    operands: list[str]
    raw: str


@dataclasses.dataclass
class Computation:
    name: str
    instructions: dict  # name -> Instruction


_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\]\{\},:#\* ]+?))\s*"
    r"([\w\-]+)\((.*)$"
)
_OPERAND = re.compile(r"%([\w\.\-]+)")
_CALL_ATTR = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w\.\-]+)")
_BRANCH_ATTR = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")


def parse_hlo(text: str) -> dict:
    """-> {comp_name: Computation}"""
    comps: dict[str, Computation] = {}
    current: Computation | None = None
    for line in text.splitlines():
        if current is None:
            m = _COMP_HEADER.match(line.strip())
            if m and "{" in line:
                current = Computation(m.group(1), {})
            continue
        if line.strip() == "}":
            comps[current.name] = current
            current = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        # operands: the %refs before any attribute section in `rest`
        paren_depth = 1
        args_end = len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                paren_depth += 1
            elif ch == ")":
                paren_depth -= 1
                if paren_depth == 0:
                    args_end = i
                    break
        operand_str = rest[:args_end]
        operands = _OPERAND.findall(operand_str)
        current.instructions[name] = Instruction(
            name, type_str.strip(), opcode, operands, line.strip()
        )
    return comps


def _operand_type(comp: Computation, op_name: str) -> str:
    ins = comp.instructions.get(op_name)
    return ins.type_str if ins else ""


def _dot_flops(comp: Computation, ins: Instruction) -> float:
    out_elems = 1
    for d in _shape_dims(ins.type_str):
        out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.raw)
    contracting = 1
    if m and ins.operands:
        lhs_dims = _shape_dims(_operand_type(comp, ins.operands[0]))
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contracting *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contracting


def _conv_flops(comp: Computation, ins: Instruction) -> float:
    out_elems = 1
    for d in _shape_dims(ins.type_str):
        out_elems *= d
    if len(ins.operands) > 1:
        k_dims = _shape_dims(_operand_type(comp, ins.operands[1]))
        k_elems = 1
        for d in k_dims:
            k_elems *= d
        # per output element: kernel_elems MACs / output-feature count
        out_feat = k_dims[-1] if k_dims else 1
        return 2.0 * out_elems * max(k_elems // max(out_feat, 1), 1)
    return 2.0 * out_elems


def trip_count(comps: dict, cond_name: str) -> int:
    """Loop limit from the condition computation.

    Scan lowers to ``i < limit`` with a constant limit; post-fusion the
    compare often lives inside a wrapped fusion whose *operand* is the
    constant.  Heuristic: the largest integer constant reachable from the
    condition computation (scan counters start at 0, so the limit is the
    max).  Falls back to 1 (cost_analysis-equivalent) when nothing parses.
    """
    seen: set = set()

    def max_const(name: str) -> int:
        if name in seen:
            return 0
        seen.add(name)
        comp = comps.get(name)
        if comp is None:
            return 0
        best = 0
        for ins in comp.instructions.values():
            if ins.opcode == "constant" and ins.type_str.strip().startswith(
                ("s32[]", "u32[]", "s64[]", "u64[]")
            ):
                m = _CONST_INT.search(ins.raw)
                if m:
                    best = max(best, int(m.group(1)))
            m = _CALL_ATTR.search(ins.raw)
            if m and ins.opcode in ("fusion", "call"):
                best = max(best, max_const(m.group(1)))
        return best

    return max(max_const(cond_name), 1)


def _logical_operand_bytes(comps: dict, comp: Computation, op_name: str,
                           depth: int = 0) -> int:
    """Logical (pre-dtype-emulation) bytes of a buffer: unwrap converts and
    convert-rooted fusions back to the narrowest source buffer."""
    if depth > 6:
        return 0
    src = comp.instructions.get(op_name)
    if src is None:
        return 0
    if src.opcode == "convert" and src.operands:
        inner = _shape_bytes(_operand_type(comp, src.operands[0]))
        deeper = _logical_operand_bytes(comps, comp, src.operands[0], depth + 1)
        return min(x for x in (inner, deeper) if x) if (inner or deeper) else 0
    if src.opcode == "fusion":
        m = _CALL_ATTR.search(src.raw)
        called = comps.get(m.group(1)) if m else None
        if called is not None:
            root = None
            for fi in called.instructions.values():
                if "ROOT" in fi.raw:
                    root = fi
            seen = 0
            while (root is not None and root.opcode in ("convert", "bitcast",
                                                         "copy")
                   and root.operands and seen < 8):
                root = called.instructions.get(root.operands[0])
                seen += 1
            if root is not None and seen:
                return _shape_bytes(root.type_str)
    return 0


def _fusion_boundary_bytes(comps: dict, comp: Computation,
                           ins: Instruction) -> float:
    """Traffic at a fusion boundary = output + operands, with two in-place
    patterns discounted (XLA/TPU alias these buffers):

    * an operand the fused computation only *slices* costs the slice;
    * a fusion whose ROOT is a dynamic-update-slice of a parameter is an
      in-place update: the output costs the update region, and the aliased
      parameter operand costs nothing extra (donated KV caches)."""
    called = None
    m = _CALL_ATTR.search(ins.raw)
    if m:
        called = comps.get(m.group(1))
    param_names: dict[int, str] = {}
    consumers: dict[str, list] = {}
    root: Instruction | None = None
    if called is not None:
        for fi in called.instructions.values():
            if fi.opcode == "parameter":
                pm = re.search(r"parameter\((\d+)\)", fi.raw)
                if pm:
                    param_names[int(pm.group(1))] = fi.name
            for o in fi.operands:
                consumers.setdefault(o, []).append(fi)
            if "ROOT" in fi.raw:
                root = fi

    # `convert`/`bitcast` are layout/emulation artifacts (the CPU backend
    # wraps every bf16 buffer in converts); trace through them.
    TRANSPARENT = ("convert", "bitcast", "copy")

    def unwrap(iref: Instruction | None) -> Instruction | None:
        seen = 0
        while (iref is not None and iref.opcode in TRANSPARENT
               and iref.operands and seen < 16):
            iref = called.instructions.get(iref.operands[0])
            seen += 1
        return iref

    def real_consumers(name: str) -> list:
        out, frontier, seen = [], [name], 0
        while frontier and seen < 64:
            nxt = []
            for n in frontier:
                for c in consumers.get(n, []):
                    seen += 1
                    if c.opcode in TRANSPARENT:
                        nxt.append(c.name)
                    else:
                        out.append((n, c))
            frontier = nxt
        return out

    # pure-movement fusions: root unwraps through convert/bitcast/copy/
    # transpose/reshape straight to a parameter.  A same-logical-shape chain
    # is a CPU bf16-emulation round-trip (absent on TPU) -> 0 bytes; a
    # permuted chain is a layout change -> count the buffer once at the
    # smaller element size.
    MOVEMENT = TRANSPARENT + ("transpose", "reshape")

    def unwrap_move(iref: Instruction | None) -> Instruction | None:
        seen = 0
        while (iref is not None and iref.opcode in MOVEMENT
               and iref.operands and seen < 16):
            iref = called.instructions.get(iref.operands[0])
            seen += 1
        return iref

    if called is not None and root is not None:
        # track the smallest buffer along the movement chain (bf16 twin of a
        # CPU-emulation f32 copy)
        chain_min = [_shape_bytes(root.type_str)] if root.type_str else []

        def unwrap_move_track(iref):
            seen = 0
            while (iref is not None and iref.opcode in MOVEMENT
                   and iref.operands and seen < 16):
                iref = called.instructions.get(iref.operands[0])
                if iref is not None:
                    chain_min.append(_shape_bytes(iref.type_str))
                seen += 1
            return iref

        mroot = unwrap_move_track(root)
        if mroot is not None and mroot.opcode == "parameter":
            in_dims = _shape_dims(mroot.type_str)
            same_shape = sorted(_shape_dims(ins.type_str)) == sorted(in_dims)
            if same_shape:
                # dtype-emulation round-trip or pure relayout of the same
                # buffer: free on TPU when fused into the consumer
                return 0.0
            return float(min(chain_min)) if chain_min else 0.0

    # output cost (in-place DUS root -> update bytes only)
    aliased_params: set[str] = set()
    rroot = unwrap(root) if called else None
    if rroot is not None and rroot.opcode == "dynamic-update-slice":
        upd = (_shape_bytes(_operand_type(called, rroot.operands[1]))
               if len(rroot.operands) > 1 else 0)
        total = 2.0 * upd
        # walk the updated-buffer chain back to a parameter (alias)
        cur = called.instructions.get(rroot.operands[0]) if rroot.operands else None
        cur = unwrap(cur)
        if cur is not None and cur.opcode == "parameter":
            aliased_params.add(cur.name)
    else:
        total = float(_shape_bytes(ins.type_str))
    for idx, o in enumerate(ins.operands):
        full = _shape_bytes(_operand_type(comp, o))
        if called is not None and idx in param_names:
            pname = param_names[idx]
            if pname in aliased_params:
                continue
            cons = real_consumers(pname)
            if cons and all(c.opcode == "dynamic-slice" for _, c in cons):
                total += sum(_shape_bytes(c.type_str) for _, c in cons)
                continue
            if cons and all(
                c.opcode == "dynamic-update-slice" and c.operands
                and unwrap(called.instructions.get(c.operands[0])) is not None
                and unwrap(called.instructions.get(c.operands[0])).name == pname
                for _, c in cons
            ):
                total += sum(
                    _shape_bytes(_operand_type(called, c.operands[1]))
                    for _, c in cons if len(c.operands) > 1
                )
                continue
        total += full
    return total


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_op: dict = dataclasses.field(default_factory=dict)
    loops: list = dataclasses.field(default_factory=list)
    sites: list = dataclasses.field(default_factory=list)  # (bytes, flops, desc)

    def add(self, other: "HloCost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.collective_by_op.items():
            self.collective_by_op[k] = self.collective_by_op.get(k, 0.0) + v * mult
        for b, f, d in other.sites:
            self.sites.append((b * mult, f * mult, d))

    def top_sites(self, n=15, key="bytes"):
        idx = 0 if key == "bytes" else 1
        merged: dict[str, list] = {}
        for b, f, d in self.sites:
            e = merged.setdefault(d, [0.0, 0.0])
            e[0] += b
            e[1] += f
        rows = [(v[0], v[1], d) for d, v in merged.items()]
        rows.sort(key=lambda r: -r[idx])
        return rows[:n]


def _comp_cost(comps: dict, name: str, memo: dict, *, top: bool) -> HloCost:
    key = (name, top)
    if key in memo:
        return memo[key]
    comp = comps.get(name)
    cost = HloCost()
    if comp is None:
        memo[key] = cost
        return cost
    for ins in comp.instructions.values():
        op = ins.opcode
        ins_flops = 0.0
        ins_bytes = 0.0
        if op == "dot":
            ins_flops = _dot_flops(comp, ins)
            cost.flops += ins_flops
        elif op == "convolution":
            ins_flops = _conv_flops(comp, ins)
            cost.flops += ins_flops
        elif op in _ELEMENTWISE:
            n = 1
            for d in _shape_dims(ins.type_str):
                n *= d
            ins_flops = n
            cost.flops += n
        if op in _COLLECTIVES:
            sizes = [_shape_bytes(ins.type_str)]
            for o in ins.operands:
                sizes.append(_shape_bytes(_operand_type(comp, o)))
                # CPU backend emulates bf16 collectives in f32: if the
                # operand traces back (through converts / convert-rooted
                # fusions) to a bf16 buffer, TPU wire bytes are bf16
                logical = _logical_operand_bytes(comps, comp, o)
                if logical and logical < sizes[-1]:
                    sizes[-1] = logical
                    sizes[0] = min(sizes[0], max(logical, 1))
            b = max(sizes) * _COLLECTIVES[op]
            cost.collective_bytes += b
            cost.collective_by_op[op] = cost.collective_by_op.get(op, 0.0) + b
            cost.sites.append(
                (b, 0.0, f"COLL::{comp.name}::{op}::{ins.type_str.strip()[:60]}")
            )
        # HBM traffic at top-level (post-fusion) instruction boundaries.
        # Structural ops move no data; slicing ops touch the slice, not the
        # operand; `while`/`call` operands are counted inside their bodies.
        if top and op not in (
            "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
            "while", "conditional", "call", "after-all", "partition-id",
            "replica-id",
        ):
            if op == "copy" and ins.operands and (
                (src := comp.instructions.get(ins.operands[0])) is not None
                and src.opcode == "parameter"
            ):
                ins_bytes = 0  # donated-input copy: aliased on TPU
            elif op == "dynamic-slice":
                ins_bytes = 2 * _shape_bytes(ins.type_str)
            elif op == "dynamic-update-slice":
                upd = (_shape_bytes(_operand_type(comp, ins.operands[1]))
                       if len(ins.operands) > 1 else 0)
                ins_bytes = 2 * upd
            elif op == "fusion":
                ins_bytes = _fusion_boundary_bytes(comps, comp, ins)
            else:
                b = _shape_bytes(ins.type_str)
                for o in ins.operands:
                    b += _shape_bytes(_operand_type(comp, o))
                ins_bytes = b
            cost.hbm_bytes += ins_bytes
        if ins_bytes or ins_flops:
            cost.sites.append(
                (ins_bytes, ins_flops,
                 f"{comp.name}::{op}::{ins.type_str.strip()[:60]}")
            )
        # recurse into called computations
        if op == "while":
            body = cond = None
            m = re.search(r"body=%?([\w\.\-]+)", ins.raw)
            if m:
                body = m.group(1)
            m = re.search(r"condition=%?([\w\.\-]+)", ins.raw)
            if m:
                cond = m.group(1)
            tc = trip_count(comps, cond) if cond else 1
            if body:
                sub = _comp_cost(comps, body, memo, top=top)
                cost.add(sub, tc)
                cost.loops.append((body, tc))
        elif op == "conditional":
            m = _BRANCH_ATTR.search(ins.raw)
            if m:
                branches = _OPERAND.findall(m.group(1)) or [
                    b.strip().lstrip("%") for b in m.group(1).split(",")
                ]
                subs = [_comp_cost(comps, b, memo, top=top) for b in branches]
                if subs:
                    best = max(subs, key=lambda c: c.flops)
                    cost.add(best)
        elif op == "fusion":
            m = _CALL_ATTR.search(ins.raw)
            if m:
                # flops live inside the fused computation; bytes were already
                # counted at this fusion's boundary
                sub = _comp_cost(comps, m.group(1), memo, top=False)
                cost.flops += sub.flops
                cost.collective_bytes += sub.collective_bytes
                for k, v in sub.collective_by_op.items():
                    cost.collective_by_op[k] = cost.collective_by_op.get(k, 0) + v
        elif op in ("call", "custom-call", "async-start"):
            m = _CALL_ATTR.search(ins.raw)
            if m:
                sub = _comp_cost(comps, m.group(1), memo, top=False)
                cost.add(sub)
    memo[key] = cost
    return cost


def analyze(hlo_text: str) -> HloCost:
    comps = parse_hlo(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HEADER.match(line.strip())
            if m:
                entry = m.group(1)
                break
    if entry is None:
        # fall back: biggest computation
        entry = max(comps, key=lambda n: len(comps[n].instructions))
    return _comp_cost(comps, entry, {}, top=True)
