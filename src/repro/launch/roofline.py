"""Roofline terms from compiled dry-run artifacts (TPU v5e constants).

    compute    = HLO_FLOPs_per_chip / 197e12
    memory     = HLO_bytes_per_chip / 819e9
    collective = collective_bytes_per_chip / 50e9   (per-link ICI)

HLO terms come from ``hlo_analysis.analyze`` (trip-count-aware — XLA's own
cost_analysis counts loop bodies once).  Shapes in partitioned HLO are
already per-shard, so no further division by chip count.  MODEL_FLOPS is
6·N_active·tokens for train, 2·N_active·tokens for inference (global), and
the usefulness ratio divides by global HLO flops (= per-chip × chips, which
deliberately *counts* model-parallel redundancy — that is the waste the
ratio is meant to expose).
"""

from __future__ import annotations

import dataclasses
import json
import os

PEAK_FLOPS = 197e12      # bf16 / chip (TPU v5e class)
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link

EXPERIMENT_DIR = os.environ.get(
    "HAM_EXPERIMENT_DIR", os.path.join(os.path.dirname(__file__), "..", "..",
                                       "..", "experiments")
)


def tree_shard_bytes(shapes, ns_tree) -> int:
    """Exact per-chip bytes of a pytree under its NamedShardings."""
    import jax
    import numpy as np

    total = 0
    flat_s = jax.tree_util.tree_leaves(shapes)
    flat_n = jax.tree_util.tree_leaves(
        ns_tree, is_leaf=lambda x: hasattr(x, "spec")
    )
    for leaf, ns in zip(flat_s, flat_n):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        nbytes = n * leaf.dtype.itemsize
        shards = 1
        mesh = ns.mesh
        for axes in ns.spec:
            if axes is None:
                continue
            for a in (axes if isinstance(axes, tuple) else (axes,)):
                shards *= mesh.shape[a]
        total += nbytes // max(shards, 1)
    return total


def analytic_memory_bytes(cfg, cell, mesh, plan, *, param_bytes, opt_bytes,
                          cache_bytes) -> float:
    """TPU-faithful per-chip HBM traffic model (primary memory term).

    The CPU-backend HLO inflates byte counts with bf16-emulation converts
    and materialised transposes that do not exist on TPU, so the memory
    term is modelled from first principles over the *actual shard sizes*:

    train:   4·P (fwd read + bwd-recompute read + grad write/read)
             + P (update write) + 2·O (moments read+write)
             + 2·(L/g)·A_boundary (saved activations w+r)
             + 3·S_scores (fwd, recompute, backward of the f32 score tile —
               the honest cost of the XLA attention path; drops to ~0 with
               the Pallas flash kernel) + 4·logits
    prefill: P + C (cache write) + 2·S_scores + 2·A_layer + 2·logits
    decode:  P + C (KV prefix read) + update (negligible) + logits
    """
    import numpy as np

    present = set(mesh.axis_names)
    batch_shard = 1
    for a in ("pod", "data"):
        if a in present and cell.global_batch % (batch_shard * mesh.shape[a]) == 0:
            batch_shard *= mesh.shape[a]
    model_size = mesh.shape.get("model", 1)
    B_loc = max(cell.global_batch // batch_shard, 1)
    L = cfg.num_layers
    d = cfg.d_model
    act_bytes = 2  # bf16 activations

    H_loc = cfg.num_heads / model_size if cfg.num_heads % model_size == 0 \
        else cfg.num_heads
    S = cell.seq_len
    seq_loc = S / model_size if plan.seq_shard else S

    # f32 score-tile traffic per forward pass (ref/XLA attention path)
    if cfg.family in ("ssm",):
        # mLSTM chunked: (L_c × L_c) tiles per chunk per head
        Lc = cfg.xlstm.chunk_size
        scores = L * B_loc * cfg.num_heads * (S / Lc) * Lc * Lc * 4
    elif cfg.family == "hybrid":
        Lc = cfg.ssm.chunk_size
        di = cfg.ssm.expand * d
        Hs = di // cfg.ssm.head_dim
        scores = L * B_loc * Hs * (S / Lc) * Lc * Lc * 4
        n_attn = L // cfg.ssm.attn_every
        win = cfg.ssm.attn_window or S
        scores += n_attn * B_loc * (cfg.num_heads / model_size if cfg.num_heads % model_size == 0 else cfg.num_heads) * seq_loc * min(win, S) * 4
    elif cfg.family == "audio":
        F = cfg.encdec.encoder_frames
        enc = cfg.encdec.encoder_layers * B_loc * H_loc * F * F * 4
        dec = L * B_loc * H_loc * seq_loc * (S + F) * 4
        scores = enc + dec
    else:
        scores = L * B_loc * H_loc * seq_loc * S * 4

    if getattr(cfg, "attn_causal_skip", False):
        scores *= 0.5  # per-chunk growing kv extent: triangular, not square
    if getattr(cfg, "attn_impl", "ref") == "flash":
        # Pallas flash kernel: score tiles never leave VMEM (validated in
        # kernels/flash_attention.py against the ref oracle)
        scores = 0.0

    vocab_loc = (cfg.vocab_size / model_size
                 if cfg.vocab_size % model_size == 0 else cfg.vocab_size)
    logits = B_loc * (S if cell.kind != "decode" else 1) * vocab_loc * 4

    moe_dispatch = 0.0
    if cfg.moe is not None and cell.kind != "decode":
        # xe/h tensors r/w: tokens×topk×cf×(d + d_ff_expert)
        tok_loc = B_loc * S
        moe_dispatch = (
            L * tok_loc * cfg.moe.top_k * cfg.moe.capacity_factor
            * (d + cfg.moe.d_ff_expert) * act_bytes * 2
        )

    if cell.kind == "train":
        g = max(getattr(cfg, "remat_group", 1), 1)
        boundary = (L / g) * B_loc * seq_loc * d * act_bytes * 2
        return (5 * param_bytes + 2 * param_bytes  # fwd+bwd+grads+update
                + 2 * opt_bytes + boundary + 3 * scores + 4 * logits
                + 3 * moe_dispatch)
    if cell.kind == "prefill":
        layer_acts = 2 * L * B_loc * seq_loc * d * act_bytes
        return param_bytes + cache_bytes + 2 * scores + layer_acts + logits + moe_dispatch
    # decode
    return param_bytes + cache_bytes + logits + moe_dispatch


@dataclasses.dataclass
class RooflineReport:
    arch: str
    cell: str
    mesh: str
    chips: int
    flops_per_chip: float
    hbm_bytes_per_chip: float        # analytic model (primary memory term)
    hbm_bytes_hlo_ub: float          # HLO-parsed upper bound (CPU backend)
    collective_bytes_per_chip: float
    model_flops: float
    collective_by_op: dict
    memory_stats: dict
    xla_cost: dict

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / global HLO flops."""
        total = self.flops_per_chip * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the bound step time spent on useful model flops:
        (MODEL_FLOPS / chips / peak) / max(term) — the score to push up."""
        ideal = self.model_flops / self.chips / PEAK_FLOPS
        worst = max(self.t_compute, self.t_memory, self.t_collective)
        return ideal / worst if worst else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(
            t_compute=self.t_compute, t_memory=self.t_memory,
            t_collective=self.t_collective, bottleneck=self.bottleneck,
            useful_ratio=self.useful_ratio,
            roofline_fraction=self.roofline_fraction,
        )
        return d

    def summary(self) -> str:
        return (
            f"{self.arch:18s} {self.cell:12s} {self.mesh:9s} "
            f"comp={self.t_compute*1e3:9.3f}ms "
            f"mem={self.t_memory*1e3:9.3f}ms "
            f"coll={self.t_collective*1e3:9.3f}ms "
            f"bound={self.bottleneck:10s} "
            f"useful={self.useful_ratio:6.1%} "
            f"roofline={self.roofline_fraction:6.1%}"
        )


def build_report(arch, cell, mesh_name, chips, hlo_cost, model_flops,
                 memory_stats, xla_cost, analytic_bytes=None) -> RooflineReport:
    return RooflineReport(
        arch=arch, cell=cell, mesh=mesh_name, chips=chips,
        flops_per_chip=hlo_cost.flops,
        hbm_bytes_per_chip=(analytic_bytes if analytic_bytes is not None
                            else hlo_cost.hbm_bytes),
        hbm_bytes_hlo_ub=hlo_cost.hbm_bytes,
        collective_bytes_per_chip=hlo_cost.collective_bytes,
        model_flops=model_flops,
        collective_by_op=dict(hlo_cost.collective_by_op),
        memory_stats=memory_stats,
        xla_cost=xla_cost,
    )


def save_report(report: RooflineReport, tag: str = "baseline") -> str:
    d = os.path.join(EXPERIMENT_DIR, "dryrun")
    os.makedirs(d, exist_ok=True)
    path = os.path.join(
        d, f"{report.arch}_{report.cell}_{report.mesh}_{tag}.json"
    )
    with open(path, "w") as f:
        json.dump(report.to_dict(), f, indent=1)
    return path
