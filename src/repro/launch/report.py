"""Render the EXPERIMENTS.md roofline tables from the dry-run JSONs."""

from __future__ import annotations

import json
import os

from repro.launch.roofline import EXPERIMENT_DIR

ARCH_ORDER = [
    "xlstm-1.3b", "internlm2-20b", "qwen1.5-4b", "llama3-405b",
    "nemotron-4-340b", "olmoe-1b-7b", "qwen2-moe-a2.7b", "internvl2-76b",
    "zamba2-2.7b", "whisper-large-v3",
]
CELL_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
SKIPS = {
    (a, "long_500k")
    for a in ARCH_ORDER if a not in ("xlstm-1.3b", "zamba2-2.7b")
}


def load_reports(tag: str = "baseline") -> dict:
    d = os.path.join(EXPERIMENT_DIR, "dryrun")
    out = {}
    for name in os.listdir(d):
        if not name.endswith(f"_{tag}.json"):
            continue
        with open(os.path.join(d, name)) as f:
            r = json.load(f)
        out[(r["arch"], r["cell"], r["mesh"])] = r
    return out


def _fmt_ms(s: float) -> str:
    return f"{s*1e3:.2f}"


def roofline_table(tag: str = "baseline", mesh: str = "pod16x16") -> str:
    reports = load_reports(tag)
    lines = [
        "| arch | cell | comp (ms) | mem (ms) | coll (ms) | bound | "
        "MODEL_FLOPS | useful | roofline |",
        "|---|---|---:|---:|---:|---|---:|---:|---:|",
    ]
    for arch in ARCH_ORDER:
        for cell in CELL_ORDER:
            if (arch, cell) in SKIPS:
                lines.append(
                    f"| {arch} | {cell} | — | — | — | SKIP (full attention "
                    f"at 524k; DESIGN.md §5) | — | — | — |"
                )
                continue
            r = reports.get((arch, cell, mesh))
            if r is None:
                lines.append(f"| {arch} | {cell} | MISSING | | | | | | |")
                continue
            lines.append(
                f"| {arch} | {cell} | {_fmt_ms(r['t_compute'])} | "
                f"{_fmt_ms(r['t_memory'])} | {_fmt_ms(r['t_collective'])} | "
                f"{r['bottleneck']} | {r['model_flops']:.2e} | "
                f"{r['useful_ratio']*100:.1f}% | "
                f"{r['roofline_fraction']*100:.1f}% |"
            )
    return "\n".join(lines)


def dryrun_table(tag: str = "baseline") -> str:
    reports = load_reports(tag)
    lines = [
        "| arch | cell | mesh | per-chip bytes (args+temp) | HLO flops/chip | "
        "collective B/chip | dominant collective |",
        "|---|---|---|---:|---:|---:|---|",
    ]
    for arch in ARCH_ORDER:
        for cell in CELL_ORDER:
            for mesh in ("pod16x16", "pod2x16x16"):
                r = reports.get((arch, cell, mesh))
                if r is None:
                    continue
                mem = r["memory_stats"]
                per_chip = (mem["argument_bytes"] + mem["temp_bytes"]) / r["chips"]
                dom = max(r["collective_by_op"].items(),
                          key=lambda kv: kv[1])[0] if r["collective_by_op"] else "-"
                lines.append(
                    f"| {arch} | {cell} | {mesh} | {per_chip/1e9:.2f} GB | "
                    f"{r['flops_per_chip']:.2e} | "
                    f"{r['collective_bytes_per_chip']:.2e} | {dom} |"
                )
    return "\n".join(lines)


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--table", default="roofline",
                    choices=["roofline", "dryrun"])
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args()
    if args.table == "roofline":
        print(roofline_table(args.tag, args.mesh))
    else:
        print(dryrun_table(args.tag))


if __name__ == "__main__":
    main()
