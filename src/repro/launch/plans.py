"""Per-(arch × cell) sharding plans and dry-run config tuning.

This file IS the perf surface: §Perf iterations in EXPERIMENTS.md are diffs
against the choices recorded here.  Baselines were chosen by napkin math
(see DESIGN.md §6); deviations per arch:

* 405B / 340B / 76B-VLM: FSDP over the batch axes + bf16 params + bf16 Adam
  moments (fp32 master math in-step) + grouped remat + sequence-sharded
  residual stream — the combination that fits v5e HBM at 256 chips.
* qwen2-moe (60 experts vs 16-way axis): TP-in-expert instead of EP.
* zamba2 long_500k: shared-attention block runs a 4096 sliding window.
* whisper / qwen1.5 (20 heads vs 16-way axis): attention stays replicated
  on the model axis (divisibility fallback), FFN/vocab still shard.
"""

from __future__ import annotations

import dataclasses

from repro.configs import get_config
from repro.models.config import ModelConfig, ShapeCell, ShardingPlan

_GIANT = {"llama3-405b", "nemotron-4-340b", "internvl2-76b"}

# remat_group must divide num_layers
_REMAT_GROUP = {"llama3-405b": 7, "nemotron-4-340b": 8, "internvl2-76b": 8}


def plan_for(arch: str, cell: ShapeCell, *, multi_pod: bool) -> ShardingPlan:
    # FSDP on every train cell (MaxText-style default: optimizer+param
    # shards over the batch axes); serving keeps params TP-only — a per-step
    # all-gather of the full model would dominate decode latency.
    fsdp = cell.kind == "train"
    fsdp_axes = ("pod", "data") if multi_pod else ("data",)
    seq_shard = arch in _GIANT and cell.kind == "train"
    return ShardingPlan(
        batch_axes=("pod", "data"),
        model_axis="model",
        fsdp=fsdp,
        fsdp_axes=fsdp_axes,
        seq_shard=seq_shard,
    )


def tuned_config(arch: str, cell: ShapeCell) -> ModelConfig:
    cfg = get_config(arch)
    rep: dict = {}
    if cell.kind == "train":
        rep["remat"] = "full"
        if arch in _REMAT_GROUP:
            rep["remat_group"] = _REMAT_GROUP[arch]
    else:
        rep["remat"] = "none"
        # serving in bf16 weights (industry norm; halves weight HBM and,
        # for the 20-head archs whose attention replicates on the model
        # axis, keeps the per-chip footprint inside v5e HBM)
        rep["param_dtype"] = "bfloat16"
    if arch in _GIANT:
        rep["param_dtype"] = "bfloat16"
    if arch == "zamba2-2.7b" and cell.name == "long_500k":
        rep["ssm"] = dataclasses.replace(cfg.ssm, attn_window=4096)
    return dataclasses.replace(cfg, **rep)


def opt_state_dtype(arch: str) -> str:
    return "bfloat16" if arch in _GIANT else "float32"
