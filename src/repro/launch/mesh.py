"""Production mesh factory.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init;
smoke tests must keep seeing 1 device).
"""

from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax.sharding.AxisType landed after 0.4.x; explicit Auto axis types are
    # the default there anyway, so fall back to the plain call on older jax
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use small host-device meshes)."""
    return _make_mesh(tuple(shape), tuple(axes))
