import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver:
  1. builds the full-size model config (plans.tuned_config) and the
     production mesh (single-pod 16×16 = 256 chips, multi-pod 2×16×16 = 512),
  2. resolves parameter/optimizer/cache PartitionSpecs from the divisibility
     -aware rules (models.sharding.Sharder),
  3. ``jax.jit(step, in_shardings, out_shardings).lower(**ShapeDtypeStructs)``
     and ``.compile()`` — no arrays are ever allocated,
  4. prints ``compiled.memory_analysis()`` (fits-per-device proof) and
     ``compiled.cost_analysis()``, runs the trip-count-aware HLO analyzer,
     and writes the roofline report JSON for EXPERIMENTS.md.

Usage::

    python -m repro.launch.dryrun --arch llama3-405b --cell train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--arch X] [--cell Y]
"""

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS
from repro.launch import plans
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (
    analytic_memory_bytes,
    build_report,
    save_report,
    tree_shard_bytes,
)
from repro.models.api import build_model
from repro.models.config import SHAPE_CELLS, shape_cell, supports_cell
from repro.models.counting import model_flops
from repro.models.sharding import Sharder
from repro.optim import adamw
from repro.train.step import build_train_step


# -- rules tree -> NamedSharding tree (walks params/cache structures) --------


def spec_tree(sharder: Sharder, shapes, rules):
    """Walk a shapes pytree (dicts/tuples/lists of ShapeDtypeStructs)
    alongside a rules tree of the same container structure; leaves are
    ShapeDtypeStructs, so containers are never ambiguous."""
    if isinstance(shapes, dict):
        return {k: spec_tree(sharder, v, rules[k]) for k, v in shapes.items()}
    if isinstance(shapes, (tuple, list)):
        return type(shapes)(
            spec_tree(sharder, s, r) for s, r in zip(shapes, rules)
        )
    return NamedSharding(sharder.mesh, sharder.spec(shapes.shape, rules))


def _mirror(shapes, ns_tree_builder):
    return jax.tree_util.tree_map(ns_tree_builder, shapes)


def shardings_for(model, sharder, cell, opt_dtype):
    """(in_shardings, arg ShapeDtypeStructs, donate) for the cell's step."""
    mesh = sharder.mesh
    rep = NamedSharding(mesh, P())
    params_shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    param_ns = spec_tree(sharder, params_shapes, model.param_rules())
    batch_shapes = model.input_specs(cell)
    batch_ns = {}
    for k, v in batch_shapes.items():
        if k in ("tokens", "labels"):
            batch_ns[k] = NamedSharding(mesh, sharder.spec(v.shape, ["batch", None]))
        elif k in ("patch_embeds", "frames"):
            batch_ns[k] = NamedSharding(
                mesh, sharder.spec(v.shape, ["batch", None, None])
            )
        else:  # pos scalar
            batch_ns[k] = rep
    if cell.kind == "train":
        opt_shapes = jax.eval_shape(
            lambda ps: adamw.init(ps, state_dtype=opt_dtype), params_shapes
        )
        opt_ns = {
            "mu": spec_tree(sharder, opt_shapes["mu"], model.param_rules()),
            "nu": spec_tree(sharder, opt_shapes["nu"], model.param_rules()),
            "step": rep,
        }
        return (
            (param_ns, opt_ns, batch_ns),
            (params_shapes, opt_shapes, batch_shapes),
            (0, 1),
        )
    if cell.kind == "decode":
        window = (
            model.cfg.ssm.attn_window
            if model.cfg.ssm is not None else None
        )
        cache_shapes = jax.eval_shape(
            lambda: model.init_cache(cell.global_batch, cell.seq_len,
                                     window=window)
        )
        cache_ns = spec_tree(sharder, cache_shapes, model.cache_rules())
        return (
            (param_ns, cache_ns, batch_ns),
            (params_shapes, cache_shapes, batch_shapes),
            (1,),
        )
    # prefill
    return ((param_ns, batch_ns), (params_shapes, batch_shapes), ())


def lower_cell(arch: str, cell_name: str, *, multi_pod: bool,
               cfg_override=None, plan_override=None, tag="baseline",
               save=True, verbose=True, train_variant="plain"):
    cell = shape_cell(cell_name)
    cfg = cfg_override if cfg_override is not None else plans.tuned_config(arch, cell)
    ok, why = supports_cell(cfg, cell)
    if not ok:
        return {"arch": arch, "cell": cell_name, "skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = mesh.size
    plan = plan_override if plan_override is not None else plans.plan_for(
        arch, cell, multi_pod=multi_pod
    )
    sharder = Sharder(mesh, plan)
    model = build_model(cfg)
    opt_dtype = plans.opt_state_dtype(arch)

    in_ns, arg_shapes, donate = shardings_for(model, sharder, cell, opt_dtype)

    if cell.kind == "train":
        opt_cfg = adamw.AdamWConfig(
            state_dtype=opt_dtype,
            reduce_dtype="bfloat16" if cfg.param_dtype == "bfloat16" else None,
        )
        if train_variant == "compressed":
            # int8 error-feedback gradient compression (§Perf): the EF
            # residual rides along as an extra donated argument
            from repro.train.step import build_compressed_train_step

            step = build_compressed_train_step(model, opt_cfg, sharder)
            res_shapes = jax.tree_util.tree_map(
                lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32),
                arg_shapes[0],
            )
            in_ns = (in_ns[0], in_ns[1], in_ns[0], in_ns[2])
            arg_shapes = (arg_shapes[0], arg_shapes[1], res_shapes,
                          arg_shapes[2])
            donate = (0, 1, 2)
            fn = step
            out_ns = (in_ns[0], in_ns[1], in_ns[0], None)
        else:
            fn = build_train_step(model, opt_cfg, sharder,
                                  grad_shardings=in_ns[0])
            out_ns = (in_ns[0], in_ns[1], None)
    elif cell.kind == "decode":
        window = cfg.ssm.attn_window if cfg.ssm is not None else None

        def fn(params, cache, batch):
            return model.decode_step(params, cache, batch, sharder=sharder)

        out_ns = (None, in_ns[1])
    else:  # prefill

        def fn(params, batch):
            logits, cache = model.prefill(params, batch, sharder=sharder)
            return logits, cache

        out_ns = None

    t0 = time.time()
    jitted = jax.jit(fn, in_shardings=in_ns, out_shardings=out_ns,
                     donate_argnums=donate)
    lowered = jitted.lower(*arg_shapes)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_stats = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
    }
    try:
        xla_cost = {k: float(v) for k, v in (compiled.cost_analysis() or {}).items()
                    if k in ("flops", "bytes accessed")}
    except Exception:  # noqa: BLE001
        xla_cost = {}
    hlo_cost = analyze(compiled.as_text())
    mf = model_flops(cfg, cell)

    # analytic memory term from the actual shard sizes
    param_b = tree_shard_bytes(arg_shapes[0], in_ns[0])
    opt_b = tree_shard_bytes(arg_shapes[1], in_ns[1]) if cell.kind == "train" else 0
    cache_b = tree_shard_bytes(arg_shapes[1], in_ns[1]) if cell.kind == "decode" else 0
    if cell.kind == "prefill":
        window = cfg.ssm.attn_window if cfg.ssm is not None else None
        cache_shapes = jax.eval_shape(
            lambda: build_model(cfg).init_cache(cell.global_batch, cell.seq_len,
                                                window=window)
        )
        cache_b = tree_shard_bytes(
            cache_shapes, spec_tree(Sharder(mesh, plan), cache_shapes,
                                    model.cache_rules())
        )
    analytic_b = analytic_memory_bytes(
        cfg, cell, mesh, plan, param_bytes=param_b, opt_bytes=opt_b,
        cache_bytes=cache_b,
    )
    report = build_report(arch, cell_name, mesh_name, chips, hlo_cost, mf,
                          mem_stats, xla_cost, analytic_bytes=analytic_b)
    if verbose:
        print(report.summary(), flush=True)
        per_dev = (mem_stats["argument_bytes"] + mem_stats["temp_bytes"]) / chips
        print(
            f"  memory_analysis: args={mem_stats['argument_bytes']/1e9:.2f}GB "
            f"temp={mem_stats['temp_bytes']/1e9:.2f}GB total "
            f"(~{per_dev/1e9:.2f}GB/chip)  "
            f"cost_analysis: {xla_cost}  "
            f"lower={t_lower:.0f}s compile={t_compile:.0f}s",
            flush=True,
        )
    if save:
        save_report(report, tag=tag)
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS) + [None])
    ap.add_argument("--cell", default=None,
                    choices=[c.name for c in SHAPE_CELLS] + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    cells = [args.cell] if args.cell else [c.name for c in SHAPE_CELLS]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        for cell in cells:
            for mp in meshes:
                try:
                    r = lower_cell(arch, cell, multi_pod=mp, tag=args.tag)
                    if isinstance(r, dict) and "skipped" in r:
                        print(f"{arch:18s} {cell:12s} "
                              f"{'pod2x16x16' if mp else 'pod16x16':9s} "
                              f"SKIP: {r['skipped']}", flush=True)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, cell, mp, repr(e)[:500]))
                    print(f"{arch:18s} {cell:12s} FAIL({mp=}): {e!r}"[:300],
                          flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES")
        return 1
    print("\nALL CELLS COMPILED")
    return 0


if __name__ == "__main__":
    sys.exit(main())
