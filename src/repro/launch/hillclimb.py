import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> validate.

Three cells (chosen per the assignment's criteria from the baseline table):

1. llama3-405b × train_4k   — most collective-bound (TP act all-reduces)
2. qwen1.5-4b × prefill_32k — worst roofline fraction (score-tile traffic)
3. llama3-405b × decode_32k — paper-representative (the serving step HAM's
   device table dispatches) + the v5e HBM fit crisis

Each iteration is a (cfg_override, plan_override) delta against
``plans.tuned_config``/``plans.plan_for``; results are written as tagged
JSONs next to the baselines and summarised for EXPERIMENTS.md §Perf.
"""

import dataclasses
import sys

from repro.launch import plans
from repro.launch.dryrun import lower_cell
from repro.models.config import shape_cell


def _show(label, r, base=None):
    extra = ""
    if base is not None:
        dom = base.bottleneck
        before = {"compute": base.t_compute, "memory": base.t_memory,
                  "collective": base.t_collective}[dom]
        after = {"compute": r.t_compute, "memory": r.t_memory,
                 "collective": r.t_collective}[dom]
        extra = (f"  [dominant({dom}): {before*1e3:.1f} -> {after*1e3:.1f} ms, "
                 f"{(1 - after/before)*100:+.1f}% | roofline "
                 f"{base.roofline_fraction*100:.1f}% -> "
                 f"{r.roofline_fraction*100:.1f}%]")
    print(f"--- {label}\n{r.summary()}{extra}", flush=True)


def climb_llama_train():
    arch, cell = "llama3-405b", "train_4k"
    c = shape_cell(cell)
    base = lower_cell(arch, cell, multi_pod=False, tag="baseline", save=True,
                      verbose=False)
    _show("BASELINE (paper-faithful sharding, remat=full)", base)

    # it1: remat="dots" — hypothesis: saving dot outputs removes the whole
    # recompute forward pass, cutting one of three TP all-reduce sweeps
    # (napkin: collective -1/3) at higher saved-activation memory
    cfg1 = dataclasses.replace(plans.tuned_config(arch, c), remat="dots",
                               remat_group=1)
    r1 = lower_cell(arch, cell, multi_pod=False, cfg_override=cfg1,
                    tag="it1_remat_dots", save=True, verbose=False)
    _show("it1 remat=dots (kill recompute pass)", r1, base)

    # it2: int8 error-feedback gradient compression — hypothesis: the grad
    # reduce (~810GB bf16 global) quarters on the wire
    from repro.launch.dryrun import shardings_for
    cfg2 = plans.tuned_config(arch, c)
    r2 = lower_cell(arch, cell, multi_pod=False, cfg_override=cfg2,
                    tag="it2_grad_int8", save=True, verbose=False,
                    train_variant="compressed")
    _show("it2 int8 EF gradient compression", r2, base)

    # it3: combine the winners
    cfg3 = dataclasses.replace(plans.tuned_config(arch, c), remat="dots",
                               remat_group=1)
    r3 = lower_cell(arch, cell, multi_pod=False, cfg_override=cfg3,
                    tag="it3_combined", save=True, verbose=False,
                    train_variant="compressed")
    _show("it3 combined", r3, base)
    return base, [r1, r2, r3]


def climb_qwen_prefill():
    arch, cell = "qwen1.5-4b", "prefill_32k"
    c = shape_cell(cell)
    base = lower_cell(arch, cell, multi_pod=False, tag="baseline", save=True,
                      verbose=False)
    _show("BASELINE (chunked ref attention, full-S per chunk)", base)

    # it1: causal skip — hypothesis: kv extent grows with the chunk index,
    # halving score traffic AND attention flops (triangle vs square)
    cfg1 = dataclasses.replace(plans.tuned_config(arch, c),
                               attn_causal_skip=True)
    r1 = lower_cell(arch, cell, multi_pod=False, cfg_override=cfg1,
                    tag="it1_causal_skip", save=True, verbose=False)
    _show("it1 causal-skip chunking", r1, base)

    # it2: + flash attention (Pallas kernel, validated vs oracle in
    # tests/test_kernels.py): score tiles stay in VMEM -> memory term loses
    # the score-traffic component entirely
    cfg2 = dataclasses.replace(plans.tuned_config(arch, c),
                               attn_causal_skip=True, attn_impl="flash")
    r2 = lower_cell(arch, cell, multi_pod=False, cfg_override=cfg2,
                    tag="it2_flash", save=True, verbose=False)
    _show("it2 + flash kernel (VMEM-resident scores)", r2, base)
    return base, [r1, r2]


def climb_llama_decode():
    arch, cell = "llama3-405b", "decode_32k"
    c = shape_cell(cell)
    base = lower_cell(arch, cell, multi_pod=False, tag="baseline", save=True,
                      verbose=False)
    _show("BASELINE (TP-only weights: 50GB/chip — does NOT fit v5e)", base)

    # it1: serve-FSDP — weights stored sharded over data too (3.2GB/chip,
    # fits), gathered per layer inside the scan; costs an all-gather sweep
    plan1 = dataclasses.replace(
        plans.plan_for(arch, c, multi_pod=False), fsdp=True
    )
    r1 = lower_cell(arch, cell, multi_pod=False, plan_override=plan1,
                    tag="it1_serve_fsdp", save=True, verbose=False)
    _show("it1 serve-FSDP (fits; pays weight all-gather)", r1, base)

    # it2: + int8 KV cache (per-vector scales): halves cache bytes
    cfg2 = dataclasses.replace(plans.tuned_config(arch, c), kv_quant=True)
    r2 = lower_cell(arch, cell, multi_pod=False, cfg_override=cfg2,
                    plan_override=plan1, tag="it2_kv_int8", save=True,
                    verbose=False)
    _show("it2 + int8 KV cache", r2, base)
    return base, [r1, r2]


def main(argv):
    which = argv[0] if argv else "all"
    if which in ("all", "llama_train"):
        climb_llama_train()
    if which in ("all", "qwen_prefill"):
        climb_qwen_prefill()
    if which in ("all", "llama_decode"):
        climb_llama_decode()
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
