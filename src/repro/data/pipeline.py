"""Deterministic synthetic token pipeline.

Every batch is a pure function of (seed, step, shard) — no files, no state.
That determinism is what the fault-tolerance tests lean on: a restarted
worker reproduces exactly the batches it would have seen, so checkpoint
-restart equality can be asserted bit-for-bit.

The token stream is Zipfian with a Markov flavour (token t+1 depends on t),
so cross-entropy actually decreases during the e2e training examples —
a pure-uniform stream would pin the loss at log(V).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.1


class SyntheticTokens:
    """Sharded, deterministic, restartable token source."""

    def __init__(self, cfg: DataConfig, shard: int = 0, num_shards: int = 1):
        if cfg.global_batch % num_shards:
            raise ValueError(
                f"global_batch {cfg.global_batch} not divisible by "
                f"{num_shards} shards"
            )
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        self.local_batch = cfg.global_batch // num_shards
        # fixed Zipf unigram table + a deterministic "grammar" permutation
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_alpha)
        self._probs = probs / probs.sum()
        rng = np.random.default_rng(cfg.seed)
        self._succ = rng.permutation(cfg.vocab_size)  # t -> likely successor

    def batch(self, step: int) -> dict:
        """{'tokens': (local_batch, S) int32, 'labels': same} for ``step``."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + self.shard
        )
        B, S = self.local_batch, cfg.seq_len
        base = rng.choice(cfg.vocab_size, size=(B, S + 1), p=self._probs)
        # Markov mixing: with p=0.5 the next token is succ(prev) — learnable
        follow = rng.random((B, S)) < 0.5
        seq = base.copy()
        for t in range(1, S + 1):
            seq[:, t] = np.where(follow[:, t - 1], self._succ[seq[:, t - 1]],
                                 base[:, t])
        return {
            "tokens": seq[:, :S].astype(np.int32),
            "labels": seq[:, 1 : S + 1].astype(np.int32),
        }

    def frontend_stub(self, step: int, kind: str, d_model: int, n: int) -> np.ndarray:
        """Precomputed modality embeddings (VLM patches / audio frames)."""
        rng = np.random.default_rng(
            (self.cfg.seed * 9_176_941 + step) * 131 + self.shard + hash(kind) % 1000
        )
        return rng.standard_normal((self.local_batch, n, d_model)).astype(np.float32)


def batch_for_model(source: SyntheticTokens, cfg, step: int) -> dict:
    """Model-aware batch: adds stub frontend tensors per family."""
    b = source.batch(step)
    if cfg.vlm is not None:
        b["patch_embeds"] = source.frontend_stub(
            step, "vlm", cfg.d_model, cfg.vlm.num_patches
        )
    if cfg.encdec is not None:
        b["frames"] = source.frontend_stub(
            step, "audio", cfg.d_model, cfg.encdec.encoder_frames
        )
    return b
