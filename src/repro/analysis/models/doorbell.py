"""Model of the doorbell arm/park/wake protocol (PR 7).

The shm receiver parks on a futex word instead of burning CPU; the protocol
has two known lost-wakeup windows that PR 7 closed:

* *publish-before-arm*: a frame published before ``waiters`` is set gets no
  wake — closed by the MANDATORY ring re-poll between arm and park.
* *publish-after-repoll*: a frame published after the re-poll bumps ``seq``
  — closed by FUTEX_WAIT's compare-on-entry against the pre-poll snapshot.

This model explores every interleaving of N producers (each: publish,
non-atomic two-step seq bump, read waiters, conditional wake) against one
consumer driven by :data:`repro.comm.doorbell.CONSUMER_PARK_PROTOCOL` —
the step list is built from the implementation's tuple, so reordering the
implementation (e.g. snapshotting ``seq`` after the re-poll) reshapes the
model and the checker finds the stranded park.

FUTEX_WAIT has no timeout here: a park that nothing will ever wake is a
deadlock state, and the checker flags it when published frames are pending
(liveness-as-safety).  A park with nothing pending is benign — in the real
system ``park_timeout`` bounds it and termination arrives as a frame.  The
model's guarantee is interleaving-level: it assumes each half-word access
is sequentially consistent, which CPython shared memory on x86/ARM-with-
GIL-handoff approximates; see docs/static-analysis.md for the caveat.
"""

from __future__ import annotations

from repro.comm.doorbell import (
    CONSUMER_PARK_PROTOCOL,
    PRODUCER_RING_PROTOCOL,
    SEQ_OFF,
    WAITERS_OFF,
    Doorbell,
)

__all__ = ["DoorbellModel"]

# Layout: two distinct u32 words in one segment, futex on the seq word.
assert SEQ_OFF != WAITERS_OFF
assert max(SEQ_OFF, WAITERS_OFF) + 4 <= Doorbell.NBYTES

# The step VOCABULARY is fixed; the step ORDER is taken from the tuples so
# an implementation reorder is model-checked rather than assumed away.
assert set(PRODUCER_RING_PROTOCOL) == {
    "publish", "bump_seq", "read_waiters", "wake_if_armed",
}
assert PRODUCER_RING_PROTOCOL[0] == "publish"
assert set(CONSUMER_PARK_PROTOCOL) == {
    "arm", "read_seq", "repoll", "wait_if_unchanged",
}
assert CONSUMER_PARK_PROTOCOL[0] == "arm"
assert CONSUMER_PARK_PROTOCOL[-1] == "wait_if_unchanged"

# the non-atomic seq bump is two micro-steps (Python has no atomic RMW on
# shared memory) — concurrent producers can interleave and collapse bumps
_PRODUCER_MICRO = {
    "publish": ("publish",),
    "bump_seq": ("bump_read", "bump_write"),
    "read_waiters": ("read_waiters",),
    "wake_if_armed": ("wake",),
}

_PARKED = "parked"
_TOP = "top"
_DONE = "done"


class DoorbellModel:
    """States are ``(seq, waiters, pending, producers, consumer)``:

    * ``seq``/``waiters`` — the two futex-segment words.
    * ``pending`` — published-but-unconsumed frame count (the rings).
    * ``producers`` — per-producer ``(items_left, pc, reg)``; ``pc`` indexes
      the micro-step list, ``reg`` holds the bump's read value.
    * ``consumer`` — ``(phase, reg)``; phase is ``"top"``, an index into
      the park-step list, ``"parked"``, or ``"done"``; ``reg`` is the seq
      snapshot FUTEX_WAIT compares against.
    """

    def __init__(self, *, producers: int = 2, items: int = 1,
                 repoll: bool = True, seq_check: bool = True):
        self.n_producers = producers
        self.items = items
        self.repoll = repoll
        self.seq_check = seq_check
        broken = [] if repoll else ["no-repoll"]
        if not seq_check:
            broken.append("no-seq-check")
        self.name = (
            f"doorbell({'BROKEN ' + '+'.join(broken) if broken else 'mitigated'}, "
            f"producers={producers}, items={items})"
        )
        self._psteps = [
            micro for step in PRODUCER_RING_PROTOCOL
            for micro in _PRODUCER_MICRO[step]
        ]
        self._csteps = [
            s for s in CONSUMER_PARK_PROTOCOL
            if repoll or s != "repoll"
        ]

    # -- state helpers -----------------------------------------------------

    def initial_state(self):
        producers = tuple((self.items, 0, 0) for _ in range(self.n_producers))
        return (0, 0, 0, producers, (_TOP, 0))

    @staticmethod
    def _producer_done(p) -> bool:
        items_left, pc, _reg = p
        return items_left == 0 and pc == 0

    # -- transition relation ----------------------------------------------

    def actions(self, state):
        seq, waiters, pending, producers, consumer = state
        out = []
        for i, p in enumerate(producers):
            if not self._producer_done(p):
                out.append(self._producer_step(state, i))
        out.extend(self._consumer_steps(state))
        return [a for a in out if a is not None]

    def _with_producer(self, producers, i, p):
        return producers[:i] + (p,) + producers[i + 1 :]

    def _finish_item(self, p):
        items_left, _pc, _reg = p
        return (items_left - 1, 0, 0)

    def _producer_step(self, state, i):
        seq, waiters, pending, producers, consumer = state
        items_left, pc, reg = producers[i]
        step = self._psteps[pc]
        who = f"producer {i}"
        if step == "publish":
            nxt = self._with_producer(producers, i, (items_left, pc + 1, reg))
            return (f"{who}: publish frame (pending={pending + 1})",
                    (seq, waiters, pending + 1, nxt, consumer))
        if step == "bump_read":
            nxt = self._with_producer(producers, i, (items_left, pc + 1, seq))
            return (f"{who}: bump reads seq={seq}",
                    (seq, waiters, pending, nxt, consumer))
        if step == "bump_write":
            nxt = self._with_producer(producers, i, (items_left, pc + 1, 0))
            return (f"{who}: bump writes seq={reg + 1}",
                    (reg + 1, waiters, pending, nxt, consumer))
        if step == "read_waiters":
            if waiters == 0:
                nxt = self._with_producer(producers, i, self._finish_item(
                    (items_left, pc, reg)))
                return (f"{who}: waiters==0, skip wake",
                        (seq, waiters, pending, nxt, consumer))
            nxt = self._with_producer(producers, i, (items_left, pc + 1, reg))
            return (f"{who}: waiters==1, will wake",
                    (seq, waiters, pending, nxt, consumer))
        # "wake": FUTEX_WAKE unparks whoever is parked AT SYSCALL TIME
        nxt = self._with_producer(producers, i, self._finish_item(
            (items_left, pc, reg)))
        phase, creg = consumer
        if phase == _PARKED:
            # woken consumer resumes the armed loop: re-snapshot, re-poll
            return (f"{who}: FUTEX_WAKE unparks consumer",
                    (seq, waiters, pending, nxt, (1, creg)))
        return (f"{who}: FUTEX_WAKE finds nobody parked",
                (seq, waiters, pending, nxt, consumer))

    def _consumer_steps(self, state):
        seq, waiters, pending, producers, consumer = state
        phase, reg = consumer
        if phase in (_PARKED, _DONE):
            return []
        if phase == _TOP:
            if pending:
                return [(f"consumer: poll finds {pending} frame(s), consume",
                         (seq, 0, 0, producers, (_TOP, 0)))]
            if all(self._producer_done(p) for p in producers):
                return [("consumer: all producers done, exit",
                         (seq, waiters, pending, producers, (_DONE, 0)))]
            # spin budget exhausted: enter the park sequence
            assert self._csteps[0] == "arm"
            return [("consumer: arm (waiters=1)",
                     (seq, 1, pending, producers, (1, reg)))]
        step = self._csteps[phase]
        if step == "read_seq":
            return [(f"consumer: snapshot seq={seq}",
                     (seq, waiters, pending, producers, (phase + 1, seq)))]
        if step == "repoll":
            if pending:
                return [(f"consumer: re-poll finds {pending} frame(s), "
                         "consume and disarm",
                         (seq, 0, 0, producers, (_TOP, 0)))]
            return [("consumer: re-poll finds nothing",
                     (seq, waiters, pending, producers, (phase + 1, reg)))]
        # "wait_if_unchanged"
        if self.seq_check and seq != reg:
            return [(f"consumer: FUTEX_WAIT sees seq={seq} != expected "
                     f"{reg}, EAGAIN",
                     (seq, waiters, pending, producers, (1, reg)))]
        return [(f"consumer: FUTEX_WAIT parks (seq={seq})",
                 (seq, waiters, pending, producers, (_PARKED, reg)))]

    # -- properties --------------------------------------------------------

    def invariant(self, state):
        _seq, _waiters, pending, producers, consumer = state
        if consumer[0] == _DONE and pending:
            return f"consumer exited with {pending} frame(s) pending"
        return None

    def deadlock(self, state):
        _seq, _waiters, pending, producers, consumer = state
        if consumer[0] == _PARKED and pending:
            return (
                f"lost wakeup: consumer parked forever with {pending} "
                "published frame(s) pending and all producers finished "
                "(PR 7)"
            )
        # parked with nothing pending is benign: park_timeout bounds it in
        # the real system, and termination arrives as a frame
        return None
