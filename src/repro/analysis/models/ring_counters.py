"""Model of the shm ring's double-publish torn-counter mitigation (PR 1).

CPython ``struct.pack_into``/``unpack_from`` on shared memory can tear an
8-byte counter: a reader racing a writer observes a value that was *never
stored* — typically a fabricated-high ``head`` that sends the consumer past
the published bytes into garbage.  PR 1 mitigated this by publishing every
counter twice (primary then confirm copy) and having readers re-read until
the independently loaded pair matches.

This module models one monotonic counter (``head``) as two half-words so a
torn load/store is a first-class pair of transitions, not a probabilistic
event.  The writer publishes the values ``1..publishes`` in order; a single
reader performs one load.  Safety: a load may be *stale* (monotonic
counters make stale conservative) but must never exceed the newest value
whose publication has begun — a fabricated-high counter is exactly the
frame-boundary corruption PR 1 fixed.

Layout offsets and step orders are imported from :mod:`repro.comm.shm`, so
the model and the implementation share one source of truth.  With
``mitigated=False`` the reader does what the pre-PR-1 code did — one raw
load of the primary word, no confirm compare — and the checker must
rediscover the fabrication.
"""

from __future__ import annotations

from repro.comm.shm import (
    COUNTER_CONFIRM_STRIDE,
    COUNTER_LOAD_ORDER,
    COUNTER_STABLE_RETRIES,
    COUNTER_STORE_ORDER,
    HEAD_CONFIRM_OFF,
    HEAD_OFF,
)

__all__ = ["RingCounterModel"]

# The model is built for the implemented layout: one u64 confirm copy
# directly after each primary word, stored primary-first, loaded
# confirm-first.  If the implementation reshapes, these trip and force the
# model to be revisited rather than silently verifying the wrong protocol.
assert HEAD_CONFIRM_OFF == HEAD_OFF + COUNTER_CONFIRM_STRIDE
assert COUNTER_STORE_ORDER == ("primary", "confirm")
assert set(COUNTER_LOAD_ORDER) == {"primary", "confirm"}

#: the implementation retries ``COUNTER_STABLE_RETRIES`` (10000) times
#: before the min() fallback; the model shrinks the bound so the fallback
#: path is reachable and verified, not just the happy path
MODEL_RETRIES = min(2, COUNTER_STABLE_RETRIES)

_DONE = -1  # reader pc sentinel


def _halves(v: int) -> tuple[int, int]:
    """(lo, hi) half-words of a counter value, stored/loaded lo-first
    (little-endian: low bytes land first)."""
    return v & 1, v >> 1


def _value(lo: int, hi: int) -> int:
    return (hi << 1) | lo


class RingCounterModel:
    """States are tuples ``(w_pc, mem, r_pc, regs, retries, accepted)``:

    * ``w_pc`` — writer micro-step counter; each publish is four half-word
      stores (primary lo, primary hi, confirm lo, confirm hi).
    * ``mem`` — ``(p_lo, p_hi, c_lo, c_hi)`` shared half-words.
    * ``r_pc``/``regs``/``retries`` — reader program counter, loaded
      half-word registers, and retry count.
    * ``accepted`` — the value the reader returned, or None.
    """

    def __init__(self, *, publishes: int = 2, mitigated: bool = True):
        # below 2 publishes no fabricated-high value is constructible and
        # the broken variant would vacuously verify
        if publishes < 2:
            raise ValueError("need >= 2 publishes to expose a torn read")
        self.publishes = publishes
        self.mitigated = mitigated
        self.name = (
            f"ring-counters({'mitigated' if mitigated else 'BROKEN'}, "
            f"publishes={publishes})"
        )
        # reader load program: half-words of each word in the
        # implementation's load order (confirm first when mitigated)
        if mitigated:
            self._loads = [
                (word, half)
                for word in COUNTER_LOAD_ORDER
                for half in ("lo", "hi")
            ]
        else:
            self._loads = [("primary", "lo"), ("primary", "hi")]

    # -- state helpers -----------------------------------------------------

    def initial_state(self):
        return (0, (0, 0, 0, 0), 0, (None, None, None, None), 0, None)

    def _max_safe(self, w_pc: int) -> int:
        """Newest value whose publication has begun.  Frame bytes are
        written before the counter stores start, so accepting this value is
        safe; anything above it points past published data."""
        return (w_pc + 3) // 4

    # -- transition relation ----------------------------------------------

    def actions(self, state):
        w_pc, mem, r_pc, regs, retries, accepted = state
        out = []

        # writer: four half-word stores per publish, order derived from
        # COUNTER_STORE_ORDER x (lo, hi)
        if w_pc < 4 * self.publishes:
            publish = w_pc // 4 + 1
            word, half = (
                COUNTER_STORE_ORDER[(w_pc % 4) // 2],
                ("lo", "hi")[w_pc % 2],
            )
            lo, hi = _halves(publish)
            val = lo if half == "lo" else hi
            slot = {"primary": 0, "confirm": 2}[word] + (half == "hi")
            new_mem = list(mem)
            new_mem[slot] = val
            out.append((
                f"writer: publish {publish}: store {word} {half}={val}",
                (w_pc + 1, tuple(new_mem), r_pc, regs, retries, accepted),
            ))

        # reader
        if r_pc != _DONE:
            if r_pc < len(self._loads):
                word, half = self._loads[r_pc]
                slot = {"primary": 0, "confirm": 2}[word] + (half == "hi")
                new_regs = list(regs)
                new_regs[slot] = mem[slot]
                out.append((
                    f"reader: load {word} {half}={mem[slot]}",
                    (w_pc, mem, r_pc + 1, tuple(new_regs), retries, accepted),
                ))
            else:
                out.append(self._decide(state))
        return out

    def _decide(self, state):
        w_pc, mem, r_pc, regs, retries, accepted = state
        p = _value(regs[0], regs[1])
        if not self.mitigated:
            return (
                f"reader: accept raw primary={p} (no confirm compare)",
                (w_pc, mem, _DONE, regs, retries, p),
            )
        c = _value(regs[2], regs[3])
        if p == c:
            return (
                f"reader: primary==confirm=={p}, accept",
                (w_pc, mem, _DONE, regs, retries, p),
            )
        if retries + 1 < MODEL_RETRIES:
            return (
                f"reader: primary={p} != confirm={c}, retry",
                (w_pc, mem, 0, (None, None, None, None), retries + 1,
                 accepted),
            )
        v = min(p, c)
        return (
            f"reader: retries exhausted, accept min({p}, {c})={v}",
            (w_pc, mem, _DONE, regs, retries + 1, v),
        )

    # -- properties --------------------------------------------------------

    def invariant(self, state):
        w_pc, _mem, _r_pc, _regs, _retries, accepted = state
        if accepted is not None and accepted > self._max_safe(w_pc):
            return (
                f"torn counter: reader accepted {accepted}, but only "
                f"{self._max_safe(w_pc)} was ever published — the consumer "
                "would read past the published bytes (PR 1)"
            )
        return None

    def deadlock(self, state):
        """No parking in this protocol: every terminal state is benign."""
