"""Step-function models of the runtime's shared-memory protocols.

Each model is a small explicit-state transition system consumed by
:mod:`repro.analysis.modelcheck`.  The models do not re-invent the
protocols: layout offsets and step orders are imported from the
implementation modules (:mod:`repro.comm.shm`, :mod:`repro.comm.doorbell`)
so there is one source of truth — reordering the implementation reshapes
the model, and the checker catches the regression.

* :mod:`repro.analysis.models.ring_counters` — torn 8-byte counter reads
  vs. the double-publish/confirm-compare mitigation (PR 1).
* :mod:`repro.analysis.models.doorbell` — the arm/park/wake protocol and
  its two lost-wakeup windows (PR 7).
"""
