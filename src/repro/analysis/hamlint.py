"""hamlint — AST-based protocol linter for HAM handler registrations.

Usage::

    python -m repro.analysis.hamlint src/ [more roots...]
    python -m repro.analysis.hamlint --list-rules
    python -m repro.analysis.hamlint --select HAM001,HAM003 src/

Walks every ``.py`` file under the given roots, extracts every
``@handler`` / ``register(...)`` site (including the repo's
registration-loop idiom — ``for name, fn, read_only in ((...), ...):``
bodies are unrolled per literal tuple element), and runs the rule set from
:mod:`repro.analysis.rules`.  Exit status 0 = clean, 1 = findings (printed
as ``path:line:col: RULE message``), 2 = usage error.

What counts as a registration site
----------------------------------

* a decorator named ``handler`` (bare or called, ``@handler`` /
  ``@reg.handler(...)``);
* a call whose callee attribute is ``register`` or ``handler``, whose
  receiver is not ``atexit`` and whose first positional argument is not a
  string literal (this excludes ``atexit.register(cb)`` and the
  name-first ``DeviceHandlerTable.register("key", fn)`` family, which is a
  *device-side* table with its own validation);
* the same calls inside a ``for`` loop over a literal tuple-of-tuples —
  unrolled, so per-element ``name=`` / ``read_only=`` values resolve.

A site records whether it executes at *import time* (module level, or in a
function called at module level, transitively within the module) — the
property the same-source rule is built on.
"""

from __future__ import annotations

import ast
import os
import sys

from repro.analysis.rules import (
    Finding,
    LintContext,
    ModuleInfo,
    RegistrationSite,
    all_rules,
)

__all__ = ["lint_paths", "main", "parse_module", "extract_sites"]


# --------------------------------------------------------------------------
# module parsing
# --------------------------------------------------------------------------


def _modname_for(path: str) -> str:
    """Dotted module name, derived from the nearest ``src`` or package root
    on the path; bare basename otherwise (fixture corpora)."""
    norm = os.path.normpath(os.path.abspath(path))
    parts = norm.split(os.sep)
    stem = parts[-1][:-3] if parts[-1].endswith(".py") else parts[-1]
    if "src" in parts:
        rel = parts[parts.index("src") + 1 : -1]
        dotted = ".".join(rel + ([] if stem == "__init__" else [stem]))
        if dotted:
            return dotted
    return stem


def parse_module(path: str) -> ModuleInfo | None:
    try:
        with open(path, encoding="utf-8") as f:
            source = f.read()
        tree = ast.parse(source, filename=path)
    except (OSError, SyntaxError):
        return None
    mod = ModuleInfo(path=path, modname=_modname_for(path), tree=tree)

    for node in tree.body:
        _index_toplevel(mod, node)

    # functions executed at import time: called at module level, closed
    # transitively over same-module calls
    called: set[str] = set()
    _collect_calls(tree, called)
    frontier = [n for n in called if n in mod.toplevel_defs]
    seen = set(frontier)
    while frontier:
        fname = frontier.pop()
        mod.import_time_funcs.add(fname)
        inner: set[str] = set()
        _collect_calls(mod.toplevel_defs[fname], inner)
        for n in inner:
            if n in mod.toplevel_defs and n not in seen:
                seen.add(n)
                frontier.append(n)
    return mod


def _index_toplevel(mod: ModuleInfo, node: ast.AST) -> None:
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
        mod.toplevel_defs[node.name] = node
    elif isinstance(node, ast.Assign):
        for t in node.targets:
            if isinstance(t, ast.Name):
                mod.toplevel_assigns.add(t.id)
    elif isinstance(node, ast.AnnAssign) and isinstance(node.target, ast.Name):
        mod.toplevel_assigns.add(node.target.id)
    elif isinstance(node, ast.Import):
        for alias in node.names:
            mod.imports[alias.asname or alias.name.split(".")[0]] = alias.name
    elif isinstance(node, ast.ImportFrom):
        src = node.module or ""
        for alias in node.names:
            mod.imports[alias.asname or alias.name] = src
    elif isinstance(node, (ast.Try, ast.If, ast.With)):
        for child in ast.iter_child_nodes(node):
            _index_toplevel(mod, child)


def _collect_calls(node: ast.AST, out: set) -> None:
    """Names called as plain functions in code that RUNS when ``node``
    executes: nested function bodies are pruned (they only run when called
    — their decorators and defaults still evaluate here), class bodies are
    walked (they execute at definition time)."""
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        out.add(node.func.id)
    for child in ast.iter_child_nodes(node):
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
            for deco in getattr(child, "decorator_list", []):
                _collect_calls(deco, out)
            for default in (getattr(child, "args", None) and
                            child.args.defaults or []):
                _collect_calls(default, out)
            continue
        _collect_calls(child, out)


# --------------------------------------------------------------------------
# site extraction
# --------------------------------------------------------------------------

_REGISTER_ATTRS = {"register", "handler"}


def _const(node):
    """Literal constant value, or the sentinel ``_NOT_CONST``."""
    if isinstance(node, ast.Constant):
        return node.value
    return _NOT_CONST


_NOT_CONST = object()


def _kwargs_of(call: ast.Call) -> dict[str, ast.expr]:
    return {kw.arg: kw.value for kw in call.keywords if kw.arg is not None}


class _SiteExtractor(ast.NodeVisitor):
    def __init__(self, mod: ModuleInfo):
        self.mod = mod
        self.sites: list[RegistrationSite] = []
        #: stack of enclosing function names (module level = empty)
        self.func_stack: list[str] = []
        #: parameters of the innermost enclosing function(s)
        self.param_stack: list[set[str]] = []
        #: loop-variable bindings active at this point (from unrolled loops)
        self._loop_bindings: dict[str, ast.expr] | None = None
        #: decorator Call nodes already recorded as decorator sites —
        #: generic_visit will reach them again as plain calls; skip there
        self._decorator_calls: set[int] = set()

    # -- helpers -----------------------------------------------------------

    def _import_time_here(self) -> bool:
        if not self.func_stack:
            return True
        return self.func_stack[0] in self.mod.import_time_funcs

    def _resolve_fn(self, node: ast.expr | None):
        """(fn_name, func_def, fn_is_param) for the registered-function
        expression, resolving loop bindings first."""
        if self._loop_bindings is not None and isinstance(node, ast.Name):
            node = self._loop_bindings.get(node.id, node)
        if not isinstance(node, ast.Name):
            return None, None, False
        name = node.id
        is_param = any(name in params for params in self.param_stack)
        return name, self.mod.toplevel_defs.get(name), is_param

    def _resolve_value(self, node: ast.expr | None) -> ast.expr | None:
        if self._loop_bindings is not None and isinstance(node, ast.Name):
            return self._loop_bindings.get(node.id, node)
        return node

    def _add_site(self, call: ast.Call, *, via: str, fn_expr, func_def_node=None,
                  loc=None) -> None:
        kws = _kwargs_of(call)
        name_node = self._resolve_value(kws.get("name"))
        wire_name = _const(name_node)
        ro_node = self._resolve_value(kws.get("read_only"))
        ro = _const(ro_node)
        mu_node = self._resolve_value(kws.get("mutates"))
        mu = _const(mu_node)
        specs_kw = None
        specs_node = None
        for key in ("arg_specs", "args"):
            if key in kws:
                specs_kw = key
                specs_node = self._resolve_value(kws[key])
                break
        fn_name, func_def, fn_is_param = self._resolve_fn(fn_expr)
        if func_def_node is not None:
            func_def = func_def_node
            fn_name = func_def_node.name
            fn_is_param = False
        receiver = None
        if isinstance(call.func, ast.Attribute) and isinstance(
            call.func.value, ast.Name
        ):
            receiver = call.func.value.id
        loc = loc or call
        self.sites.append(RegistrationSite(
            module=self.mod,
            line=loc.lineno,
            col=loc.col_offset,
            via=via,
            wire_name=wire_name if isinstance(wire_name, str) else None,
            fn_name=fn_name,
            func_def=func_def,
            read_only=ro if isinstance(ro, bool) else None,
            mutates=mu if isinstance(mu, bool) else None,
            specs_node=specs_node,
            specs_kw=specs_kw,
            result_specs_node=self._resolve_value(kws.get("result_specs")),
            import_time=self._import_time_here(),
            receiver=receiver,
            fn_is_param=fn_is_param,
        ))

    # -- visitors ----------------------------------------------------------

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._visit_funcdef(node)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._visit_funcdef(node)

    def _visit_funcdef(self, node) -> None:
        for deco in node.decorator_list:
            call = deco if isinstance(deco, ast.Call) else None
            target = call.func if call else deco
            is_handler = (
                isinstance(target, ast.Name) and target.id == "handler"
            ) or (
                isinstance(target, ast.Attribute) and target.attr == "handler"
            )
            if is_handler:
                synth = call if call else ast.Call(func=target, args=[],
                                                   keywords=[])
                if call is not None:
                    self._decorator_calls.add(id(call))
                self._add_site(synth, via="decorator", fn_expr=None,
                               func_def_node=node, loc=deco)
        params = {a.arg for a in (
            node.args.posonlyargs + node.args.args + node.args.kwonlyargs
        )}
        if node.args.vararg:
            params.add(node.args.vararg.arg)
        if node.args.kwarg:
            params.add(node.args.kwarg.arg)
        self.func_stack.append(node.name)
        self.param_stack.append(params)
        self.generic_visit(node)
        self.func_stack.pop()
        self.param_stack.pop()

    def visit_For(self, node: ast.For) -> None:
        unrolled = self._try_unroll(node)
        if not unrolled:
            self.generic_visit(node)

    def _try_unroll(self, node: ast.For) -> bool:
        """Unroll ``for a, b, ... in ((...), (...)):`` over register calls."""
        if not isinstance(node.iter, (ast.Tuple, ast.List)):
            return False
        if not isinstance(node.target, ast.Tuple):
            return False
        targets = node.target.elts
        if not all(isinstance(t, ast.Name) for t in targets):
            return False
        elements = node.iter.elts
        if not elements or not all(
            isinstance(e, (ast.Tuple, ast.List)) and len(e.elts) == len(targets)
            for e in elements
        ):
            return False
        calls = [
            n for n in ast.walk(node)
            if isinstance(n, ast.Call)
            and isinstance(n.func, ast.Attribute)
            and n.func.attr in _REGISTER_ATTRS
            and self._is_registration_call(n)
        ]
        if not calls:
            return False
        for element in elements:
            bindings = {
                t.id: v for t, v in zip(targets, element.elts)
            }
            prev = self._loop_bindings
            self._loop_bindings = bindings
            try:
                for call in calls:
                    self._add_site(call, via="loop",
                                   fn_expr=call.args[0] if call.args else None,
                                   loc=element)
            finally:
                self._loop_bindings = prev
        return True

    def _is_registration_call(self, call: ast.Call) -> bool:
        func = call.func
        if not isinstance(func, ast.Attribute) or \
                func.attr not in _REGISTER_ATTRS:
            return False
        if isinstance(func.value, ast.Name) and func.value.id == "atexit":
            return False
        # name-first tables (DeviceHandlerTable.register("key", fn), serve
        # tables) are a different dispatch layer — skip string-first calls
        if call.args and isinstance(call.args[0], ast.Constant) \
                and isinstance(call.args[0].value, str):
            return False
        # a bare .register()/.handler() with neither a positional fn nor any
        # registration keyword is some unrelated API
        if not call.args and not call.keywords:
            return False
        return True

    def visit_Call(self, node: ast.Call) -> None:
        if self._is_registration_call(node) and \
                self._loop_bindings is None and \
                id(node) not in self._decorator_calls:
            self._add_site(node, via="call",
                           fn_expr=node.args[0] if node.args else None)
        self.generic_visit(node)


def extract_sites(mod: ModuleInfo) -> list[RegistrationSite]:
    ex = _SiteExtractor(mod)
    ex.visit(mod.tree)
    return ex.sites


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------


def _iter_py_files(roots):
    for root in roots:
        if os.path.isfile(root):
            if root.endswith(".py"):
                yield root
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = sorted(
                d for d in dirnames if d != "__pycache__"
            )
            for fname in sorted(filenames):
                if fname.endswith(".py"):
                    yield os.path.join(dirpath, fname)


def lint_paths(roots, select: set[str] | None = None) -> list[Finding]:
    modules = []
    for path in _iter_py_files(roots):
        mod = parse_module(path)
        if mod is not None:
            modules.append(mod)
    sites = []
    for mod in modules:
        sites.extend(extract_sites(mod))
    ctx = LintContext(modules=modules, sites=sites)
    findings: list[Finding] = []
    for rule_id, rule in sorted(all_rules().items()):
        if select and rule_id not in select:
            continue
        findings.extend(rule.check(ctx))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    select: set[str] | None = None
    roots: list[str] = []
    it = iter(argv)
    for arg in it:
        if not arg.startswith("-"):
            roots.append(arg)
            continue
        if arg == "--list-rules":
            for rule_id, rule in sorted(all_rules().items()):
                line = f"{rule_id}  {rule.title}"
                if rule.historical:
                    line += f"  [would have caught: {rule.historical}]"
                print(line)
            return 0
        if arg == "--select":
            val = next(it, None)
            if val is None:
                print("error: --select needs a comma-separated rule list",
                      file=sys.stderr)
                return 2
            select = set(val.split(","))
        elif arg.startswith("--select="):
            select = set(arg.split("=", 1)[1].split(","))
        else:
            print(f"error: unknown option {arg!r}", file=sys.stderr)
            return 2
    if not roots:
        print("usage: python -m repro.analysis.hamlint [--select IDS] "
              "[--list-rules] ROOT [ROOT...]", file=sys.stderr)
        return 2
    missing = [r for r in roots if not os.path.exists(r)]
    if missing:
        print(f"error: no such path(s): {', '.join(missing)}", file=sys.stderr)
        return 2
    findings = lint_paths(roots, select=select)
    for f in findings:
        print(f.format())
    if findings:
        print(f"hamlint: {len(findings)} finding(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
