"""modelcheck — explicit-state exhaustive-interleaving checker.

Usage::

    python -m repro.analysis.modelcheck [--quick] [--model NAME]

Explores EVERY interleaving of small step-function models of the two
hairiest shared-memory protocols in the runtime, with torn 8-byte
loads/stores modeled as first-class (two half-word) transitions:

* :mod:`repro.analysis.models.ring_counters` — the shm ring's double-publish
  torn-counter mitigation (PR 1).
* :mod:`repro.analysis.models.doorbell` — the seq/waiters arm-park-wake
  protocol (PR 7).

For each protocol the CLI checks BOTH directions, so a green run proves the
checker has teeth, not just green lights:

* the *mitigated* model (the protocol as implemented, constants imported
  from the implementation modules) must verify exhaustively, and
* every *broken* variant (a mitigation toggled off) must rediscover its
  historical bug as a concrete counterexample trace.

Exit status 0 only if all expectations hold.

Model interface
---------------

A model is an object with:

* ``name`` — display name,
* ``initial_state()`` — hashable state,
* ``actions(state)`` — iterable of ``(label, next_state)``; empty = final,
* ``invariant(state)`` — error string or None,
* ``deadlock(state)`` — error string or None, asked only when ``actions``
  is empty (liveness-as-safety: a stranded state is a lost wakeup).
"""

from __future__ import annotations

import dataclasses
import sys
from collections import deque

__all__ = ["ExploreResult", "explore", "main"]


@dataclasses.dataclass
class ExploreResult:
    ok: bool
    states: int
    violation: str | None = None
    trace: list[str] | None = None

    def describe(self) -> str:
        if self.ok:
            return f"verified ({self.states} states)"
        lines = [f"VIOLATION after {self.states} states: {self.violation}"]
        if self.trace:
            lines.append("shortest counterexample:")
            lines.extend(f"  {i + 1:2d}. {step}"
                         for i, step in enumerate(self.trace))
        return "\n".join(lines)


def explore(model, max_states: int = 2_000_000) -> ExploreResult:
    """BFS over the model's state graph; BFS order makes the first
    counterexample a shortest one."""
    init = model.initial_state()
    seen = {init}
    parent: dict = {init: None}  # state -> (prev_state, label)
    queue = deque([init])
    checked = 0

    def trace_to(state) -> list[str]:
        steps: list[str] = []
        while parent[state] is not None:
            state, label = parent[state]
            steps.append(label)
        steps.reverse()
        return steps

    while queue:
        state = queue.popleft()
        checked += 1
        err = model.invariant(state)
        if err is not None:
            return ExploreResult(False, checked, err, trace_to(state))
        actions = list(model.actions(state))
        if not actions:
            err = model.deadlock(state)
            if err is not None:
                return ExploreResult(False, checked, err, trace_to(state))
            continue
        for label, nxt in actions:
            if nxt not in seen:
                if len(seen) >= max_states:
                    raise RuntimeError(
                        f"state-space bound exceeded ({max_states}); "
                        "tighten the model"
                    )
                seen.add(nxt)
                parent[nxt] = (state, label)
                queue.append(nxt)
    return ExploreResult(True, checked)


def _suite(quick: bool):
    """(description, model, expect_ok) triples for the CLI gate."""
    from repro.analysis.models import doorbell, ring_counters

    publishes = 2
    producers, items = (1, 1) if quick else (2, 1)
    return [
        (
            "ring-counters mitigated (double-publish + confirm compare)",
            ring_counters.RingCounterModel(publishes=publishes,
                                           mitigated=True),
            True,
        ),
        (
            "ring-counters BROKEN (single-word read, PR 1 torn counter)",
            ring_counters.RingCounterModel(publishes=publishes,
                                           mitigated=False),
            False,
        ),
        (
            "doorbell mitigated (arm -> re-poll -> seq-checked park)",
            doorbell.DoorbellModel(producers=producers, items=items),
            True,
        ),
        (
            "doorbell BROKEN no re-poll (publish-before-arm lost wakeup)",
            doorbell.DoorbellModel(producers=producers, items=items,
                                   repoll=False),
            False,
        ),
        (
            "doorbell BROKEN no seq check (publish-after-repoll lost wakeup)",
            doorbell.DoorbellModel(producers=producers, items=items,
                                   seq_check=False),
            False,
        ),
    ]


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    quick = "--quick" in argv
    argv = [a for a in argv if a != "--quick"]
    only = None
    if "--model" in argv:
        i = argv.index("--model")
        if i + 1 >= len(argv):
            print("error: --model needs a name", file=sys.stderr)
            return 2
        only = argv[i + 1]
        del argv[i : i + 2]
    if argv:
        print(f"error: unknown arguments {argv}", file=sys.stderr)
        return 2

    failures = 0
    for desc, model, expect_ok in _suite(quick):
        if only is not None and only not in desc:
            continue
        result = explore(model)
        matched = result.ok == expect_ok
        status = "PASS" if matched else "FAIL"
        print(f"[{status}] {desc}")
        if result.ok:
            print(f"       {result.describe()}")
        else:
            for line in result.describe().splitlines():
                print(f"       {line}")
        if not matched:
            failures += 1
            if expect_ok:
                print("       expected exhaustive verification, found a "
                      "violation", file=sys.stderr)
            else:
                print("       expected the seeded bug to be found — the "
                      "checker has lost its teeth", file=sys.stderr)
    if failures:
        print(f"modelcheck: {failures} expectation(s) failed",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
