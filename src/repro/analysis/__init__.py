"""Static analysis for the HAM runtime: protocol linter + model checker.

Two engines (see ``docs/static-analysis.md``):

* :mod:`repro.analysis.hamlint` — AST-based protocol linter over every
  ``@handler`` / ``register(...)`` site.  ``python -m repro.analysis.hamlint
  src/``.
* :mod:`repro.analysis.modelcheck` — explicit-state exhaustive-interleaving
  checker for the torn-counter and doorbell protocols.
  ``python -m repro.analysis.modelcheck [--quick]``.

The HAM paper leans on the C++ type system to make handler dispatch safe at
compile time (§4); this package is the Python runtime's equivalent static
backstop, encoding the invariant classes behind every protocol bug this
codebase has shipped (PR 1 torn counters, PR 2 same-source divergence,
PR 5 undeclared-mutation replica divergence, PR 7 lost-wakeup races).
"""
