"""HAM001 — buffer-write declarations must be true of the code.

A handler registered ``read_only=True`` may be routed at (and have its
buffer pointers retargeted to) ANY replica of its buffers.  If such a
handler writes through a ``deref``'d pointer it updates one replica and
silently diverges the others — the exact bug class closed dynamically in
PR 5 by gating replica serving on the declaration.  This rule closes it
*statically*: the declaration must be true of the code.

The annotation space has three points and the rule polices two edges:

* ``read_only=True`` + a store through buffer memory — the PR 5 replica
  divergence; the finding demands the store be removed (or the
  declaration dropped);
* *no* declaration (neither ``read_only`` nor ``mutates``) + a store —
  the write lands on the primary but its replicas are never invalidated,
  so a replica-served read observes stale bytes; the finding names the
  fix: **declare** ``mutates=True`` so the scheduler routes the call at
  the primary and commits/invalidates on completion (the Active Access
  write path — dataplane module docs);
* ``mutates=True`` + a store — declared and coherent: **no finding**.

Taint model: every value produced by ``deref(...)`` — and every view
derived from one by plain assignment, subscripting/slicing, attribute
chains (``.T``), or view-returning methods (``reshape``/``ravel``/
``view``/``transpose``) — is buffer memory.  A store through tainted
memory (subscript/attribute assignment, augmented assignment, a known
in-place method, an ``out=`` kwarg, ``np.copyto``) is a violation; so is
alias-escaping a tainted view into module-global state (the write then
merely happens later, off-site).  Reading, reducing (``.sum()``), and
returning tainted values are fine — the wire layer copies results.
"""

from __future__ import annotations

import ast

from repro.analysis.rules import Finding, LintContext, rule

#: ndarray methods that mutate the receiver in place
_INPLACE_METHODS = {
    "fill", "sort", "put", "resize", "setfield", "itemset", "partition",
    "byteswap", "setflags",
}
#: methods returning a view of (i.e. aliasing) the receiver
_VIEW_METHODS = {"reshape", "ravel", "view", "transpose", "swapaxes",
                 "squeeze", "diagonal"}
#: free functions whose FIRST argument is written in place
_INPLACE_FUNCS = {"copyto"}
#: container methods that capture a reference to their argument
_CAPTURE_METHODS = {"append", "add", "insert", "extend", "setdefault",
                    "update"}


def _root_name(node: ast.expr) -> str | None:
    """Innermost Name of a Subscript/Attribute chain."""
    while isinstance(node, (ast.Subscript, ast.Attribute)):
        node = node.value
    if isinstance(node, ast.Name):
        return node.id
    return None


class _PurityChecker:
    def __init__(self, func_def, module_globals: set, path: str,
                 wire_name: str, declared_read_only: bool = True):
        self.func = func_def
        self.module_globals = set(module_globals)
        self.path = path
        self.wire_name = wire_name
        #: True: the site says read_only=True (PR 5 divergence message);
        #: False: the site declares nothing (undeclared-mutation message
        #: naming the mutates=True fix — module docs)
        self.declared_read_only = declared_read_only
        self.tainted: set[str] = set()
        self.declared_global: set[str] = set()
        self.findings: list[Finding] = []

    def run(self) -> list[Finding]:
        # two passes: the first only propagates taint (assign chains are
        # short, one pass reaches fixpoint for straight-line code); the
        # second reports, so a store textually above the assignment that
        # tainted its target still fires
        for report in (False, True):
            self.findings = []
            for node in self.func.body:
                self._stmt(node, report)
        return self.findings

    # -- taint -------------------------------------------------------------

    def _is_tainted(self, node: ast.expr) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, (ast.Subscript, ast.Attribute)):
            # a slice/attr of buffer memory aliases it, except method refs
            if isinstance(node, ast.Attribute) and \
                    node.attr in _INPLACE_METHODS | _VIEW_METHODS:
                return self._is_tainted(node.value)
            return self._is_tainted(node.value)
        if isinstance(node, ast.Call):
            func = node.func
            if isinstance(func, ast.Name) and func.id == "deref":
                return True
            if isinstance(func, ast.Attribute) and \
                    func.attr in _VIEW_METHODS:
                return self._is_tainted(func.value)
        if isinstance(node, ast.Starred):
            return self._is_tainted(node.value)
        return False

    # -- statement walk ----------------------------------------------------

    def _stmt(self, node: ast.stmt, report: bool) -> None:
        if isinstance(node, ast.Global):
            self.declared_global.update(node.names)
        elif isinstance(node, ast.Assign):
            self._assign(node, report)
        elif isinstance(node, ast.AugAssign):
            self._aug_assign(node, report)
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            if isinstance(node.target, ast.Name) and \
                    self._is_tainted(node.value):
                self.tainted.add(node.target.id)
        elif isinstance(node, ast.Expr):
            self._expr_stmt(node.value, report)
        elif isinstance(node, (ast.If, ast.While, ast.For, ast.With,
                               ast.Try)):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, ast.stmt):
                    self._stmt(child, report)
                elif isinstance(child, (ast.ExceptHandler, ast.withitem)):
                    for sub in ast.iter_child_nodes(child):
                        if isinstance(sub, ast.stmt):
                            self._stmt(sub, report)
            if isinstance(node, ast.For) and \
                    isinstance(node.target, ast.Name) and \
                    self._is_tainted(node.iter):
                # iterating rows of buffer memory yields views
                self.tainted.add(node.target.id)
        # Return / Raise / Pass / nested defs: nothing to do (returning a
        # view is legal — the wire layer copies)

    def _assign(self, node: ast.Assign, report: bool) -> None:
        value_tainted = self._is_tainted(node.value)
        for target in node.targets:
            if isinstance(target, ast.Name):
                is_global = (target.id in self.declared_global
                             or target.id in self.module_globals)
                if value_tainted and is_global and report:
                    self._report(
                        node,
                        f"stores a buffer view into module global "
                        f"'{target.id}' (alias escape)",
                    )
                if value_tainted:
                    self.tainted.add(target.id)
                else:
                    self.tainted.discard(target.id)  # rebound to clean value
            elif isinstance(target, (ast.Subscript, ast.Attribute)):
                root = _root_name(target)
                if root is None:
                    continue
                if root in self.tainted:
                    if report:
                        self._report(
                            node,
                            f"writes through buffer-derived '{root}' "
                            f"(offending store at line {node.lineno})",
                        )
                elif value_tainted and report and (
                    root in self.module_globals
                    or root in self.declared_global
                ):
                    self._report(
                        node,
                        f"stores a buffer view into module global "
                        f"'{root}' (alias escape)",
                    )
            elif isinstance(target, ast.Tuple) and value_tainted:
                for el in target.elts:
                    if isinstance(el, ast.Name):
                        self.tainted.add(el.id)

    def _aug_assign(self, node: ast.AugAssign, report: bool) -> None:
        target = node.target
        root = _root_name(target) if isinstance(
            target, (ast.Subscript, ast.Attribute)
        ) else (target.id if isinstance(target, ast.Name) else None)
        if root is not None and root in self.tainted and report:
            self._report(
                node,
                f"augmented assignment mutates buffer-derived '{root}' in "
                f"place (offending store at line {node.lineno})",
            )

    def _expr_stmt(self, node: ast.expr, report: bool) -> None:
        if not isinstance(node, ast.Call):
            return
        func = node.func
        if isinstance(func, ast.Attribute):
            recv_root = _root_name(func.value)
            if func.attr in _INPLACE_METHODS and recv_root in self.tainted:
                if report:
                    self._report(
                        node,
                        f"in-place method '.{func.attr}()' mutates "
                        f"buffer-derived '{recv_root}'",
                    )
            if func.attr in _CAPTURE_METHODS and \
                    recv_root is not None and \
                    recv_root in self.module_globals and \
                    any(self._is_tainted(a) for a in node.args):
                if report:
                    self._report(
                        node,
                        f"captures a buffer view into module global "
                        f"'{recv_root}' (alias escape)",
                    )
            if func.attr in _INPLACE_FUNCS and node.args and \
                    self._is_tainted(node.args[0]) and report:
                self._report(
                    node,
                    f"'{func.attr}' writes into its first argument, which "
                    "is buffer-derived",
                )
        elif isinstance(func, ast.Name) and func.id in _INPLACE_FUNCS and \
                node.args and self._is_tainted(node.args[0]) and report:
            self._report(
                node,
                f"'{func.id}' writes into its first argument, which is "
                "buffer-derived",
            )
        for kw in node.keywords:
            if kw.arg == "out" and self._is_tainted(kw.value) and report:
                self._report(node, "out= targets a buffer-derived array")

    def _report(self, node: ast.AST, detail: str) -> None:
        if self.declared_read_only:
            message = (
                f"handler {self.wire_name!r} is declared read_only=True "
                f"but {detail}; a replica-served call would diverge the "
                "other replicas (PR 5 bug class)"
            )
        else:
            message = (
                f"handler {self.wire_name!r} {detail} but declares "
                "neither read_only=True nor mutates=True; declare "
                "mutates=True so the scheduler routes the call at the "
                "buffer's primary and invalidates replicas when it "
                "completes — undeclared, replica holders keep serving "
                "the overwritten bytes (docs/failure-model.md, 'Write "
                "visibility and convergence')"
            )
        self.findings.append(Finding(
            rule="HAM001",
            path=self.path,
            line=node.lineno,
            col=node.col_offset,
            message=message,
        ))


@rule(
    "HAM001",
    title="buffer writes must match the handler's declaration: "
          "read_only=True handlers must not mutate or alias-escape "
          "BufferPtr-derived memory, and a handler that does must "
          "declare mutates=True",
    historical="PR 5: an undeclared-mutation handler served from a replica "
               "silently diverged the other replicas",
)
def check(ctx: LintContext) -> list[Finding]:
    findings: list[Finding] = []
    for site in ctx.sites:
        if site.func_def is None or site.mutates is True:
            # mutates=True declares the store — in-place writes are the
            # point of the annotation (Active Access), nothing to police
            continue
        checker = _PurityChecker(
            site.func_def,
            site.module.toplevel_assigns,
            site.module.path,
            site.wire_name or site.fn_name or "<anonymous>",
            declared_read_only=site.read_only is True,
        )
        findings.extend(checker.run())
    return findings
