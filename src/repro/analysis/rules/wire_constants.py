"""HAM004 — wire-constant soundness.

The u16 flags field and the u64 msg_id space are tiny shared namespaces
spanning every process in a fleet; a colliding ``FLAG_*`` bit or a replay
sentinel drifting into live msg_id space is a cross-version wire-corruption
bug with no local symptom.  ``repro.core.flags`` is the single declared
source of truth (with import-time assertions); this rule enforces that it
stays the *only* source:

* any literal assignment to a ``FLAG_*`` / ``MSG_ID_*`` name outside the
  canonical module is flagged (re-exports via ``import`` are fine —
  imports cannot drift from the table);
* the canonical table itself is re-verified here (distinct bits, bits
  inside the flags field, sentinels at/above the reserved floor) so a CI
  run reports a diagnostic with file:line instead of an ImportError
  traceback.
"""

from __future__ import annotations

import ast
import re

from repro.analysis.rules import Finding, LintContext, rule

_CANONICAL_SUFFIX = "repro/core/flags.py"
_NAME_RE = re.compile(r"^(FLAG_[A-Z0-9_]+|MSG_ID_[A-Z0-9_]+|FLUSH)$")


def _fold_int(node: ast.expr):
    """Constant-fold int literals and the shift/or/add arithmetic wire
    constants are written in; None when not a literal expression."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    if isinstance(node, ast.BinOp):
        left, right = _fold_int(node.left), _fold_int(node.right)
        if left is None or right is None:
            return None
        if isinstance(node.op, ast.LShift):
            return left << right
        if isinstance(node.op, ast.BitOr):
            return left | right
        if isinstance(node.op, ast.Add):
            return left + right
        if isinstance(node.op, ast.Sub):
            return left - right
        if isinstance(node.op, ast.Mult):
            return left * right
    return None


def _literal_wire_assignments(tree: ast.Module):
    """Yield ``(name, value, node)`` for FLAG_*/sentinel-name assignments
    with literal integer values, anywhere in the module (class bodies
    included — ``ReplayCache.FLUSH = 1 << 61`` was exactly the pattern)."""
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign):
            continue
        value = _fold_int(node.value)
        if value is None:
            continue
        for target in node.targets:
            if isinstance(target, ast.Name) and _NAME_RE.match(target.id):
                yield target.id, value, node


@rule(
    "HAM004",
    title="wire constants (flag bits, msg_id sentinels) live only in the "
          "centralized registry and must not collide",
    historical="FLAG_SEG_SRC and the replay FLUSH sentinel were each added "
               "by grepping message.py for the highest bit in use — one "
               "missed module and two fleet versions disagree on a bit",
)
def check(ctx: LintContext) -> list[Finding]:
    findings: list[Finding] = []

    # the authoritative table — import the real module so the rule can
    # never drift from what the runtime actually uses
    from repro.core import flags as canonical

    canonical_bits = dict(canonical.FLAG_BITS)
    bit_owner = {bit: name for name, bit in canonical_bits.items()}

    for mod in ctx.modules:
        is_canonical = mod.path.replace("\\", "/").endswith(_CANONICAL_SUFFIX)
        for name, value, node in _literal_wire_assignments(mod.tree):
            if is_canonical:
                continue
            detail = ""
            if name.startswith("FLAG_"):
                bit = value.bit_length() - 1
                if value > 0 and value == (1 << bit) and bit in bit_owner:
                    detail = (f" — and its bit {bit} collides with "
                              f"{bit_owner[bit]}")
                findings.append(Finding(
                    rule="HAM004", path=mod.path, line=node.lineno,
                    col=node.col_offset,
                    message=(
                        f"flag constant '{name}' defined outside the "
                        "centralized registry (repro.core.flags); declare "
                        f"the bit there and import it{detail}"
                    ),
                ))
            else:
                in_reserved = (canonical.MSG_ID_RESERVED_FLOOR <= value
                               < (1 << canonical.MSG_ID_FIELD_WIDTH))
                detail = ("" if in_reserved else
                          " — and its value is INSIDE live msg_id space "
                          f"(reserved floor is "
                          f"{canonical.MSG_ID_RESERVED_FLOOR:#x})")
                findings.append(Finding(
                    rule="HAM004", path=mod.path, line=node.lineno,
                    col=node.col_offset,
                    message=(
                        f"msg_id sentinel '{name}' defined outside the "
                        "centralized registry (repro.core.flags); declare "
                        f"it there and import it{detail}"
                    ),
                ))

    # re-verify the canonical table itself, diagnosably
    canonical_path = next(
        (m.path for m in ctx.modules
         if m.path.replace("\\", "/").endswith(_CANONICAL_SUFFIX)),
        _CANONICAL_SUFFIX,
    )
    seen: dict[int, str] = {}
    for name, bit in canonical_bits.items():
        if bit in seen:
            findings.append(Finding(
                rule="HAM004", path=canonical_path, line=1, col=0,
                message=f"colliding flag bits: {name} and {seen[bit]} both "
                        f"claim bit {bit}",
            ))
        seen[bit] = name
        if not 0 <= bit < canonical.FLAGS_FIELD_WIDTH:
            findings.append(Finding(
                rule="HAM004", path=canonical_path, line=1, col=0,
                message=f"{name} bit {bit} outside the "
                        f"u{canonical.FLAGS_FIELD_WIDTH} flags field",
            ))
    for name, value in canonical.MSG_ID_SENTINELS.items():
        if not (canonical.MSG_ID_RESERVED_FLOOR <= value
                < (1 << canonical.MSG_ID_FIELD_WIDTH)):
            findings.append(Finding(
                rule="HAM004", path=canonical_path, line=1, col=0,
                message=f"msg_id sentinel {name} = {value:#x} is inside "
                        "live msg_id space",
            ))
    return findings
