"""HAM002 — static-spec / signature coherence.

A static spec tuple IS the wire layout: the sender packs ``len(arg_specs)``
leaves and the receiver applies them positionally to the handler.  An arity
mismatch means the payload and the call disagree — caught today only when
``init()`` compiles the plan or, worse, when the dispatch explodes on a
live frame.  This rule checks at lint time that

* a literal ``arg_specs=(...)`` / ``args=(...)`` tuple has exactly as many
  leaves as the function has positional parameters (``*args`` signatures
  are exempt), and
* every ``ScalarSpec(...)`` leaf names a wire-plan-compilable kind — the
  fused-scalar struct only speaks ``i8`` / ``f8`` / ``b1``
  (``repro.core.wireplan``).

The call-time twin lives in ``HandlerRegistry.register`` (the dynamic path
and this static pass can never disagree silently).
"""

from __future__ import annotations

import ast

from repro.analysis.rules import Finding, LintContext, rule

_SCALAR_KINDS = {"i8", "f8", "b1"}


def _positional_arity(func_def) -> tuple[int, bool]:
    """(positional parameter count, has *args)."""
    a = func_def.args
    return len(a.posonlyargs) + len(a.args), a.vararg is not None


def _scalar_kind_findings(tup: ast.expr, path: str, wire_name: str):
    if not isinstance(tup, (ast.Tuple, ast.List)):
        return
    for leaf in tup.elts:
        if not (isinstance(leaf, ast.Call) and
                isinstance(leaf.func, ast.Name) and
                leaf.func.id == "ScalarSpec"):
            continue
        kind = None
        if leaf.args and isinstance(leaf.args[0], ast.Constant):
            kind = leaf.args[0].value
        for kw in leaf.keywords:
            if kw.arg == "kind" and isinstance(kw.value, ast.Constant):
                kind = kw.value.value
        if isinstance(kind, str) and kind not in _SCALAR_KINDS:
            yield Finding(
                rule="HAM002",
                path=path,
                line=leaf.lineno,
                col=leaf.col_offset,
                message=(
                    f"handler {wire_name!r}: ScalarSpec kind {kind!r} is not "
                    f"wire-plan compilable (known kinds: "
                    f"{', '.join(sorted(_SCALAR_KINDS))})"
                ),
            )


@rule(
    "HAM002",
    title="static spec tuples must match the handler signature and be "
          "wire-plan compilable",
    historical="arity drift between a spec tuple and its handler surfaces "
               "as a SpecMismatchError on a live frame, far from the "
               "registration that caused it",
)
def check(ctx: LintContext) -> list[Finding]:
    findings: list[Finding] = []
    for site in ctx.sites:
        wire_name = site.wire_name or site.fn_name or "<anonymous>"
        if site.specs_node is not None and \
                isinstance(site.specs_node, (ast.Tuple, ast.List)) and \
                site.func_def is not None:
            n_leaves = len(site.specs_node.elts)
            n_params, has_varargs = _positional_arity(site.func_def)
            if not has_varargs and n_leaves != n_params:
                findings.append(Finding(
                    rule="HAM002",
                    path=site.module.path,
                    line=site.line,
                    col=site.col,
                    message=(
                        f"handler {wire_name!r}: spec tuple declares "
                        f"{n_leaves} leaves but "
                        f"'{site.func_def.name}' takes {n_params} positional "
                        "parameters — payload and call disagree"
                    ),
                ))
        for node in (site.specs_node, site.result_specs_node):
            if node is not None:
                findings.extend(
                    _scalar_kind_findings(node, site.module.path, wire_name)
                )
    return findings
