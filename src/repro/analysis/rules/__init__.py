"""Pluggable rule registry for ``hamlint``.

A rule is a function ``check(ctx: LintContext) -> list[Finding]`` declared
with the :func:`rule` decorator.  Rules see the *whole* parsed tree (every
module, every extracted registration site), so cross-module invariants
(same-source coverage, wire-constant collisions) are first-class.

To add a rule: create a module in this package, decorate a function with
``@rule("HAM0xx", title=..., historical=...)``, and import the module at
the bottom of this ``__init__`` (the import *is* the registration — the
same static-initialisation idiom as the handler registry itself).
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Callable

__all__ = [
    "Finding",
    "LintContext",
    "ModuleInfo",
    "RegistrationSite",
    "Rule",
    "all_rules",
    "rule",
]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic: ``path:line:col: RULE message``."""

    rule: str
    path: str
    line: int
    col: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


@dataclasses.dataclass
class ModuleInfo:
    """One parsed source module plus the lookup tables rules need."""

    path: str
    modname: str                 # dotted name ('' when not under a package root)
    tree: ast.Module
    #: local name -> source module, for names bound by import statements
    imports: dict[str, str] = dataclasses.field(default_factory=dict)
    #: module-level function defs by name
    toplevel_defs: dict[str, ast.AST] = dataclasses.field(default_factory=dict)
    #: names assigned at module level (module-global state)
    toplevel_assigns: set[str] = dataclasses.field(default_factory=set)
    #: local functions executed at import time (called at module level,
    #: transitively within this module)
    import_time_funcs: set[str] = dataclasses.field(default_factory=set)


@dataclasses.dataclass
class RegistrationSite:
    """One ``@handler`` / ``register(...)`` occurrence (loop sites are
    unrolled: one site per literal tuple element)."""

    module: ModuleInfo
    line: int
    col: int
    via: str                     # 'decorator' | 'call' | 'loop'
    wire_name: str | None        # literal name= if present
    fn_name: str | None          # identifier of the registered function
    func_def: ast.AST | None     # same-module def, when resolvable
    read_only: bool | None       # literal read_only= value; None if absent
    mutates: bool | None         # literal mutates= value; None if absent
    specs_node: ast.expr | None  # arg_specs= / args= expression
    specs_kw: str | None         # which keyword carried the specs
    result_specs_node: ast.expr | None
    import_time: bool            # executes when the module is imported
    receiver: str | None         # receiver identifier of a .register call
    fn_is_param: bool            # registered fn is a parameter of the
                                 # enclosing function (dynamic path)


@dataclasses.dataclass
class LintContext:
    modules: list[ModuleInfo]
    sites: list[RegistrationSite]


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    title: str
    historical: str              # the shipped bug this rule would have caught
    check: Callable[[LintContext], list[Finding]]


_RULES: dict[str, Rule] = {}


def rule(rule_id: str, *, title: str, historical: str = ""):
    def deco(fn):
        if rule_id in _RULES:
            raise ValueError(f"duplicate rule id {rule_id}")
        _RULES[rule_id] = Rule(rule_id, title, historical, fn)
        return fn
    return deco


def all_rules() -> dict[str, Rule]:
    return dict(_RULES)


# importing the submodules registers the rules (static initialisation)
from repro.analysis.rules import (  # noqa: E402,F401
    read_only_purity,
    same_source,
    spec_coherence,
    wire_constants,
)
