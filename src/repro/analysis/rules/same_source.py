"""HAM003 — same-source coverage.

Workers derive their import list from the *defining module* of every
registered handler (``registered_setup_modules``: ``fn.__module__`` over
the pending records).  The invariant that makes this correct: importing a
handler's defining module must re-run its registration.  Two static
violations break it — both are the PR 2 divergence class, where host and
worker silently derive different key maps:

* **cross-module registration at import time** — module A registers, at
  import, a function *defined in* module B.  The worker imports B (that is
  where ``fn.__module__`` points), A's registration statement never runs,
  the handler is missing, and the key-map digests diverge at attach.

* **registration not executed at import** — module M defines handlers and
  a ``register_*`` helper, but nothing calls the helper at module level.
  A worker importing M gets the defs and not the registrations.  (Helpers
  that register *caller-supplied* functions — the ``l2f`` / ``offloaded``
  dynamic paths — are exempt: there is no module-level def to cover.)

Both fixes are one line: register in the defining module, or add the
guarded module-level call (see ``offload/dataplane.py`` for the idiom).
"""

from __future__ import annotations

from repro.analysis.rules import Finding, LintContext, rule


@rule(
    "HAM003",
    title="every registering module must re-register on import "
          "(registered_setup_modules coverage)",
    historical="PR 2: a registration living outside the handler's defining "
               "module made workers derive a different key map than the "
               "host (digest mismatch at attach)",
)
def check(ctx: LintContext) -> list[Finding]:
    findings: list[Finding] = []
    for site in ctx.sites:
        # dynamic paths register functions they were handed — the caller
        # owns coverage; nothing to check statically
        if site.fn_is_param or site.receiver in ("self", "cls"):
            continue
        if site.import_time:
            if site.fn_name is not None and site.func_def is None and \
                    site.fn_name in site.module.imports:
                origin = site.module.imports[site.fn_name]
                findings.append(Finding(
                    rule="HAM003",
                    path=site.module.path,
                    line=site.line,
                    col=site.col,
                    message=(
                        f"import-time registration of '{site.fn_name}', "
                        f"which is defined in '{origin}': workers import a "
                        "handler's *defining* module "
                        "(registered_setup_modules), so this registration "
                        "will not run there and key maps diverge (PR 2 "
                        "class) — register it from "
                        f"'{origin}' instead"
                    ),
                ))
        elif site.func_def is not None:
            findings.append(Finding(
                rule="HAM003",
                path=site.module.path,
                line=site.line,
                col=site.col,
                message=(
                    f"registration of "
                    f"'{site.wire_name or site.fn_name}' never executes at "
                    "import time: a worker importing "
                    f"'{site.module.modname or site.module.path}' re-runs "
                    "module-level statements only, so it would derive a key "
                    "map missing this handler (PR 2 class) — call the "
                    "registering function at module level, guarded with "
                    "RegistrySealedError (see offload/dataplane.py)"
                ),
            ))
    return findings
