"""TCP/IP fabric — the paper's TCP backend class, length-prefixed frames.

Connections are established lazily per (src, dst) pair; each endpoint runs a
listener plus one reader thread per inbound connection feeding a single
inbox.  Slowest backend, but the only one that crosses machine boundaries —
used in tests to prove the wire protocol is process-image independent
(heterogeneous binaries: a worker launched as a fresh interpreter).
"""

from __future__ import annotations

import queue
import socket
import struct
import threading

from repro.comm.base import CommBackend, Fabric
from repro.core.errors import CommError

_LEN = struct.Struct("<Q")


def _recv_exact(sock: socket.socket, n: int) -> bytes | None:
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        k = sock.recv_into(view[got:], n - got)
        if k == 0:
            return None
        got += k
    return bytes(buf)


class SocketEndpoint(CommBackend):
    def __init__(
        self,
        node_id: int,
        num_nodes: int,
        base_port: int,
        host: str = "127.0.0.1",
    ):
        self.node_id = node_id
        self.num_nodes = num_nodes
        self._host = host
        self._base_port = base_port
        self._inbox: queue.SimpleQueue = queue.SimpleQueue()
        self._out: dict[int, socket.socket] = {}
        self._out_lock = threading.Lock()
        self._closing = threading.Event()

        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, base_port + node_id))
        self._listener.listen(num_nodes)
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name=f"ham-sock-accept-{node_id}", daemon=True
        )
        self._accept_thread.start()

    def _accept_loop(self) -> None:
        while not self._closing.is_set():
            try:
                conn, _ = self._listener.accept()
            except OSError:
                return
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            threading.Thread(
                target=self._read_loop, args=(conn,), daemon=True
            ).start()

    def _read_loop(self, conn: socket.socket) -> None:
        try:
            while True:
                hdr = _recv_exact(conn, _LEN.size)
                if hdr is None:
                    return
                (n,) = _LEN.unpack(hdr)
                frame = _recv_exact(conn, n)
                if frame is None:
                    return
                self._inbox.put(frame)
        except OSError:
            return

    def _connect(self, dst: int) -> socket.socket:
        with self._out_lock:
            sock = self._out.get(dst)
            if sock is not None:
                return sock
            sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            # the peer's listener may not be up yet: bounded retry
            import time

            for attempt in range(200):
                try:
                    sock.connect((self._host, self._base_port + dst))
                    break
                except ConnectionRefusedError:
                    time.sleep(0.02)
            else:
                raise CommError(f"cannot connect to node {dst}")
            self._out[dst] = sock
            return sock

    def send(self, dst: int, frame) -> None:
        self._check_dst(dst)
        sock = self._connect(dst)
        data = bytes(frame)
        try:
            sock.sendall(_LEN.pack(len(data)) + data)
        except OSError as e:
            raise CommError(f"send to node {dst} failed: {e}") from e

    def recv(self, timeout: float | None = None) -> bytes | None:
        try:
            return self._inbox.get(timeout=timeout)
        except queue.Empty:
            return None

    def close(self) -> None:
        self._closing.set()
        try:
            self._listener.close()
        except OSError:
            pass
        with self._out_lock:
            for sock in self._out.values():
                try:
                    sock.close()
                except OSError:
                    pass


class SocketFabric(Fabric):
    """Same-host fabric over loopback TCP (endpoints may live anywhere that
    can reach ``host:base_port+i``)."""

    def __init__(self, num_nodes: int, base_port: int = 0, host: str = "127.0.0.1"):
        self.num_nodes = num_nodes
        self.host = host
        if base_port == 0:
            # pick a free contiguous region by binding a probe socket
            probe = socket.socket()
            probe.bind((host, 0))
            base_port = probe.getsockname()[1] + 1000
            probe.close()
        self.base_port = base_port
        self._endpoints: dict[int, SocketEndpoint] = {}

    def endpoint(self, node_id: int) -> SocketEndpoint:
        if node_id not in self._endpoints:
            self._endpoints[node_id] = SocketEndpoint(
                node_id, self.num_nodes, self.base_port, self.host
            )
        return self._endpoints[node_id]

    def close(self) -> None:
        for ep in self._endpoints.values():
            ep.close()
