"""TCP/IP fabric — the paper's TCP backend class, length-prefixed frames.

Connections are established lazily per (src, dst) pair; each endpoint runs a
listener plus one reader thread per inbound connection feeding a single
inbox.  Slowest backend, but the only one that crosses machine boundaries —
used in tests to prove the wire protocol is process-image independent
(heterogeneous binaries: a worker launched as a fresh interpreter).

Hot path:

* sends are *gathered* — ``sendmsg`` writes ``len || frame`` (and, for
  ``send_many``, a whole batch of them) in one syscall with no
  concatenation copy;
* the reader is *buffered* — one big ``recv_into`` per syscall, then every
  complete frame in the buffer is sliced out, so under load one syscall
  yields many frames; frames larger than the buffer are streamed straight
  into their own allocation (no repeated buffer growth).
"""

from __future__ import annotations

import queue
import socket
import struct
import threading

from repro.comm.base import CommBackend, Fabric, as_byte_view as _as_view
from repro.core.errors import CommError

_LEN = struct.Struct("<Q")
_RECV_BUF = 1 << 18  # reader syscall granularity
_IOV_BATCH = 512     # conservative cap under Linux IOV_MAX (1024)


def _recv_exact_into(sock: socket.socket, view: memoryview, got: int = 0) -> bool:
    n = view.nbytes
    while got < n:
        k = sock.recv_into(view[got:], n - got)
        if k == 0:
            return False
        got += k
    return True


def _sendv(sock: socket.socket, buffers: list) -> None:
    """Gathered send of all ``buffers``, handling partial writes."""
    views = [_as_view(b) for b in buffers]
    while views:
        sent = sock.sendmsg(views[:_IOV_BATCH])
        while views and sent >= views[0].nbytes:
            sent -= views[0].nbytes
            views.pop(0)
        if sent and views:
            views[0] = views[0][sent:]


class SocketEndpoint(CommBackend):
    def __init__(
        self,
        node_id: int,
        num_nodes: int,
        base_port: int,
        host: str = "127.0.0.1",
    ):
        self.node_id = node_id
        self.num_nodes = num_nodes
        self._host = host
        self._base_port = base_port
        self._removed: set[int] = set()  # retired peers: fail fast, never dial
        self._inbox: queue.SimpleQueue = queue.SimpleQueue()
        self._out: dict[int, socket.socket] = {}
        self._out_lock = threading.Lock()
        self._send_locks: dict[int, threading.Lock] = {}
        self._closing = threading.Event()

        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, base_port + node_id))
        self._listener.listen(num_nodes)
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name=f"ham-sock-accept-{node_id}", daemon=True
        )
        self._accept_thread.start()

    def _accept_loop(self) -> None:
        while not self._closing.is_set():
            try:
                conn, _ = self._listener.accept()
            except OSError:
                return
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            threading.Thread(
                target=self._read_loop, args=(conn,), daemon=True
            ).start()

    def _read_loop(self, conn: socket.socket) -> None:
        """Buffered reader: one recv syscall can yield many frames."""
        pending = bytearray()
        scratch = memoryview(bytearray(_RECV_BUF))
        try:
            while True:
                k = conn.recv_into(scratch)
                if k == 0:
                    return
                pending += scratch[:k]
                # slice out every complete frame already in the buffer
                mv = memoryview(pending)
                total = len(pending)
                off = 0
                while total - off >= _LEN.size:
                    (n,) = _LEN.unpack_from(mv, off)
                    if total - off - _LEN.size < n:
                        break
                    self._inbox.put(bytes(mv[off + 8 : off + 8 + n]))
                    off += 8 + n
                mv.release()
                if off:
                    del pending[:off]
                # oversized frame: stream the remainder straight into its
                # final buffer instead of growing `pending` chunk by chunk
                if len(pending) >= _LEN.size:
                    (n,) = _LEN.unpack_from(pending, 0)
                    if n > _RECV_BUF:
                        frame = bytearray(n)
                        have = len(pending) - 8
                        frame[:have] = memoryview(pending)[8:]
                        del pending[:]
                        if not _recv_exact_into(conn, memoryview(frame), have):
                            return
                        self._inbox.put(frame)
        except OSError:
            return

    def _connect(self, dst: int) -> socket.socket:
        with self._out_lock:
            sock = self._out.get(dst)
            if sock is not None:
                return sock
            # the peer's listener may not be up yet (a fresh-interpreter
            # worker can take seconds to import): time-bounded retry, and a
            # mid-handshake abort/reset gets a fresh socket rather than
            # escaping the loop
            import time

            deadline = time.monotonic() + 15.0
            while True:
                sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                try:
                    sock.connect((self._host, self._base_port + dst))
                    break
                except (ConnectionRefusedError, ConnectionAbortedError,
                        ConnectionResetError, TimeoutError):
                    sock.close()
                    if time.monotonic() > deadline:
                        raise CommError(f"cannot connect to node {dst}") from None
                    time.sleep(0.02)
            self._out[dst] = sock
            self._send_locks[dst] = threading.Lock()
            return sock

    def send(self, dst: int, frame) -> None:
        self._check_dst(dst)
        sock = self._connect(dst)
        mv = _as_view(frame)
        try:
            with self._send_locks[dst]:
                _sendv(sock, [_LEN.pack(mv.nbytes), mv])
        except OSError as e:
            raise CommError(f"send to node {dst} failed: {e}") from e

    def send_many(self, dst: int, frames) -> None:
        """One gathered syscall per ~256 frames: ``len||frame`` iovec pairs."""
        self._check_dst(dst)
        sock = self._connect(dst)
        iov: list = []
        for frame in frames:
            mv = _as_view(frame)
            iov.append(_LEN.pack(mv.nbytes))
            iov.append(mv)
        try:
            with self._send_locks[dst]:
                _sendv(sock, iov)
        except OSError as e:
            raise CommError(f"send to node {dst} failed: {e}") from e

    def reset_peer(self, dst: int) -> None:
        """Forget the cached outbound connection to ``dst``: the next send
        redials, reaching the replacement process listening on dst's port."""
        with self._out_lock:
            sock = self._out.pop(dst, None)
            self._send_locks.pop(dst, None)
        if sock is not None:
            try:
                sock.close()
            except OSError:
                pass

    def _check_dst(self, dst: int) -> None:
        if dst in self._removed:
            from repro.core.errors import CommError as _CE

            raise _CE(f"destination {dst} was removed from the fabric")
        super()._check_dst(dst)

    def attach_peer(self, node_id: int) -> None:
        """Widen the valid-destination range (connections are dialled lazily
        by port, so a new peer needs no resources until the first send)."""
        self._removed.discard(node_id)
        self.num_nodes = max(self.num_nodes, node_id + 1)

    def detach_peer(self, node_id: int) -> None:
        """Retire a peer: close any cached connection and refuse later sends
        toward the id (ids are never reused)."""
        self._removed.add(node_id)
        self.reset_peer(node_id)

    def recv(self, timeout: float | None = None) -> bytes | None:
        try:
            return self._inbox.get(timeout=timeout)
        except queue.Empty:
            return None

    def recv_many(self, max_frames: int = 64, timeout: float | None = None) -> list:
        """Drain up to ``max_frames`` from the inbox (frames are owned)."""
        try:
            out = [self._inbox.get(timeout=timeout)]
        except queue.Empty:
            return []
        while len(out) < max_frames:
            try:
                out.append(self._inbox.get_nowait())
            except queue.Empty:
                break
        return out

    def pending_frames(self) -> int:
        return self._inbox.qsize()

    def close(self) -> None:
        self._closing.set()
        try:
            self._listener.close()
        except OSError:
            pass
        with self._out_lock:
            for sock in self._out.values():
                try:
                    sock.close()
                except OSError:
                    pass


class SocketFabric(Fabric):
    """Same-host fabric over loopback TCP (endpoints may live anywhere that
    can reach ``host:base_port+i``)."""

    #: ports reserved past the initial node count so add_node stays inside
    #: the probed free region
    GROW_HEADROOM = 64

    def __init__(self, num_nodes: int, base_port: int = 0, host: str = "127.0.0.1"):
        self.num_nodes = num_nodes
        self.host = host
        while base_port == 0:
            # pick a free contiguous region by binding a probe socket;
            # re-probe if the region would run past the port range
            probe = socket.socket()
            probe.bind((host, 0))
            candidate = probe.getsockname()[1] + 1000
            probe.close()
            if candidate + num_nodes + self.GROW_HEADROOM <= 65535:
                base_port = candidate
        self.base_port = base_port
        self._endpoints: dict[int, SocketEndpoint] = {}
        self._nodes: set[int] = set(range(num_nodes))
        self._next_id = num_nodes

    def endpoint(self, node_id: int) -> SocketEndpoint:
        if node_id not in self._endpoints:
            self._endpoints[node_id] = SocketEndpoint(
                node_id, self.num_nodes, self.base_port, self.host
            )
        return self._endpoints[node_id]

    def nodes(self) -> list[int]:
        return sorted(self._nodes)

    def add_node(self) -> int:
        node_id = self._next_id
        if self.base_port + node_id > 65535:
            raise CommError(
                f"cannot add node {node_id}: port {self.base_port + node_id} "
                "out of range"
            )
        self._next_id += 1
        self._nodes.add(node_id)
        self.num_nodes = max(self.num_nodes, node_id + 1)
        return node_id

    def remove_node(self, node_id: int) -> None:
        self._nodes.discard(node_id)
        ep = self._endpoints.pop(node_id, None)
        if ep is not None:
            ep.close()

    def close(self) -> None:
        for ep in self._endpoints.values():
            ep.close()
