"""Communication backends for HAM (paper Fig. 1: MPI/TCP/SCIF/VEO -> here
local/shm/socket).  Frames are opaque; all semantics live in repro.core."""

from repro.comm.base import CommBackend, Fabric
from repro.comm.local import LocalEndpoint, LocalFabric
from repro.comm.shm import ShmEndpoint, ShmFabric, ShmRing
from repro.comm.socket import SocketEndpoint, SocketFabric

__all__ = [
    "CommBackend", "Fabric",
    "LocalEndpoint", "LocalFabric",
    "ShmEndpoint", "ShmFabric", "ShmRing",
    "SocketEndpoint", "SocketFabric",
]
