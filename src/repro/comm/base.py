"""Abstract communication backend (paper Fig. 1, bottom layer).

HAM itself is transport-agnostic; HAM-Offload plugs in MPI, TCP/IP, SCIF or
VEO/DMA.  Here the portable set is:

* ``local``  — in-process queues (threads as nodes); zero-copy handoff.
* ``shm``    — POSIX shared-memory SPSC rings between processes (the
  fast-path analogue of SCIF/DMA windows).
* ``socket`` — TCP/IP, byte-for-byte the paper's TCP backend class.

A backend moves opaque *frames* (header || payload, see core.message) between
integer-identified nodes.  It knows nothing about handlers.

Elastic membership
------------------

The paper fixes the node set at MPI startup; here the fabric is *elastic*:

* ``Fabric.add_node()`` allocates the next node id (ids are monotonic and
  never reused — a retired id stays dead forever, which is what lets
  stragglers addressed to it be dropped instead of misdelivered) and
  provisions whatever transport resources the new node needs (shm rings, a
  port, an inbox slot).
* ``Fabric.remove_node(node_id)`` retires an id and reclaims its resources.
* ``CommBackend.attach_peer(node_id)`` / ``detach_peer(node_id)`` are the
  *per-endpoint* half: every already-running endpoint must be told about a
  membership change, because endpoints cache per-peer state (rings, sockets,
  the valid-destination set).  The cluster layer broadcasts these as
  ``_cluster/attach_peer`` / ``_cluster/detach_peer`` control messages —
  see ``repro.cluster.pool`` for the ordering contract.
"""

from __future__ import annotations

from repro.core.errors import CommError


def as_byte_view(data) -> memoryview:
    """Flat uint8 memoryview over any buffer-protocol object, zero-copy —
    the normal form transports move frames in."""
    mv = data if isinstance(data, memoryview) else memoryview(data)
    if mv.format != "B" or mv.ndim != 1:
        mv = mv.cast("B")
    return mv


class CommBackend:
    """Per-node endpoint of a fabric.

    Backends expose two tiers:

    * per-frame ``send``/``recv`` — always available, frames are *owned*
      (plain bytes objects the caller may keep forever);
    * coalesced ``send_many``/``recv_many``/``release`` — the hot path.
      ``send_many`` moves N frames per transport publication (one ring
      counter store, one gathered syscall).  ``recv_many`` may hand out
      zero-copy views into the transport's receive window when the backend
      sets ``zero_copy_recv``; those views stay valid only until the next
      ``release()`` call, which returns the window space to the producer.
      Backends without a zero-copy window return owned frames and make
      ``release`` a no-op, so callers can use one code path everywhere.
    """

    node_id: int
    num_nodes: int

    #: True when recv_many returns leased views into transport memory that
    #: are invalidated by release(); False when frames are caller-owned.
    zero_copy_recv: bool = False

    #: Largest single frame this backend can move, or None for unlimited.
    #: Data-plane callers chunk transfers to stay under it.
    max_frame_nbytes: int | None = None

    def send(self, dst: int, frame: bytes | bytearray | memoryview) -> None:
        raise NotImplementedError

    def recv(self, timeout: float | None = None) -> bytes | None:
        """Next inbound frame, or ``None`` on timeout."""
        raise NotImplementedError

    def send_many(self, dst: int, frames) -> None:
        """Send a batch of frames to one destination (default: a loop)."""
        for frame in frames:
            self.send(dst, frame)

    def recv_many(self, max_frames: int = 64, timeout: float | None = None) -> list:
        """Up to ``max_frames`` inbound frames; ``[]`` on timeout.

        Default implementation degrades to one frame per call.
        """
        frame = self.recv(timeout=timeout)
        return [] if frame is None else [frame]

    def release(self) -> None:
        """Release every view handed out by prior ``recv_many`` calls.

        No-op unless ``zero_copy_recv`` is set.
        """

    def reset_peer(self, dst: int) -> None:
        """Drop cached transport state toward ``dst`` (a worker that died and
        is being replaced): stale connections/cursors must not leak into the
        restarted peer.  No-op for connectionless backends.
        """

    def attach_peer(self, node_id: int) -> None:
        """Make ``node_id`` a valid peer of this endpoint (elastic grow).

        Called on every *running* endpoint when the fabric adds a node —
        after the fabric has provisioned the node's transport resources and
        before the new node sends its first frame.  Default: widen the
        valid-destination range.
        """
        self.num_nodes = max(self.num_nodes, node_id + 1)

    def detach_peer(self, node_id: int) -> None:
        """Forget peer ``node_id`` (elastic shrink): drop cached transport
        state and stop accepting it as a destination.  The id is never
        reused, so a late send toward it must fail fast rather than queue.
        """
        self.reset_peer(node_id)

    def pending_frames(self) -> int:
        """Best-effort count of inbound frames queued in the transport that
        this endpoint has not yet received.  Feeds the runtime's queue-depth
        reports; 0 when the backend cannot tell cheaply.
        """
        return 0

    def close(self) -> None:
        pass

    def _check_dst(self, dst: int) -> None:
        if not 0 <= dst < self.num_nodes or dst == self.node_id:
            raise CommError(
                f"invalid destination {dst} (node {self.node_id} of {self.num_nodes})"
            )


class Fabric:
    """Factory/owner of the per-node backends of one communication domain."""

    num_nodes: int

    def endpoint(self, node_id: int) -> CommBackend:
        raise NotImplementedError

    def nodes(self) -> list[int]:
        """Current member node ids.  Dense ``range(num_nodes)`` by default;
        elastic fabrics may have holes after ``remove_node``."""
        return list(range(self.num_nodes))

    def add_node(self) -> int:
        """Provision transport resources for one new node and return its id
        (monotonic, never reused).  Running endpoints still need
        ``attach_peer`` before they accept the id as a destination.
        """
        raise NotImplementedError(f"{type(self).__name__} is not elastic")

    def remove_node(self, node_id: int) -> None:
        """Retire ``node_id`` and reclaim its transport resources.  The
        caller must have detached every running endpoint first
        (``detach_peer`` broadcast) — frames in flight toward a reclaimed
        resource are dropped, not redelivered.
        """
        raise NotImplementedError(f"{type(self).__name__} is not elastic")

    def prepare_restart(self, node_id: int) -> None:
        """Make the fabric safe for a replacement process to attach as
        ``node_id`` after the original died: discard frames queued toward the
        dead node (their futures were already failed by the failure detector;
        redelivering them to the replacement would resurrect cancelled work).
        No-op where nothing is buffered in the fabric itself.
        """

    def close(self) -> None:
        pass
