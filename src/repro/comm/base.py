"""Abstract communication backend (paper Fig. 1, bottom layer).

HAM itself is transport-agnostic; HAM-Offload plugs in MPI, TCP/IP, SCIF or
VEO/DMA.  Here the portable set is:

* ``local``  — in-process queues (threads as nodes); zero-copy handoff.
* ``shm``    — POSIX shared-memory SPSC rings between processes (the
  fast-path analogue of SCIF/DMA windows).
* ``socket`` — TCP/IP, byte-for-byte the paper's TCP backend class.

A backend moves opaque *frames* (header || payload, see core.message) between
integer-identified nodes.  It knows nothing about handlers.
"""

from __future__ import annotations

from repro.core.errors import CommError


class CommBackend:
    """Per-node endpoint of a fabric."""

    node_id: int
    num_nodes: int

    def send(self, dst: int, frame: bytes | bytearray | memoryview) -> None:
        raise NotImplementedError

    def recv(self, timeout: float | None = None) -> bytes | None:
        """Next inbound frame, or ``None`` on timeout."""
        raise NotImplementedError

    def close(self) -> None:
        pass

    def _check_dst(self, dst: int) -> None:
        if not 0 <= dst < self.num_nodes or dst == self.node_id:
            raise CommError(
                f"invalid destination {dst} (node {self.node_id} of {self.num_nodes})"
            )


class Fabric:
    """Factory/owner of the per-node backends of one communication domain."""

    num_nodes: int

    def endpoint(self, node_id: int) -> CommBackend:
        raise NotImplementedError

    def close(self) -> None:
        pass
