"""Seeded, deterministic fault injection over any Fabric/CommBackend.

The fault-tolerance layer (scheduler deadlines/retries, the worker replay
cache, directory recovery — see ``docs/failure-model.md``) is only
trustworthy if it is *tested against* the failures it claims to absorb.
:class:`ChaosFabric` wraps a real fabric and injects, per frame:

* **drop** — the frame never arrives;
* **dup** — the frame arrives twice (the retry path's dedup test);
* **delay** — the frame arrives ``delay_s`` later (re-sent by a timer, so
  it can overtake everything sent in between — delayed-delivery reordering);
* **reorder** — the frame is moved behind the frames that follow it in the
  same batch (or degrades to a short delay when it travels alone);
* **one-way partition** — :meth:`ChaosFabric.block` force-drops every frame
  on one ``src -> dst`` link until :meth:`ChaosFabric.unblock`.

Determinism contract
--------------------

Every link (an ordered ``src -> dst`` pair, per direction of injection)
owns a private ``random.Random`` seeded from ``(seed, src, dst)`` and a
monotonically increasing per-link frame sequence number.  The fault decided
for a frame is a pure function of ``(seed, link, link_seq, config)`` — NOT
of wall-clock time or thread interleaving — so the same seed and per-link
schedule produce the *identical fault sequence* on every run and on every
transport.  :attr:`ChaosFabric.fault_log` records each non-deliver decision
as ``(src, dst, link_seq, action, where)``; tests assert two same-seed runs
produce equal logs (``tests/test_chaos.py``).

Per-link **schedules** override the probabilistic draw for a window of the
link's sequence numbers: ``ChaosConfig(schedule=((3, 6, "drop"),))`` drops
exactly frames 3, 4 and 5 of that link, whatever the probabilities say.
The RNG is still advanced for scheduled frames, so a schedule does not
shift the fault pattern of the frames after its window.

Injection sides
---------------

Faults are injected at the **send boundary** of every wrapped endpoint and
(for HAM frames, whose 32-byte header names the true sender) at the
**receive boundary** keyed by the frame's ``src_node``.  Recv-side
injection exists because process fabrics (shm fork children, socket
subprocess workers) build their endpoints *inside the child* — only the
host's endpoint can be wrapped, so a lost worker->host reply is simulated
by dropping it on arrival at the host.  Non-HAM frames (bad magic) pass
the receive side untouched.

``arm()`` / ``disarm()`` gate injection globally: pools are built and torn
down fault-free, and verification reads (side-effect counters, directory
dumps) run with chaos disarmed.
"""

from __future__ import annotations

import dataclasses
import heapq
import random
import struct
import threading

from repro.comm.base import CommBackend, Fabric
from repro.core.message import HEADER_STRUCT, MAGIC

_DELIVER = "deliver"
_ACTIONS = ("drop", "dup", "delay", "reorder")


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Per-link fault probabilities and forced-fault schedule.

    Probabilities are cumulative-exclusive (at most one fault per frame):
    a uniform draw lands in the drop, dup, delay, reorder or deliver band.
    ``schedule`` is a tuple of ``(lo, hi, action)`` windows over the link's
    frame sequence numbers; a frame whose seq falls in ``[lo, hi)`` takes
    ``action`` unconditionally (``"deliver"`` forces clean delivery — the
    way to protect a handshake window on an otherwise lossy link).
    """

    drop: float = 0.0
    dup: float = 0.0
    delay: float = 0.0
    reorder: float = 0.0
    #: held time for delayed frames (and the alone-frame reorder fallback)
    delay_s: float = 0.005
    schedule: tuple = ()

    def validate(self) -> "ChaosConfig":
        total = self.drop + self.dup + self.delay + self.reorder
        if not 0.0 <= total <= 1.0:
            raise ValueError(f"fault probabilities sum to {total}, not [0, 1]")
        for lo, hi, action in self.schedule:
            if action != _DELIVER and action not in _ACTIONS:
                raise ValueError(f"unknown scheduled action {action!r}")
            if lo >= hi:
                raise ValueError(f"empty schedule window [{lo}, {hi})")
        return self


class _Link:
    """Deterministic decision stream for one directed (src, dst) link."""

    __slots__ = ("rng", "seq", "config", "blocked")

    def __init__(self, seed: int, src: int, dst: int, config: ChaosConfig):
        # string-seeded so (seed, src, dst) mix without collisions like
        # seed ^ src ^ dst would produce
        self.rng = random.Random(f"{seed}:{src}->{dst}")
        self.seq = 0
        self.config = config
        self.blocked = False

    def decide(self) -> tuple[int, str]:
        """Next (link_seq, action).  The RNG advances on EVERY frame —
        including blocked and scheduled ones — so partitions toggled at
        test-dependent times never shift the fault pattern that follows."""
        seq, self.seq = self.seq, self.seq + 1
        r = self.rng.random()
        if self.blocked:
            return seq, "drop"
        c = self.config
        for lo, hi, action in c.schedule:
            if lo <= seq < hi:
                return seq, action
        edge = c.drop
        if r < edge:
            return seq, "drop"
        edge += c.dup
        if r < edge:
            return seq, "dup"
        edge += c.delay
        if r < edge:
            return seq, "delay"
        edge += c.reorder
        if r < edge:
            return seq, "reorder"
        return seq, _DELIVER


class ChaosEndpoint(CommBackend):
    """Fault-injecting wrapper around one endpoint (see module docs)."""

    def __init__(self, chaos: "ChaosFabric", inner: CommBackend):
        self._chaos = chaos
        self._inner = inner
        #: inbound frames held by delay/reorder faults: (due, tiebreak, frame)
        self._in_held: list = []
        self._in_seq = 0
        self._in_lock = threading.Lock()

    # -- delegation ----------------------------------------------------------

    @property
    def node_id(self) -> int:
        return self._inner.node_id

    @property
    def num_nodes(self) -> int:
        return self._inner.num_nodes

    @property
    def zero_copy_recv(self) -> bool:
        return getattr(self._inner, "zero_copy_recv", False)

    @property
    def max_frame_nbytes(self):
        return getattr(self._inner, "max_frame_nbytes", None)

    def release(self) -> None:
        self._inner.release()

    def reset_peer(self, dst: int) -> None:
        self._inner.reset_peer(dst)

    def attach_peer(self, node_id: int) -> None:
        self._inner.attach_peer(node_id)

    def detach_peer(self, node_id: int) -> None:
        self._inner.detach_peer(node_id)

    def pending_frames(self) -> int:
        return self._inner.pending_frames()

    def close(self) -> None:
        self._inner.close()

    def __getattr__(self, name):
        return getattr(self._inner, name)

    # -- send side -----------------------------------------------------------

    def send(self, dst: int, frame) -> None:
        chaos = self._chaos
        if not chaos.armed:
            self._inner.send(dst, frame)
            return
        out = self._apply_send(dst, frame, None)
        if len(out) == 1:
            self._inner.send(dst, out[0])
        elif out:
            self._inner.send_many(dst, out)

    def send_many(self, dst: int, frames) -> None:
        chaos = self._chaos
        if not chaos.armed:
            self._inner.send_many(dst, frames)
            return
        out: list = []
        held: list = []
        for frame in frames:
            self._apply_send(dst, frame, out, held)
        out.extend(held)  # reordered frames land behind the batch
        if len(out) == 1:
            self._inner.send(dst, out[0])
        elif out:
            self._inner.send_many(dst, out)

    def _apply_send(self, dst: int, frame, out, held=None):
        """Decide and apply one outbound frame's fate; surviving frames go
        to ``out`` (created when None), reordered ones to ``held`` (behind
        the batch) or — with no batch to fall behind — a short delay."""
        chaos = self._chaos
        if out is None:
            out = []
        seq, action = chaos._decide(self.node_id, dst)
        if action == _DELIVER:
            out.append(frame)
            return out
        chaos._log(self.node_id, dst, seq, action, "send")
        if action == "drop":
            return out
        if action == "dup":
            # the copy matters: `frame` may be a pooled/leased buffer the
            # caller reuses once the send returns
            out.append(frame)
            out.append(bytes(frame))
            return out
        delay_s = chaos._link_config(self.node_id, dst).delay_s
        if action == "reorder" and held is not None:
            held.append(bytes(frame))
            return out
        # delay (and alone-frame reorder): a timer re-sends through the
        # inner endpoint, overtaken by everything sent in between
        chaos._later(delay_s, self._inner.send, dst, bytes(frame))
        return out

    # -- receive side --------------------------------------------------------

    def recv(self, timeout: float | None = None):
        chaos = self._chaos
        if not chaos.armed and not self._in_held:
            return self._inner.recv(timeout=timeout)
        got = self.recv_many(1, timeout=timeout)
        return got[0] if got else None

    def recv_many(self, max_frames: int = 64, timeout: float | None = None) -> list:
        chaos = self._chaos
        inner = self._inner
        if not chaos.armed and not self._in_held:
            return inner.recv_many(max_frames, timeout=timeout)
        frames = inner.recv_many(max_frames, timeout=timeout)
        out: list = []
        with self._in_lock:
            # release previously held frames whose due time passed
            now = chaos._now()
            while self._in_held and self._in_held[0][0] <= now:
                out.append(heapq.heappop(self._in_held)[2])
        if not chaos.armed:
            out.extend(frames)
            return out
        tail: list = []
        for frame in frames:
            src = self._frame_src(frame)
            if src is None:  # not a HAM frame: never touched
                out.append(frame)
                continue
            seq, action = chaos._decide(src, self.node_id, side="recv")
            if action == _DELIVER:
                out.append(frame)
                continue
            chaos._log(src, self.node_id, seq, action, "recv")
            if action == "drop":
                continue
            if action == "dup":
                out.append(frame)
                out.append(bytes(frame))
                continue
            if action == "reorder":
                tail.append(bytes(frame))  # behind the rest of this batch
                continue
            # delay: hold an owned copy until due, delivered by a later recv
            due = chaos._now() + chaos._link_config(src, self.node_id).delay_s
            with self._in_lock:
                self._in_seq += 1
                heapq.heappush(self._in_held, (due, self._in_seq, bytes(frame)))
        out.extend(tail)
        return out

    @staticmethod
    def _frame_src(frame):
        """The HAM header's src_node, or None for a non-HAM frame."""
        try:
            magic, _, _, _, src, _, _ = HEADER_STRUCT.unpack_from(frame, 0)
        except struct.error:
            return None
        return src if magic == MAGIC else None


class ChaosFabric(Fabric):
    """Fabric wrapper: every endpoint it hands out injects faults.

    ``default`` is the :class:`ChaosConfig` for links without an explicit
    :meth:`set_link` override.  Starts **disarmed** — wrap the fabric, build
    the pool fault-free, then :meth:`arm`.
    """

    def __init__(self, inner: Fabric, *, seed: int = 0,
                 default: ChaosConfig | None = None):
        self.inner = inner
        self.seed = int(seed)
        self.default = (default or ChaosConfig()).validate()
        self.armed = False
        self.fault_log: list[tuple[int, int, int, str, str]] = []
        self.faults = {a: 0 for a in _ACTIONS}
        self._lock = threading.Lock()
        #: (src, dst, side) -> _Link; send- and recv-side streams are
        #: separate links so host-side recv injection cannot desync the
        #: send-side sequence of the same pair
        self._links: dict[tuple[int, int, str], _Link] = {}
        self._overrides: dict[tuple[int, int], ChaosConfig] = {}
        self._endpoints: dict[int, ChaosEndpoint] = {}
        self._timers: list[threading.Timer] = []

    # -- chaos control -------------------------------------------------------

    def arm(self) -> "ChaosFabric":
        self.armed = True
        return self

    def disarm(self) -> "ChaosFabric":
        self.armed = False
        return self

    def set_link(self, src: int, dst: int,
                 config: ChaosConfig) -> "ChaosFabric":
        """Override the fault config of one directed link (both sides)."""
        with self._lock:
            self._overrides[(src, dst)] = config.validate()
            for side in ("send", "recv"):
                link = self._links.get((src, dst, side))
                if link is not None:
                    link.config = config
        return self

    def block(self, src: int, dst: int) -> "ChaosFabric":
        """One-way partition: force-drop every src->dst frame (both
        injection sides) until :meth:`unblock`."""
        return self._set_blocked(src, dst, True)

    def unblock(self, src: int, dst: int) -> "ChaosFabric":
        return self._set_blocked(src, dst, False)

    def _set_blocked(self, src: int, dst: int, blocked: bool) -> "ChaosFabric":
        with self._lock:
            for side in ("send", "recv"):
                self._link(src, dst, side, locked=True).blocked = blocked
        return self

    def _link_config(self, src: int, dst: int) -> ChaosConfig:
        return self._overrides.get((src, dst), self.default)

    def _link(self, src: int, dst: int, side: str, locked: bool = False) -> _Link:
        key = (src, dst, side)
        link = self._links.get(key)
        if link is None:
            if not locked:
                with self._lock:
                    return self._link(src, dst, side, locked=True)
            link = self._links.get(key)
            if link is None:
                link = _Link(self.seed, src, dst, self._link_config(src, dst))
                self._links[key] = link
        return link

    def _decide(self, src: int, dst: int, side: str = "send") -> tuple[int, str]:
        with self._lock:
            return self._link(src, dst, side, locked=True).decide()

    def _log(self, src: int, dst: int, seq: int, action: str, where: str) -> None:
        with self._lock:
            self.fault_log.append((src, dst, seq, action, where))
            self.faults[action] += 1

    def _later(self, delay_s: float, fn, *args) -> None:
        """Deliver a held frame after ``delay_s`` (daemon timer; best-effort
        — a delayed frame racing fabric teardown is just a dropped frame,
        which chaos is allowed to do anyway)."""

        def _fire():
            try:
                fn(*args)
            except Exception:  # noqa: BLE001 — see docstring
                pass

        t = threading.Timer(delay_s, _fire)
        t.daemon = True
        with self._lock:
            self._timers = [x for x in self._timers if x.is_alive()]
            self._timers.append(t)
        t.start()

    @staticmethod
    def _now() -> float:
        import time

        return time.monotonic()

    # -- Fabric delegation ---------------------------------------------------

    @property
    def num_nodes(self) -> int:
        return self.inner.num_nodes

    def endpoint(self, node_id: int) -> ChaosEndpoint:
        ep = self._endpoints.get(node_id)
        if ep is None:
            ep = self._endpoints[node_id] = ChaosEndpoint(
                self, self.inner.endpoint(node_id)
            )
        return ep

    def nodes(self) -> list[int]:
        return self.inner.nodes()

    def add_node(self) -> int:
        return self.inner.add_node()

    def remove_node(self, node_id: int) -> None:
        self._endpoints.pop(node_id, None)
        self.inner.remove_node(node_id)

    def prepare_restart(self, node_id: int) -> None:
        self.inner.prepare_restart(node_id)

    def close(self) -> None:
        with self._lock:
            timers, self._timers = self._timers, []
        for t in timers:
            t.cancel()
        self.inner.close()

    def __getattr__(self, name):
        # pool constructors read fabric-specific attrs (base_port, prefix)
        return getattr(self.inner, name)
