"""Shared-memory fabric: SPSC byte rings between processes.

The analogue of the paper's SCIF / VEO-DMA backends: a pre-mapped shared
window written with plain stores, no per-message syscalls, no serialisation
beyond HAM's own bitwise payload copy.  One directed ring per ordered node
pair; single producer, single consumer.

Ring layout in the shared segment::

    [ head u64 | tail u64 | data bytes ... ]

``head``/``tail`` are *monotonic* byte counters (never wrapped), which makes
full/empty unambiguous: used = head - tail.  The producer writes payload
first, then publishes by storing ``head`` (an aligned 8-byte store — a real
TPU-host port would use C++ atomics with release/acquire; CPython's memcpy of
an aligned 8-byte slice is a single store on x86-64, which we accept here and
note as an assumption change in DESIGN.md).

Frames inside the ring are ``u64 length || bytes`` with wrap-around.
"""

from __future__ import annotations

import struct
import time
from multiprocessing import shared_memory

from repro.comm.base import CommBackend, Fabric
from repro.core.errors import CommError

_HDR = 16  # head u64 + tail u64
_U64 = struct.Struct("<Q")


class ShmRing:
    """One directed SPSC ring over a named shared-memory segment."""

    def __init__(self, name: str, capacity: int = 1 << 24, create: bool = False):
        self.capacity = capacity
        if create:
            self._shm = shared_memory.SharedMemory(
                name=name, create=True, size=_HDR + capacity
            )
            self._shm.buf[:_HDR] = b"\x00" * _HDR
        else:
            self._shm = shared_memory.SharedMemory(name=name)
            self.capacity = self._shm.size - _HDR
        self._buf = self._shm.buf
        self.name = name

    # -- counters ----------------------------------------------------------

    def _head(self) -> int:
        return _U64.unpack_from(self._buf, 0)[0]

    def _tail(self) -> int:
        return _U64.unpack_from(self._buf, 8)[0]

    def _set_head(self, v: int) -> None:
        _U64.pack_into(self._buf, 0, v)

    def _set_tail(self, v: int) -> None:
        _U64.pack_into(self._buf, 8, v)

    # -- data movement -----------------------------------------------------

    def _write_bytes(self, pos: int, data) -> int:
        """Copy ``data`` at ring offset pos (monotonic), handling wrap."""
        off = pos % self.capacity
        n = len(data)
        first = min(n, self.capacity - off)
        base = _HDR
        self._buf[base + off : base + off + first] = data[:first]
        if first < n:
            self._buf[base : base + n - first] = data[first:]
        return pos + n

    def _read_bytes(self, pos: int, n: int) -> bytes:
        off = pos % self.capacity
        base = _HDR
        first = min(n, self.capacity - off)
        out = bytearray(n)
        out[:first] = self._buf[base + off : base + off + first]
        if first < n:
            out[first:] = self._buf[base : base + n - first]
        return bytes(out)

    def push(self, frame, timeout: float | None = None) -> None:
        need = 8 + len(frame)
        if need > self.capacity:
            raise CommError(
                f"frame of {len(frame)} bytes exceeds ring capacity {self.capacity}"
            )
        deadline = None if timeout is None else time.monotonic() + timeout
        head = self._head()
        while self.capacity - (head - self._tail()) < need:
            if deadline is not None and time.monotonic() > deadline:
                raise CommError("ring full: consumer stalled")
            time.sleep(0)  # yield; SPSC spin
        pos = self._write_bytes(head, _U64.pack(len(frame)))
        pos = self._write_bytes(pos, bytes(frame))
        self._set_head(pos)  # publish

    def try_pop(self) -> bytes | None:
        tail = self._tail()
        if self._head() == tail:
            return None
        (n,) = _U64.unpack(self._read_bytes(tail, 8))
        frame = self._read_bytes(tail + 8, n)
        self._set_tail(tail + 8 + n)
        return frame

    def close(self) -> None:
        self._buf = None
        self._shm.close()

    def unlink(self) -> None:
        try:
            self._shm.unlink()
        except FileNotFoundError:
            pass


def _ring_name(prefix: str, src: int, dst: int) -> str:
    return f"{prefix}_{src}_{dst}"


class ShmEndpoint(CommBackend):
    """Attaches to the rings of one node: n-1 inbound, n-1 outbound."""

    def __init__(self, prefix: str, node_id: int, num_nodes: int):
        self.node_id = node_id
        self.num_nodes = num_nodes
        self._out = {
            dst: ShmRing(_ring_name(prefix, node_id, dst))
            for dst in range(num_nodes)
            if dst != node_id
        }
        self._in = {
            src: ShmRing(_ring_name(prefix, src, node_id))
            for src in range(num_nodes)
            if src != node_id
        }
        self._rr = sorted(self._in)  # round-robin poll order

    def send(self, dst: int, frame) -> None:
        self._check_dst(dst)
        self._out[dst].push(frame)

    def recv(self, timeout: float | None = None) -> bytes | None:
        deadline = None if timeout is None else time.monotonic() + timeout
        spins = 0
        while True:
            for src in self._rr:
                frame = self._in[src].try_pop()
                if frame is not None:
                    return frame
            spins += 1
            if deadline is not None and time.monotonic() > deadline:
                return None
            # adaptive backoff: hot-spin briefly (latency), then yield
            time.sleep(0 if spins < 2048 else 1e-4)

    def close(self) -> None:
        for r in self._out.values():
            r.close()
        for r in self._in.values():
            r.close()


class ShmFabric(Fabric):
    """Creates all directed rings; parent process owns segment lifetime."""

    def __init__(self, num_nodes: int, capacity: int = 1 << 24, prefix: str | None = None):
        import os
        import uuid

        self.num_nodes = num_nodes
        self.prefix = prefix or f"ham{os.getpid()}_{uuid.uuid4().hex[:8]}"
        self._rings = []
        for src in range(num_nodes):
            for dst in range(num_nodes):
                if src != dst:
                    self._rings.append(
                        ShmRing(
                            _ring_name(self.prefix, src, dst),
                            capacity=capacity,
                            create=True,
                        )
                    )

    def endpoint(self, node_id: int) -> ShmEndpoint:
        return ShmEndpoint(self.prefix, node_id, self.num_nodes)

    def close(self) -> None:
        for r in self._rings:
            r.close()
            r.unlink()
