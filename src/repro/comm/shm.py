"""Shared-memory fabric: SPSC byte rings between processes.

The analogue of the paper's SCIF / VEO-DMA backends: a pre-mapped shared
window written with plain stores, no per-message syscalls, no serialisation
beyond HAM's own bitwise payload copy.  One directed ring per ordered node
pair; single producer, single consumer.

Ring layout in the shared segment::

    [ head u64 | head' u64 | tail u64 | tail' u64 | data bytes ... ]

``head``/``tail`` are *monotonic* byte counters (never wrapped), which makes
full/empty unambiguous: used = head - tail.  The producer writes payload
first, then publishes by storing ``head``.

Counter stores are NOT assumed atomic.  CPython's ``struct.pack_into`` /
``unpack_from`` on a shared mapping can tear an 8-byte value (measured: a
cross-process reader spinning on a counter observes mixed-byte values a few
times per million updates — a real TPU-host port would use C++ atomics with
release/acquire).  Each counter is therefore published twice — primary then
confirm copy (``head'``/``tail'``) — and a reader rereads until confirm ==
primary.  Because the counters are monotonic, accepting a stale matching
pair is always conservative (the consumer sees less data, the producer sees
less free space — never the unsafe direction), and a torn read cannot match
its independently-loaded confirm copy.

Frames inside the ring are ``u64 length || bytes`` with wrap-around; a
coalesced batch is just the concatenation of such segments (see
``repro.core.message`` for the batched-frame layout).

Zero-copy hot path and the lease protocol
-----------------------------------------

The per-frame copying API (``push`` of caller bytes, ``try_pop`` returning a
fresh ``bytes``) is kept for compatibility, but the hot path is copy-free in
both directions:

* **push / push_many** write straight from any buffer-protocol object into
  the mapped window (length prefix packed in place, payload memcpy'd via
  memoryview slice assignment — no intermediate ``bytes(frame)``).
  ``push_many`` writes N frames and publishes ``head`` once.

* **try_pop_view / pop_many** return :class:`RingLease` objects whose
  ``views`` are memoryviews *into the ring* (frames that straddle the wrap
  boundary are the one exception: they are reassembled into a scratch
  buffer, since a Python memoryview cannot be discontiguous).  The consumed
  region is NOT returned to the producer until the lease is explicitly
  ``release()``d — that is the entire contract: a view is valid exactly as
  long as its lease.  ``pop_many`` covers N frames with a single lease, so
  ``tail`` is stored once per batch.

Leases must be released in pop order (FIFO): releasing a younger lease while
an older one is outstanding raises :class:`CommError` — out-of-order release
would either tear a hole in the ring or silently re-expose unread bytes.
Internally the copying ``try_pop`` may run while leases are outstanding
(e.g. a handler doing a nested recv during a batch drain); it reads at the
ring's private read cursor and defers its own tail advance until the older
leases resolve.

Memory-ordering assumptions of the zero-copy path (documented, not checked):

* SPSC — exactly one producer and one consumer attach to each ring, so
  ``head`` is only stored by the producer and ``tail`` only by the consumer.
* TSO (x86-64): stores become visible in program order, so frame bytes are
  visible before the ``head`` primary, which is visible before the confirm
  copy; a reader that observes ``head' == head`` therefore observes every
  byte below it.  The double-word protocol above covers the one assumption
  TSO does not give pure Python: single-store atomicity of the counters.
* The consumer additionally sanity-checks every frame boundary against the
  accepted ``head`` (length nonzero, within capacity, frame fully below
  ``head``) and treats violations as "not yet published" — a belt-and-
  braces stop rather than a walk into unwritten memory.
* A leased view is stable because the producer cannot advance past ``tail``,
  and ``tail`` only moves on release.
"""

from __future__ import annotations

import struct
import time
from collections import deque
from dataclasses import dataclass, field
from multiprocessing import shared_memory

from repro.comm.base import CommBackend, Fabric, as_byte_view as _as_view
from repro.comm.doorbell import Doorbell, bell_name, futex_available
from repro.core.errors import CommError

# Counter block layout and publication discipline.  Single source of truth
# shared with the exhaustive-interleaving model
# (repro.analysis.models.ring_counters): the model's load/store routines are
# generated from this discipline, so weakening it here (e.g. dropping the
# confirm copy that closes PR 1's torn-counter window) weakens the model and
# the checker reports the frame-boundary corruption.
HEAD_OFF = 0
HEAD_CONFIRM_OFF = 8
TAIL_OFF = 16
TAIL_CONFIRM_OFF = 24
#: byte distance from a counter's primary word to its confirm copy
COUNTER_CONFIRM_STRIDE = 8
#: reader re-reads until primary == confirm, up to this many times, then
#: falls back to min(primary, confirm) — conservative for monotonic counters
COUNTER_STABLE_RETRIES = 10000
#: writer order in ``_store_counter``: primary word first, confirm last
COUNTER_STORE_ORDER = ("primary", "confirm")
#: reader order in ``_load_counter``: the confirm copy (stored last) is
#: loaded FIRST, so primary == confirm proves the pair was stable across
#: both loads; the model executes its loads in exactly this order
COUNTER_LOAD_ORDER = ("confirm", "primary")

_HDR = 32  # head u64 + head-confirm u64 + tail u64 + tail-confirm u64
_U64 = struct.Struct("<Q")

# segments whose close() found still-exported lease views; kept alive so the
# stdlib finaliser does not raise into the void (see ShmRing.close)
_leaked_segments: list = []


class RingLease:
    """Consumer-side lease over one contiguous run of popped frames.

    ``views`` hold the frame bytes (zero-copy into the ring except for
    wrap-straddling frames).  ``release()`` returns the region to the
    producer; it must be called in pop order.
    """

    __slots__ = ("_ring", "end", "views", "released")

    def __init__(self, ring: "ShmRing", end: int, views: list):
        self._ring = ring
        self.end = end  # monotonic ring offset one past the last frame
        self.views = views
        self.released = False

    @property
    def view(self) -> memoryview:
        """The single frame of a one-frame lease (try_pop_view result)."""
        return self.views[0]

    def release(self) -> None:
        self._ring._release(self, strict=True)


class ShmRing:
    """One directed SPSC ring over a named shared-memory segment."""

    def __init__(self, name: str, capacity: int = 1 << 24, create: bool = False):
        self.capacity = capacity
        if create:
            self._shm = shared_memory.SharedMemory(
                name=name, create=True, size=_HDR + capacity
            )
            self._shm.buf[:_HDR] = b"\x00" * _HDR
        else:
            self._shm = shared_memory.SharedMemory(name=name)
            self.capacity = self._shm.size - _HDR
        self._buf = self._shm.buf
        self.name = name
        # consumer-side lease state: outstanding leases in pop order, plus a
        # private read cursor (>= tail) marking the next unread frame
        self._segments: deque[RingLease] = deque()
        self._next_read = 0

    # -- counters ----------------------------------------------------------
    # Double-word publication (see module docstring): primary at `off`,
    # confirm copy at `off + 8`.  pack_into/unpack_from on shared memory can
    # tear 8-byte values, so a value only counts once primary == confirm.

    def _load_counter(self, off: int) -> int:
        buf = self._buf
        stride = COUNTER_CONFIRM_STRIDE
        for _ in range(COUNTER_STABLE_RETRIES):
            (confirm,) = _U64.unpack_from(buf, off + stride)  # stored last
            (primary,) = _U64.unpack_from(buf, off)           # stored first
            if primary == confirm:
                return primary
            time.sleep(0)  # writer mid-publish: sub-microsecond window
        # writer stalled between the two stores (e.g. preempted for a long
        # time): the smaller of the pair is the older value — conservative
        # in both directions for monotonic counters
        return min(primary, confirm)

    def _store_counter(self, off: int, v: int) -> None:
        _U64.pack_into(self._buf, off, v)
        _U64.pack_into(self._buf, off + COUNTER_CONFIRM_STRIDE, v)

    def _head(self) -> int:
        return self._load_counter(HEAD_OFF)

    def _tail(self) -> int:
        return self._load_counter(TAIL_OFF)

    def _set_head(self, v: int) -> None:
        self._store_counter(HEAD_OFF, v)

    def _set_tail(self, v: int) -> None:
        self._store_counter(TAIL_OFF, v)

    def _read_pos(self) -> int:
        """Next unread offset: the cursor while leases are outstanding,
        otherwise the shared ``tail`` (cursor == tail at quiescence)."""
        return self._next_read if self._segments else self._tail()

    # -- data movement -----------------------------------------------------

    def _write_view(self, pos: int, mv: memoryview) -> int:
        """memcpy ``mv`` at ring offset pos (monotonic), handling wrap."""
        off = pos % self.capacity
        n = mv.nbytes
        first = min(n, self.capacity - off)
        base = _HDR
        self._buf[base + off : base + off + first] = mv[:first]
        if first < n:
            self._buf[base : base + n - first] = mv[first:]
        return pos + n

    def _write_u64(self, pos: int, value: int) -> int:
        off = pos % self.capacity
        if off + 8 <= self.capacity:
            _U64.pack_into(self._buf, _HDR + off, value)
            return pos + 8
        return self._write_view(pos, memoryview(_U64.pack(value)))

    def _read_u64(self, pos: int) -> int:
        off = pos % self.capacity
        if off + 8 <= self.capacity:
            return _U64.unpack_from(self._buf, _HDR + off)[0]
        return _U64.unpack(bytes(self._read_copy(pos, 8)))[0]

    def _read_copy(self, pos: int, n: int) -> bytearray:
        off = pos % self.capacity
        base = _HDR
        first = min(n, self.capacity - off)
        out = bytearray(n)
        out[:first] = self._buf[base + off : base + off + first]
        if first < n:
            out[first:] = self._buf[base : base + n - first]
        return out

    def _frame_view(self, start: int, n: int) -> memoryview:
        """Zero-copy view of [start, start+n) when contiguous; a scratch copy
        when the frame straddles the wrap boundary."""
        off = start % self.capacity
        if off + n <= self.capacity:
            return self._buf[_HDR + off : _HDR + off + n]
        return memoryview(self._read_copy(start, n))

    # -- producer side -----------------------------------------------------

    def _wait_space(self, head: int, need: int, deadline) -> None:
        while self.capacity - (head - self._tail()) < need:
            if deadline is not None and time.monotonic() > deadline:
                raise CommError("ring full: consumer stalled")
            time.sleep(0)  # yield; SPSC spin

    def push(self, frame, timeout: float | None = None) -> None:
        mv = _as_view(frame)
        need = 8 + mv.nbytes
        if need > self.capacity:
            raise CommError(
                f"frame of {mv.nbytes} bytes exceeds ring capacity {self.capacity}"
            )
        deadline = None if timeout is None else time.monotonic() + timeout
        head = self._head()
        self._wait_space(head, need, deadline)
        pos = self._write_u64(head, mv.nbytes)
        pos = self._write_view(pos, mv)
        self._set_head(pos)  # publish

    def push_many(self, frames, timeout: float | None = None) -> None:
        """Write N frames, publishing ``head`` once per sub-batch.

        Batches larger than the ring are split greedily; each sub-batch is
        one counter store.
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        batch: list[memoryview] = []
        batch_need = 0
        for frame in frames:
            mv = _as_view(frame)
            need = 8 + mv.nbytes
            if need > self.capacity:
                raise CommError(
                    f"frame of {mv.nbytes} bytes exceeds ring capacity "
                    f"{self.capacity}"
                )
            if batch and batch_need + need > self.capacity:
                self._push_batch(batch, batch_need, deadline)
                batch, batch_need = [], 0
            batch.append(mv)
            batch_need += need
        if batch:
            self._push_batch(batch, batch_need, deadline)

    # below this total size a batch is joined into one contiguous segment
    # before the ring write: for small frames one join + one memcpy beats
    # 2N slice-assigns (the join copy is noise next to the saved Python ops)
    _JOIN_LIMIT = 1 << 16

    def _push_batch(self, views: list[memoryview], need: int, deadline) -> None:
        head = self._head()
        self._wait_space(head, need, deadline)
        if need <= self._JOIN_LIMIT and len(views) > 1:
            parts: list = []
            append = parts.append
            pack = _U64.pack
            for mv in views:
                append(pack(mv.nbytes))
                append(mv)
            pos = self._write_view(head, memoryview(b"".join(parts)))
        else:
            pos = head
            for mv in views:
                pos = self._write_u64(pos, mv.nbytes)
                pos = self._write_view(pos, mv)
        self._set_head(pos)  # single publish for the whole batch

    # -- consumer side -----------------------------------------------------

    def _frame_len_checked(self, pos: int, head: int) -> int | None:
        """Length of the frame at ``pos``, or None if the bytes there do not
        describe a fully-published frame below ``head`` (belt-and-braces
        against counter tears; see module docstring)."""
        n = self._read_u64(pos)
        if n == 0 or n > self.capacity - 8 or pos + 8 + n > head:
            return None
        return n

    def try_pop_view(self) -> RingLease | None:
        """Zero-copy pop: a one-frame lease, or ``None`` if empty."""
        pos = self._read_pos()
        head = self._head()
        if head == pos:
            return None
        n = self._frame_len_checked(pos, head)
        if n is None:
            return None
        end = pos + 8 + n
        lease = RingLease(self, end, [self._frame_view(pos + 8, n)])
        self._segments.append(lease)
        self._next_read = end
        return lease

    def pop_many(self, max_frames: int = 64) -> RingLease | None:
        """Pop up to ``max_frames`` under ONE lease (one eventual tail store)."""
        pos = self._read_pos()
        head = self._head()
        if pos == head:
            return None
        # hot loop: locals + inlined view slicing (no per-frame method calls)
        buf = self._buf
        cap = self.capacity
        unpack_from = _U64.unpack_from
        views: list[memoryview] = []
        append = views.append
        while pos != head and len(views) < max_frames:
            off = pos % cap
            if off + 8 <= cap:
                (n,) = unpack_from(buf, _HDR + off)
            else:
                (n,) = _U64.unpack(bytes(self._read_copy(pos, 8)))
            if n == 0 or n > cap - 8 or pos + 8 + n > head:
                break  # not a fully-published frame: stop, retry next poll
            start = pos + 8
            soff = start % cap
            if soff + n <= cap:
                append(buf[_HDR + soff : _HDR + soff + n])
            else:
                append(memoryview(self._read_copy(start, n)))
            pos = start + n
        if not views:
            return None
        lease = RingLease(self, pos, views)
        self._segments.append(lease)
        self._next_read = pos
        return lease

    def _release(self, lease: RingLease, strict: bool) -> None:
        if lease.released:
            raise CommError("ring lease released twice")
        if strict and (not self._segments or self._segments[0] is not lease):
            raise CommError(
                "ring lease released out of order: an older lease is still "
                "outstanding (leases are FIFO)"
            )
        lease.released = True
        # advance tail over the longest released prefix (deferred releases
        # from nested copying pops resolve here)
        new_tail = None
        while self._segments and self._segments[0].released:
            new_tail = self._segments.popleft().end
        if new_tail is not None:
            self._set_tail(new_tail)

    def try_pop(self):
        """Compatibility pop: one owned frame (copied out of the ring)."""
        if not self._segments:
            # fast path: no outstanding leases, advance tail directly
            pos = self._tail()
            head = self._head()
            if head == pos:
                return None
            n = self._frame_len_checked(pos, head)
            if n is None:
                return None
            off = (pos + 8) % self.capacity
            if off + n <= self.capacity:
                frame = bytes(self._buf[_HDR + off : _HDR + off + n])
            else:
                frame = bytes(self._read_copy(pos + 8, n))
            self._set_tail(pos + 8 + n)
            return frame
        # leases outstanding (nested pop during a batch drain): read at the
        # cursor and defer the tail advance behind the older leases
        lease = self.try_pop_view()
        if lease is None:
            return None
        frame = bytes(lease.view)
        self._release(lease, strict=False)
        return frame

    def pending_frame_count(self, max_count: int = 32) -> int:
        """Consumer-side count of fully-published, unread frames (capped at
        ``max_count`` — this feeds queue-depth *estimates*, not accounting).
        Read-only walk over the length prefixes; safe under SPSC."""
        pos = self._read_pos()
        head = self._head()
        count = 0
        while pos != head and count < max_count:
            n = self._frame_len_checked(pos, head)
            if n is None:
                break
            pos += 8 + n
            count += 1
        return count

    def drop_pending(self) -> None:
        """Discard every queued-but-unconsumed frame (tail := head).

        Only safe while the ring's consumer is not running — used by the
        fabric before attaching a *replacement* consumer process: frames
        addressed to the dead worker were already failed by the failure
        detector, so redelivering them would resurrect cancelled calls.
        """
        self._segments.clear()
        self._next_read = 0
        self._set_tail(self._head())

    def close(self) -> None:
        self._segments.clear()
        self._buf = None
        try:
            self._shm.close()
        except BufferError:
            # a leased view still references the mapping; keep the segment
            # object alive (the OS reclaims the mapping at process exit)
            # rather than crash teardown or warn from a doomed __del__
            _leaked_segments.append(self._shm)

    def unlink(self) -> None:
        try:
            self._shm.unlink()
        except FileNotFoundError:
            pass


def _ring_name(prefix: str, src: int, dst: int) -> str:
    return f"{prefix}_{src}_{dst}"


def _default_spin_budget() -> int:
    # On a single-core host hot-spinning only delays the sender (time.sleep(0)
    # does not yield the GIL-holder's core), so park almost immediately; with
    # real parallelism a short spin window converts same-core-park latency
    # into sub-microsecond pickup for back-to-back frames.
    import os

    return 2048 if (os.cpu_count() or 1) > 1 else 64


@dataclass(frozen=True)
class RingConfig:
    """Tunables for the receiver wakeup path (one home for the former
    hardcoded ``2048`` spin / ``1e-4`` sleep constants).

    ``spin_budget`` polls happen before the endpoint either parks on its
    doorbell (futex available) or falls back to sleeping ``sleep_quantum``
    per miss.  ``park_timeout`` bounds each futex park so the documented
    lost-wakeup races degrade to latency, never to a hang.  Tests force the
    park path deterministically with ``spin_budget=0``.
    """

    spin_budget: int = field(default_factory=_default_spin_budget)
    sleep_quantum: float = 1e-4
    park_timeout: float = 2e-3
    use_doorbell: bool = True

    def as_dict(self) -> dict:
        """JSON-serialisable form for worker spawn specs."""
        return {
            "spin_budget": self.spin_budget,
            "sleep_quantum": self.sleep_quantum,
            "park_timeout": self.park_timeout,
            "use_doorbell": self.use_doorbell,
        }

    @classmethod
    def from_dict(cls, d: dict | None) -> "RingConfig":
        return cls(**d) if d else cls()


class ShmEndpoint(CommBackend):
    """Attaches to the rings of one node: n-1 inbound, n-1 outbound.

    ``recv_many`` hands out leased zero-copy views (``zero_copy_recv`` is
    set); callers return the window space with ``release()``.

    ``peers`` names the member node ids to attach rings for (defaults to the
    dense ``range(num_nodes)``); an elastic fabric with holes after
    ``remove_node`` must pass its live set, since rings for retired ids no
    longer exist.  ``attach_peer``/``detach_peer`` adjust the ring set of a
    *running* endpoint when membership changes.
    """

    zero_copy_recv = True

    def __init__(self, prefix: str, node_id: int, num_nodes: int, peers=None,
                 config: RingConfig | None = None):
        self.node_id = node_id
        self.num_nodes = num_nodes
        self._prefix = prefix
        self.config = config or RingConfig()
        if peers is None:
            peers = range(num_nodes)
        peers = [p for p in peers if p != node_id]
        self._out = {dst: ShmRing(_ring_name(prefix, node_id, dst)) for dst in peers}
        self._in = {src: ShmRing(_ring_name(prefix, src, node_id)) for src in peers}
        self._rr = sorted(self._in)  # round-robin poll order
        self._leases: list[RingLease] = []  # issued by recv_many, unreleased
        # Doorbells: ours to park on, one per peer to ring after a push.
        # Attach-by-name so forked and fresh-interpreter workers both work;
        # a fabric predating doorbells has no segments and we degrade to the
        # adaptive-spin path (bell is None).
        self._bell = self._attach_bell(node_id)
        self._peer_bells = {dst: self._attach_bell(dst) for dst in peers}
        self._refresh_frame_cap()

    def _attach_bell(self, node: int) -> Doorbell | None:
        if not (self.config.use_doorbell and futex_available()):
            return None
        try:
            return Doorbell(bell_name(self._prefix, node))
        except FileNotFoundError:
            return None

    def _refresh_frame_cap(self) -> None:
        # a frame must fit one ring (8-byte length prefix included)
        self.max_frame_nbytes = (
            min(r.capacity for r in self._out.values()) - 8 if self._out else None
        )

    def _check_dst(self, dst: int) -> None:
        if dst == self.node_id or dst not in self._out:
            raise CommError(
                f"invalid destination {dst} (node {self.node_id}; peers "
                f"{sorted(self._out)})"
            )

    def attach_peer(self, node_id: int) -> None:
        """Open the ring pair toward a newly added member (the fabric owner
        must have created the segments already)."""
        if node_id == self.node_id or node_id in self._out:
            return
        self._out[node_id] = ShmRing(_ring_name(self._prefix, self.node_id, node_id))
        self._in[node_id] = ShmRing(_ring_name(self._prefix, node_id, self.node_id))
        self._peer_bells[node_id] = self._attach_bell(node_id)
        self._rr = sorted(self._in)
        self.num_nodes = max(self.num_nodes, node_id + 1)
        self._refresh_frame_cap()

    def detach_peer(self, node_id: int) -> None:
        """Close this endpoint's ring pair toward a retired member.  Later
        sends toward the id fail fast (``_check_dst``)."""
        out = self._out.pop(node_id, None)
        inn = self._in.pop(node_id, None)
        bell = self._peer_bells.pop(node_id, None)
        self._rr = sorted(self._in)
        for ring in (out, inn):
            if ring is not None:
                ring.close()
        if bell is not None:
            bell.close()
        if out is not None:
            self._refresh_frame_cap()

    def _out_ring(self, dst: int) -> ShmRing:
        """Outbound ring for ``dst``, raising CommError (the documented
        retired-peer contract) when a concurrent detach_peer removed or
        closed it between the destination check and the push."""
        self._check_dst(dst)
        ring = self._out.get(dst)
        if ring is None or ring._buf is None:
            raise CommError(f"destination {dst} was removed from the fabric")
        return ring

    def send(self, dst: int, frame) -> None:
        try:
            self._out_ring(dst).push(frame)
        except (TypeError, ValueError) as e:  # ring closed mid-push
            raise CommError(f"peer {dst} detached during send") from e
        bell = self._peer_bells.get(dst)
        if bell is not None:
            bell.ring()

    def send_many(self, dst: int, frames) -> None:
        try:
            self._out_ring(dst).push_many(frames)
        except (TypeError, ValueError) as e:
            raise CommError(f"peer {dst} detached during send") from e
        bell = self._peer_bells.get(dst)
        if bell is not None:
            bell.ring()

    def recv(self, timeout: float | None = None) -> bytes | None:
        deadline = None if timeout is None else time.monotonic() + timeout
        cfg = self.config
        bell = self._bell
        spins = 0
        armed = False
        try:
            while True:
                # When armed, snapshot seq BEFORE polling: a publish after
                # this poll bumps seq and FUTEX_WAIT refuses to sleep.
                seq = bell.read_seq() if armed else 0
                for src in self._rr:
                    # detach_peer (another thread) may retire a ring
                    # mid-poll: a missing/closed ring reads as empty,
                    # never as an error
                    ring = self._in.get(src)
                    if ring is None or ring._buf is None:
                        continue
                    try:
                        frame = ring.try_pop()
                    except (TypeError, ValueError):  # closed under our feet
                        continue
                    if frame is not None:
                        return frame
                spins += 1
                if deadline is not None and time.monotonic() > deadline:
                    return None
                if bell is not None and spins >= cfg.spin_budget:
                    if not armed:
                        bell.arm()
                        armed = True
                        continue  # mandatory re-poll between arm and park
                    park = cfg.park_timeout
                    if deadline is not None:
                        park = min(park, deadline - time.monotonic())
                        if park <= 0:
                            return None
                    bell.wait(seq, park)
                else:
                    # adaptive backoff: hot-spin briefly (latency), then
                    # yield — the doorbell-less fallback path
                    time.sleep(0 if spins < cfg.spin_budget else cfg.sleep_quantum)
        finally:
            if armed:
                bell.disarm()

    def recv_many(self, max_frames: int = 64, timeout: float | None = None) -> list:
        """Up to ``max_frames`` leased frame views, ``[]`` on timeout.

        One ``pop_many`` (= one eventual tail store) per non-empty inbound
        ring; views stay valid until :meth:`release`.  Waiting follows the
        same spin-then-park protocol as :meth:`recv`.
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        cfg = self.config
        bell = self._bell
        spins = 0
        armed = False
        try:
            while True:
                seq = bell.read_seq() if armed else 0
                views: list = []
                for src in self._rr:
                    ring = self._in.get(src)
                    if ring is None or ring._buf is None:
                        continue  # retired by detach_peer mid-poll
                    try:
                        lease = ring.pop_many(max_frames - len(views))
                    except (TypeError, ValueError):  # closed under our feet
                        continue
                    if lease is not None:
                        self._leases.append(lease)
                        views.extend(lease.views)
                        if len(views) >= max_frames:
                            break
                if views:
                    return views
                spins += 1
                if deadline is not None and time.monotonic() > deadline:
                    return []
                if bell is not None and spins >= cfg.spin_budget:
                    if not armed:
                        bell.arm()
                        armed = True
                        continue  # mandatory re-poll between arm and park
                    park = cfg.park_timeout
                    if deadline is not None:
                        park = min(park, deadline - time.monotonic())
                        if park <= 0:
                            return []
                    bell.wait(seq, park)
                else:
                    time.sleep(0 if spins < cfg.spin_budget else cfg.sleep_quantum)
        finally:
            if armed:
                bell.disarm()

    def release(self) -> None:
        leases, self._leases = self._leases, []
        for lease in leases:
            if not lease.released:
                lease.release()

    def pending_frames(self) -> int:
        """Published-but-unread frames across the inbound rings (capped per
        ring; an estimate for queue-depth reports, not accounting)."""
        total = 0
        for src in self._rr:
            ring = self._in.get(src)
            if ring is None or ring._buf is None:
                continue
            try:
                total += ring.pending_frame_count()
            except (TypeError, ValueError):
                continue
        return total

    def close(self) -> None:
        self._leases.clear()
        for r in self._out.values():
            r.close()
        for r in self._in.values():
            r.close()
        if self._bell is not None:
            self._bell.close()
            self._bell = None
        for bell in self._peer_bells.values():
            if bell is not None:
                bell.close()
        self._peer_bells = {}


class ShmFabric(Fabric):
    """Creates all directed rings; parent process owns segment lifetime.

    Segment lifetime is guarded twice: an explicit :meth:`close` (the normal
    path) and an ``atexit`` hook — so a host that errors out between fabric
    creation and teardown (or a test that aborts mid-run while a child is
    dead) still unlinks its ``/dev/shm`` segments instead of leaking them
    until reboot.

    Elastic membership: :meth:`add_node` creates the new node's ring pairs
    toward every current member (segments exist before any endpoint attaches
    them); :meth:`remove_node` unlinks a retired node's rings.  Node ids are
    monotonic and never reused.  Already-running *remote* endpoints map the
    new rings via their own ``attach_peer`` (broadcast by the cluster
    layer) — the fabric owner only manages segment lifetime.
    """

    def __init__(self, num_nodes: int, capacity: int = 1 << 24, prefix: str | None = None,
                 config: RingConfig | None = None):
        import atexit
        import os
        import uuid

        self.num_nodes = num_nodes
        self.capacity = capacity
        self.config = config or RingConfig()
        self.prefix = prefix or f"ham{os.getpid()}_{uuid.uuid4().hex[:8]}"
        self._rings: dict[tuple[int, int], ShmRing] = {}
        self._bells: dict[int, Doorbell] = {}
        self._nodes: set[int] = set(range(num_nodes))
        self._next_id = num_nodes
        self._closed = False
        for src in range(num_nodes):
            for dst in range(num_nodes):
                if src != dst:
                    self._rings[(src, dst)] = ShmRing(
                        _ring_name(self.prefix, src, dst),
                        capacity=capacity,
                        create=True,
                    )
        if self.config.use_doorbell and futex_available():
            for node in range(num_nodes):
                self._bells[node] = Doorbell(
                    bell_name(self.prefix, node), create=True
                )
        atexit.register(self.close)

    def endpoint(self, node_id: int) -> ShmEndpoint:
        return ShmEndpoint(self.prefix, node_id, self.num_nodes,
                           peers=sorted(self._nodes), config=self.config)

    def nodes(self) -> list[int]:
        return sorted(self._nodes)

    def add_node(self) -> int:
        node_id = self._next_id
        self._next_id += 1
        for peer in sorted(self._nodes):
            self._rings[(node_id, peer)] = ShmRing(
                _ring_name(self.prefix, node_id, peer),
                capacity=self.capacity, create=True,
            )
            self._rings[(peer, node_id)] = ShmRing(
                _ring_name(self.prefix, peer, node_id),
                capacity=self.capacity, create=True,
            )
        if self.config.use_doorbell and futex_available():
            self._bells[node_id] = Doorbell(
                bell_name(self.prefix, node_id), create=True
            )
        self._nodes.add(node_id)
        self.num_nodes = max(self.num_nodes, node_id + 1)
        return node_id

    def remove_node(self, node_id: int) -> None:
        self._nodes.discard(node_id)
        for pair in [p for p in self._rings if node_id in p]:
            ring = self._rings.pop(pair)
            ring.close()
            ring.unlink()
        bell = self._bells.pop(node_id, None)
        if bell is not None:
            bell.close()
            bell.unlink()

    def prepare_restart(self, node_id: int) -> None:
        """Clear the dead node's inbound rings so a replacement consumer
        starts from an empty queue (see Fabric.prepare_restart)."""
        for (_, dst), ring in self._rings.items():
            if dst == node_id:
                ring.drop_pending()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        import atexit

        atexit.unregister(self.close)
        for r in self._rings.values():
            r.close()
            r.unlink()
        for bell in self._bells.values():
            bell.close()
            bell.unlink()
        self._bells = {}
