"""Spin-then-park doorbell for the shm fabric.

The shm rings are pure shared-memory SPSC queues: nothing in the data path
tells a sleeping receiver that a frame was published, so before this module
the receiver's only options were to burn CPU spinning or to sleep a fixed
quantum (1e-4 s) and eat that as wakeup latency.  On a single-core host the
spin is worse than useless -- ``time.sleep(0)`` does not yield the core in
CPython, so a spinning receiver holds the CPU for a full scheduler tick
(~4 ms) while the sender it is waiting for starves.

A :class:`Doorbell` is a tiny shared-memory segment -- one per consumer
node -- holding a futex word:

    offset 0: u32 ``seq``      bumped by a producer after it publishes a frame
    offset 4: u32 ``waiters``  nonzero while the consumer is parked (or about
                               to park); producers skip the wake syscall when
                               it is zero, keeping the un-contended send path
                               at two struct ops and no syscalls

The consumer protocol (see ``docs/transport.md`` for the memory-ordering
argument) is: spin for a budget, then *arm* (waiters=1), re-read ``seq``,
re-poll the rings once, and only then ``FUTEX_WAIT(seq, observed)`` with a
bounded timeout.  The re-poll closes the publish-before-arm window; the
``seq`` compare-on-entry closes the publish-after-repoll window (the kernel
returns EAGAIN instead of sleeping); and the timeout bounds the residual
races that pure-Python non-atomic counters cannot close (two producers
tearing each other's ``seq`` increment, a producer reading ``waiters`` just
before the consumer stores 1).  A lost wakeup therefore costs at most
``park_timeout`` (default 2 ms), never a hang.

Futexes are reached through ``ctypes``/``syscall(2)`` -- no extension module
and no new dependency.  Where the syscall is unavailable (non-Linux, odd
libc, unknown architecture) :func:`futex_available` reports False after an
import-time-style self-probe and callers degrade to the adaptive-spin path.
An ``eventfd`` fallback was considered and rejected: an eventfd is a file
descriptor, which fork-inherits but cannot be re-opened by name from a
fresh interpreter, and every shm worker spawn path here supports
attach-by-name.  The futex word lives in named shared memory, so it works
for both spawn styles with one code path.
"""

from __future__ import annotations

import ctypes
import errno
import os
import platform
import struct
from multiprocessing import shared_memory

__all__ = [
    "CONSUMER_PARK_PROTOCOL",
    "Doorbell",
    "PRODUCER_RING_PROTOCOL",
    "SEQ_OFF",
    "WAITERS_OFF",
    "futex_available",
    "futex_wait",
    "futex_wake",
]

_U32 = struct.Struct("<I")

# Word layout and protocol step orders.  These are the single source of
# truth shared with the exhaustive-interleaving model
# (repro.analysis.models.doorbell): the model builds its transition system
# from these tuples, so an implementation reorder that reopens a lost-wakeup
# window (PR 7's publish-before-arm / publish-after-repoll races) changes
# the model too and the checker finds the stranded park.
SEQ_OFF = 0
WAITERS_OFF = 4

#: producer step order in :meth:`Doorbell.ring` (after the ring push that
#: precedes it): bump ``seq`` (non-atomic RMW), then read ``waiters``, then
#: the conditional FUTEX_WAKE
PRODUCER_RING_PROTOCOL = ("publish", "bump_seq", "read_waiters", "wake_if_armed")

#: consumer step order in the shm endpoints' spin-then-park loop: arm
#: (waiters=1), snapshot ``seq``, MANDATORY ring re-poll, and only then the
#: compare-on-entry FUTEX_WAIT on the pre-poll snapshot.  The snapshot MUST
#: precede the re-poll: a publish that lands between them bumps ``seq`` and
#: FUTEX_WAIT refuses to sleep (EAGAIN) instead of stranding the park.
CONSUMER_PARK_PROTOCOL = ("arm", "read_seq", "repoll", "wait_if_unchanged")

_SEQ_OFF = SEQ_OFF
_WAITERS_OFF = WAITERS_OFF

# futex(2) operation codes.  Deliberately NOT using FUTEX_PRIVATE_FLAG: the
# word lives in shared memory mapped by unrelated processes, so the futex
# must hash on the physical page, not the per-mm address.
_FUTEX_WAIT = 0
_FUTEX_WAKE = 1

# syscall numbers vary per architecture; the generic syscall table (used by
# aarch64/riscv64) assigns 98, legacy tables differ.
_SYS_FUTEX = {
    "x86_64": 202,
    "aarch64": 98,
    "arm64": 98,
    "riscv64": 98,
    "armv7l": 240,
    "i686": 240,
    "ppc64le": 221,
    "s390x": 238,
}.get(platform.machine())


class _Timespec(ctypes.Structure):
    _fields_ = [("tv_sec", ctypes.c_long), ("tv_nsec", ctypes.c_long)]


_libc = None
_available = None


def _load_libc():
    global _libc
    if _libc is None:
        _libc = ctypes.CDLL(None, use_errno=True)
    return _libc


def _futex(addr: int, op: int, val: int, timeout_s: float | None) -> int:
    """Raw futex syscall; returns 0 on success, -errno on failure."""
    libc = _load_libc()
    if timeout_s is None:
        ts = None
    else:
        sec = int(timeout_s)
        ts = ctypes.byref(_Timespec(sec, int((timeout_s - sec) * 1e9)))
    ret = libc.syscall(
        _SYS_FUTEX, ctypes.c_void_p(addr), op, ctypes.c_uint(val), ts, None, 0
    )
    if ret == -1:
        return -ctypes.get_errno()
    return ret


def futex_available() -> bool:
    """Self-probe: does FUTEX_WAIT with a mismatched expected value EAGAIN?

    Probing (rather than trusting ``sys.platform``) catches seccomp filters,
    emulation layers, and unknown-architecture syscall numbers in one shot.
    The probe word is private process memory -- futex does not care where
    the page lives.
    """
    global _available
    if _available is None:
        if _SYS_FUTEX is None or not hasattr(os, "sched_yield"):
            _available = False
        else:
            try:
                word = ctypes.c_uint(7)
                rc = _futex(ctypes.addressof(word), _FUTEX_WAIT, 99, None)
                _available = rc == -errno.EAGAIN
            except Exception:
                _available = False
    return _available


def futex_wait(addr: int, expected: int, timeout_s: float | None) -> int:
    """Park until woken, timed out, or ``*addr != expected`` on entry.

    Returns 0 on wake, -EAGAIN if the word already changed, -ETIMEDOUT on
    timeout, -EINTR on signal.  All are "go re-poll" to the caller.
    """
    return _futex(addr, _FUTEX_WAIT, expected, timeout_s)


def futex_wake(addr: int, n: int = 2**31 - 1) -> int:
    """Wake up to ``n`` waiters parked on the word (default: all)."""
    return _futex(addr, _FUTEX_WAKE, n, None)


def bell_name(prefix: str, node: int) -> str:
    """Shared-memory name of node ``node``'s inbound doorbell."""
    return f"{prefix}_db_{node}"


class Doorbell:
    """A named futex word + waiter flag in shared memory.

    One doorbell exists per *consumer* node; every producer that pushes a
    frame to any of that node's inbound rings rings the same bell.  The
    segment is created by the fabric (which owns ring lifetimes already)
    and attached by name from endpoints, including endpoints built inside
    freshly spawned interpreters.
    """

    NBYTES = 8

    def __init__(self, name: str, *, create: bool = False):
        self.name = name
        self._shm = shared_memory.SharedMemory(
            name=name, create=create, size=self.NBYTES
        )
        buf = self._shm.buf
        if create:
            buf[: self.NBYTES] = b"\x00" * self.NBYTES
        self._buf = buf
        # Stable address of the futex word for the lifetime of the mapping.
        self._addr = ctypes.addressof(ctypes.c_char.from_buffer(buf, _SEQ_OFF))
        self._closed = False

    # -- producer side -----------------------------------------------------
    def ring(self) -> None:
        """Publish 'new frames may exist' and wake the consumer if parked.

        The seq bump is a plain read-modify-write (Python offers no atomic
        RMW on shared memory); concurrent producers can tear it, collapsing
        two bumps into one.  That is safe: the wake below is keyed on the
        waiters flag, not on seq, and a consumer that misses a seq change
        still re-polls within ``park_timeout``.
        """
        buf = self._buf
        (seq,) = _U32.unpack_from(buf, _SEQ_OFF)
        _U32.pack_into(buf, _SEQ_OFF, (seq + 1) & 0xFFFFFFFF)
        (waiters,) = _U32.unpack_from(buf, _WAITERS_OFF)
        if waiters:
            futex_wake(self._addr)

    # -- consumer side -----------------------------------------------------
    def read_seq(self) -> int:
        (seq,) = _U32.unpack_from(self._buf, _SEQ_OFF)
        return seq

    def arm(self) -> None:
        """Announce intent to park.  MUST be followed by a ring re-poll
        before :meth:`wait` -- see the protocol note in the module doc."""
        _U32.pack_into(self._buf, _WAITERS_OFF, 1)

    def disarm(self) -> None:
        _U32.pack_into(self._buf, _WAITERS_OFF, 0)

    def wait(self, expected_seq: int, timeout_s: float) -> int:
        """Park until rung, ``seq`` drift, timeout, or signal."""
        return futex_wait(self._addr, expected_seq, timeout_s)

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        # Drop the exported pointer before closing the mapping, else the
        # BufferError path leaks the whole segment mapping.
        self._addr = 0
        self._buf = None
        try:
            self._shm.close()
        except BufferError:  # pragma: no cover - defensive
            pass

    def unlink(self) -> None:
        try:
            self._shm.unlink()
        except FileNotFoundError:
            pass

    def __del__(self):  # pragma: no cover - GC safety net
        try:
            self.close()
        except Exception:
            pass
