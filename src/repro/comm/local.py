"""In-process fabric: nodes are threads, frames move by reference.

This is the intra-node offload case of the paper (host and accelerator in
one box) reduced to its cheapest possible transport — useful both as the
latency floor in the Fig. 3-analogue benchmark and as the default fabric for
unit tests.

Elastic membership is trivial here (everything shares the fabric object):
``add_node`` creates a fresh inbox, ``remove_node`` deletes it; endpoints
consult the fabric's live endpoint map on every send, so attach/detach
broadcasts are no-ops and a send toward a removed id fails fast.
"""

from __future__ import annotations

import queue

from repro.comm.base import CommBackend, Fabric
from repro.core.errors import CommError


class LocalEndpoint(CommBackend):
    def __init__(self, fabric: "LocalFabric", node_id: int):
        self._fabric = fabric
        self.node_id = node_id
        self._inbox: queue.SimpleQueue = queue.SimpleQueue()

    @property
    def num_nodes(self) -> int:
        return self._fabric.num_nodes

    def _check_dst(self, dst: int) -> None:
        if dst == self.node_id or dst not in self._fabric._endpoints:
            raise CommError(
                f"invalid destination {dst} (node {self.node_id} of "
                f"{sorted(self._fabric._endpoints)})"
            )

    def attach_peer(self, node_id: int) -> None:
        pass  # membership lives on the shared fabric object

    def detach_peer(self, node_id: int) -> None:
        pass

    def send(self, dst: int, frame) -> None:
        self._check_dst(dst)
        # by-reference handoff: frames are freshly allocated per message and
        # never mutated after send, so the zero-copy pass-through is safe
        # (the latency floor the shm/socket backends are measured against)
        self._fabric._endpoints[dst]._inbox.put(frame)

    def send_many(self, dst: int, frames) -> None:
        self._check_dst(dst)
        inbox = self._fabric._endpoints[dst]._inbox
        for frame in frames:
            inbox.put(frame)

    def recv(self, timeout: float | None = None) -> bytes | None:
        try:
            return self._inbox.get(timeout=timeout)
        except queue.Empty:
            return None

    def recv_many(self, max_frames: int = 64, timeout: float | None = None) -> list:
        """Drain up to ``max_frames`` queued frames in one call (frames are
        owned — by-reference handoff — so there is nothing to release)."""
        try:
            out = [self._inbox.get(timeout=timeout)]
        except queue.Empty:
            return []
        while len(out) < max_frames:
            try:
                out.append(self._inbox.get_nowait())
            except queue.Empty:
                break
        return out

    def pending_frames(self) -> int:
        return self._inbox.qsize()


class LocalFabric(Fabric):
    def __init__(self, num_nodes: int):
        self.num_nodes = num_nodes
        self._endpoints = {i: LocalEndpoint(self, i) for i in range(num_nodes)}
        self._next_id = num_nodes

    def endpoint(self, node_id: int) -> LocalEndpoint:
        return self._endpoints[node_id]

    def nodes(self) -> list[int]:
        return sorted(self._endpoints)

    def add_node(self) -> int:
        node_id = self._next_id
        self._next_id += 1
        self._endpoints[node_id] = LocalEndpoint(self, node_id)
        self.num_nodes = max(self.num_nodes, node_id + 1)
        return node_id

    def remove_node(self, node_id: int) -> None:
        self._endpoints.pop(node_id, None)

    def prepare_restart(self, node_id: int) -> None:
        """Drain frames queued toward a dead node's inbox — they belong to
        calls the failure detector already failed (see Fabric docs)."""
        inbox = self._endpoints[node_id]._inbox
        while True:
            try:
                inbox.get_nowait()
            except queue.Empty:
                return
