"""HAMax: Heterogeneous Active Messages (Noack, 2019) for JAX at pod scale.

Subpackages: ``core`` (the paper's RPC mechanism), ``comm`` (transports),
``offload`` (HAM-Offload API), ``models`` (the 10 assigned architectures),
``kernels`` (Pallas TPU hot spots), ``data``/``optim``/``ckpt``/``train``/
``serve`` (fleet substrate), ``configs`` (arch configs), ``launch`` (mesh,
multi-pod dry-run, roofline, hillclimb).
"""

__version__ = "1.0.0"
