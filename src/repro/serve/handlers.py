"""Cluster-serving control handlers, importable by worker processes.

These live apart from ``repro.serve.engine`` because the *registering*
module must be cheap to import everywhere: a worker derives its import
list from the modules that define the host's handlers
(:func:`repro.offload.worker.registered_setup_modules`), and if the
handlers lived in ``engine.py`` every fresh-interpreter worker would pull
the full jax stack at spawn just to re-register two control functions.
Here the module-level registration (static initialisation, paper §4.3)
costs a numpy import; the engine itself is only imported by nodes that
actually host a serving replica.
"""

from __future__ import annotations

import numpy as np

from repro.core.errors import RegistrySealedError

#: engines owned by pool workers, keyed by the identity of the worker's
#: NodeRuntime — handlers resolve "their" engine via current_node().  (One
#: entry per live runtime; ClusterServingEngine.close() removes its own.)
_NODE_ENGINES: dict[int, object] = {}


def _h_serve_admit(prompt, rid, max_new_tokens, temperature):
    """Admit one request into this node's engine (prefill runs HERE, on the
    worker, overlapping other workers' decode steps).  Returns the first
    generated token."""
    from repro.core.errors import OffloadError
    from repro.offload.runtime import current_node
    from repro.serve.engine import Request

    eng = _NODE_ENGINES.get(id(current_node()))
    if eng is None:
        # the replica was retired (node mid-removal) or never built (a
        # non-local worker mode) — fail diagnosably; the driver only admits
        # through serving_nodes(), so reaching this is a routing bug
        raise OffloadError("no serving-engine replica on this worker")
    free = eng.free_slots()
    if not free:
        # a session re-placed here by a death mid-admission (the router's
        # eligible= restriction applies to the engine's placement, not to a
        # re-placement inside Scheduler.submit) — fail diagnosably rather
        # than IndexError; the driver surfaces it as RemoteExecutionError
        raise OffloadError("no free serving slot on this worker")
    slot = free[0]
    req = Request(
        prompt=np.asarray(prompt, np.int32),
        max_new_tokens=int(max_new_tokens),
        temperature=float(temperature),
        rid=int(rid),
    )
    eng.admit(req, slot)
    return [int(rid), int(eng.outputs[req.rid][0])]


def _h_serve_step():
    """One decode step of this node's engine; returns the emitted
    ``[rid, token]`` pairs plus the engine's free-slot count (ground truth
    for the driver's admission accounting)."""
    from repro.offload.runtime import current_node

    eng = _NODE_ENGINES[id(current_node())]
    emitted = eng.step()
    return [[int(r), int(t)] for r, t in emitted], len(eng.free_slots())


def register_serve_handlers(registry=None) -> None:
    """Register the cluster-serving handlers.  Safe to call repeatedly;
    silently skipped on an already-sealed registry (as with the cluster /
    dataplane sets — then callers must have registered before ``init()``)."""
    from repro.core.registry import default_registry

    # both handlers mutate the per-node engine (admission writes a prompt
    # cache into the batch; step advances it) — never replica-servable
    reg = registry or default_registry()
    for name, fn, read_only in (("_serve/admit", _h_serve_admit, False),
                                ("_serve/step", _h_serve_step, False)):
        try:
            reg.register(fn, name=name, read_only=read_only)
        except RegistrySealedError:
            return


# module import = static initialisation: a worker that imports this module
# (because the host's registry includes _serve/*) re-derives the same keys
register_serve_handlers()
