"""Cluster-serving control handlers, importable by worker processes.

These live apart from ``repro.serve.engine`` because the *registering*
module must be cheap to import everywhere: a worker derives its import
list from the modules that define the host's handlers
(:func:`repro.offload.worker.registered_setup_modules`), and if the
handlers lived in ``engine.py`` every fresh-interpreter worker would pull
the full jax stack at spawn just to re-register a few control functions.
Here the module-level registration (static initialisation, paper §4.3)
costs a numpy import; the engine itself is only imported by nodes that
actually host a serving replica.

Two handler sets register here:

* the **lockstep** pair ``_serve/admit`` / ``_serve/step`` — the host
  drives every decode step (kept behind ``worker_driven=False``);
* the **worker-driven** trio (docs/serving.md): ``_serve/admit_stream``
  (host->worker slot lease, FLAG_STATIC — the prompt rides padded to
  ``MAX_PROMPT`` so the payload is plan-packed with fixed extents),
  ``_serve/cancel`` (host->worker oneway), and ``_serve/stream``
  (worker->host fused token oneways).  The stream handlers are all-scalar
  static specs, so each token message plan-packs into a tiny fixed-size
  segment — the FLAG_FUSED fast path end to end.
"""

from __future__ import annotations

import numpy as np

from repro.core.errors import RegistrySealedError
from repro.core.migratable import ArraySpec, ScalarSpec

#: wire bound on a (padded) admission prompt: prompt + replayed tokens of a
#: continuation re-admit must fit.  A fixed extent is what makes the admit
#: payload FLAG_STATIC (plan-packed, no per-message descriptors).
MAX_PROMPT = 512

#: engines owned by pool workers, keyed by the identity of the worker's
#: NodeRuntime — handlers resolve "their" engine via current_node().  (One
#: entry per live runtime; ClusterServingEngine.close() removes its own.)
_NODE_ENGINES: dict[int, object] = {}

#: worker decode loops (repro.serve.stream.WorkerDecodeLoop), same keying
_NODE_LOOPS: dict[int, object] = {}

#: host-side token sinks, keyed by id(host runtime): the `_serve/stream`
#: handler forwards each token message to its engine's bookkeeping callback
_STREAM_SINKS: dict[int, object] = {}

#: host-side block sinks (`_serve/stream_block`), same keying: one message
#: carries a whole fused decode block's tokens for one request
_STREAM_BLOCK_SINKS: dict[int, object] = {}

#: wire bound on tokens per `_serve/stream_block` message (fixed extent =
#: plan-packed static payload; a decode block larger than this is chunked)
STREAM_BLOCK_MAX = 32

_I8 = ScalarSpec("i8")
_F8 = ScalarSpec("f8")

#: padded prompt, prompt_len, rid, gen, max_new_tokens, temperature, deadline_s
ADMIT_STREAM_SPECS = (ArraySpec((MAX_PROMPT,), "int32"),
                      _I8, _I8, _I8, _I8, _F8, _F8)
#: node, rid, gen, seq, token, status, free_slots
STREAM_SPECS = (_I8, _I8, _I8, _I8, _I8, _I8, _I8)
#: node, rid, gen, seq0, count, tokens (padded), status, free_slots
STREAM_BLOCK_SPECS = (_I8, _I8, _I8, _I8, _I8,
                      ArraySpec((STREAM_BLOCK_MAX,), "int32"), _I8, _I8)
#: rid, gen, status
CANCEL_SPECS = (_I8, _I8, _I8)


def pad_prompt(prompt: np.ndarray) -> np.ndarray:
    """Zero-pad a prompt to the fixed ``MAX_PROMPT`` wire extent."""
    prompt = np.asarray(prompt, np.int32)
    if prompt.shape[0] > MAX_PROMPT:
        from repro.core.errors import OffloadError

        raise OffloadError(
            f"prompt of {prompt.shape[0]} tokens exceeds the serve wire "
            f"bound MAX_PROMPT={MAX_PROMPT}"
        )
    out = np.zeros(MAX_PROMPT, np.int32)
    out[: prompt.shape[0]] = prompt
    return out


# -- lockstep handlers ------------------------------------------------------


def _h_serve_admit(prompt, rid, max_new_tokens, temperature):
    """Admit one request into this node's engine (prefill runs HERE, on the
    worker, overlapping other workers' decode steps).  Returns the first
    generated token."""
    from repro.core.errors import OffloadError
    from repro.offload.runtime import current_node
    from repro.serve.engine import Request

    eng = _NODE_ENGINES.get(id(current_node()))
    if eng is None:
        # the replica was retired (node mid-removal) or never built (a
        # non-local worker mode) — fail diagnosably; the driver only admits
        # through serving_nodes(), so reaching this is a routing bug
        raise OffloadError("no serving-engine replica on this worker")
    free = eng.free_slots()
    if not free:
        # a session re-placed here by a death mid-admission (the router's
        # eligible= restriction applies to the engine's placement, not to a
        # re-placement inside Scheduler.submit) — fail diagnosably rather
        # than IndexError; the driver surfaces it as RemoteExecutionError
        raise OffloadError("no free serving slot on this worker")
    slot = free[0]
    req = Request(
        prompt=np.asarray(prompt, np.int32),
        max_new_tokens=int(max_new_tokens),
        temperature=float(temperature),
        rid=int(rid),
    )
    eng.admit(req, slot)
    return [int(rid), int(eng.outputs[req.rid][0])]


def _h_serve_step():
    """One decode step of this node's engine; returns the emitted
    ``[rid, token]`` pairs plus the engine's free-slot count (ground truth
    for the driver's admission accounting)."""
    from repro.offload.runtime import current_node

    eng = _NODE_ENGINES[id(current_node())]
    emitted = eng.step()
    return [[int(r), int(t)] for r, t in emitted], len(eng.free_slots())


# -- worker-driven handlers (docs/serving.md) -------------------------------


def _h_serve_admit_stream(prompt, prompt_len, rid, gen, max_new_tokens,
                          temperature, deadline_s):
    """Slot lease: queue one request into this worker's decode loop.  The
    ONLY host round trip a request needs — prefill, every decode step, and
    token emission happen on the worker from here on.  Returns the
    ``[rid, gen]`` lease ack (tokens travel separately via _serve/stream)."""
    from repro.core.errors import OffloadError
    from repro.offload.runtime import current_node

    loop = _NODE_LOOPS.get(id(current_node()))
    if loop is None:
        raise OffloadError("no worker decode loop on this node")
    loop.enqueue_admit(
        np.asarray(prompt[: int(prompt_len)], np.int32), int(rid), int(gen),
        int(max_new_tokens), float(temperature), float(deadline_s),
    )
    return [int(rid), int(gen)]


def _h_serve_cancel(rid, gen, status):
    """Cancel oneway: the request leaves the running batch at the loop's
    next step; the loop acks with a `_serve/stream` end-of-stream marker
    (unconditionally — even for a request it never saw)."""
    from repro.offload.runtime import current_node

    loop = _NODE_LOOPS.get(id(current_node()))
    if loop is not None:
        loop.cancel(int(rid), int(gen), int(status))


def _h_serve_stream(node, rid, gen, seq, token, status, free_slots):
    """Host-side token sink: one decoded token (or end-of-stream marker)
    from a worker's decode loop, riding a fused oneway.  Dropped silently
    when no sink is registered (engine torn down mid-stream)."""
    from repro.offload.runtime import current_node

    sink = _STREAM_SINKS.get(id(current_node()))
    if sink is not None:
        sink(int(node), int(rid), int(gen), int(seq), int(token),
             int(status), int(free_slots))


def _h_serve_stream_block(node, rid, gen, seq0, count, tokens, status,
                          free_slots):
    """Host-side block sink: one fused decode block's tokens for a single
    request in ONE plan-packed segment — per-message dispatch cost is paid
    once per block instead of once per token.  ``seq0`` is the sequence
    number of the first token; ``status`` applies to the LAST token (the
    earlier ones are implicitly STREAM_TOKEN)."""
    from repro.offload.runtime import current_node

    sink = _STREAM_BLOCK_SINKS.get(id(current_node()))
    if sink is not None:
        sink(int(node), int(rid), int(gen), int(seq0),
             np.asarray(tokens[: int(count)], np.int64), int(status),
             int(free_slots))


def register_serve_handlers(registry=None) -> None:
    """Register the cluster-serving handlers.  Safe to call repeatedly;
    silently skipped on an already-sealed registry (as with the cluster /
    dataplane sets — then callers must have registered before ``init()``)."""
    from repro.core.registry import default_registry

    # every handler mutates node-local serving state (admission writes a
    # prompt cache into the batch; step/stream advance it) — never
    # replica-servable
    reg = registry or default_registry()
    for name, fn, specs in (
        ("_serve/admit", _h_serve_admit, None),
        ("_serve/step", _h_serve_step, None),
        ("_serve/admit_stream", _h_serve_admit_stream, ADMIT_STREAM_SPECS),
        ("_serve/cancel", _h_serve_cancel, CANCEL_SPECS),
        ("_serve/stream", _h_serve_stream, STREAM_SPECS),
        ("_serve/stream_block", _h_serve_stream_block, STREAM_BLOCK_SPECS),
    ):
        try:
            reg.register(fn, name=name, arg_specs=specs, read_only=False)
        except RegistrySealedError:
            return


# module import = static initialisation: a worker that imports this module
# (because the host's registry includes _serve/*) re-derives the same keys
register_serve_handlers()
