"""Worker-resident decode loop: self-stepping continuous batching.

The worker-driven half of cluster serving (docs/serving.md).  The host's
role shrinks to *admission*: one ``_serve/admit_stream`` call leases a slot
and hands over the prompt; from then on this loop steps the worker's
:class:`~repro.serve.engine.ServingEngine` replica **without any host
involvement** — requests join and leave the running batch at block
boundaries, and tokens travel back as oneways.  Each loop iteration runs
one *fused decode block* (``engine.step_many``: a ``lax.scan`` over the
device handler table, amortising per-dispatch overhead across ``block``
steps), then ships each request's block of tokens as ONE
``_serve/stream_block`` segment (single-token messages and end-of-stream
acks ride ``_serve/stream``).  All segments produced by one iteration are
packed into a single ``FLAG_FUSED`` frame: one header, one transport
publication, one host dispatch pass per block — the fused-egress
economics of the RPC fast path applied to token streaming.

The loop parks on its doorbell (a condition variable) whenever the batch is
empty and nothing is queued — an idle replica costs no CPU (the engine's
``step()`` early-out is the in-batch half of the same economy: a fully
idle batch never dispatches the padded noop step).

Delivery/ordering contract (asserted by the stream tests):

* per-request ordering — all stream calls for a request are emitted by one
  thread and ride per-link FIFO frames, so ``seq`` arrives strictly
  ascending within a ``(rid, gen)`` generation;
* at-most-once per generation — the host increments ``gen`` before
  re-admitting a request elsewhere (death recovery), so stragglers from a
  dead worker's loop carry a stale ``gen`` and are dropped on arrival;
* cancel/expiry acks are unconditional — a cancel for a request this loop
  has never seen (e.g. the admit died in flight) still acks, so the host
  never waits on a tombstone.

This module is jax-free at import time (the engine object is injected);
only nodes that actually host a replica pay for the jax stack.
"""

from __future__ import annotations

import threading
import time
from collections import deque

import numpy as np

from repro.core.flags import (
    STREAM_CANCELLED,
    STREAM_DONE,
    STREAM_TOKEN,
)

__all__ = ["WorkerDecodeLoop"]

#: (rid, gen) pairs already cancelled — an admit that loses the race with
#: its own cancel is dropped instead of decoding as a zombie
_TOMBSTONE_CAP = 256


class WorkerDecodeLoop:
    """One self-stepping decode thread bound to (runtime, engine replica).

    The admit/cancel entry points are called from the worker's event-loop
    thread (handler context) and only enqueue + ring the doorbell; all
    engine mutation happens on the loop thread, so the jax payload is
    single-threaded by construction.
    """

    def __init__(self, runtime, engine, *, host_node: int = 0,
                 registry=None, name: str = "", block: int = 16):
        self._rt = runtime
        self._eng = engine
        self._host = int(host_node)
        self._registry = registry
        #: decode steps fused per loop iteration (engine.step_many): the
        #: per-dispatch overhead is paid once per block, and one fused
        #: frame carries the whole block's tokens.  Admission, cancel and
        #: deadline checks run between blocks, so their latency is bounded
        #: by block * step_time (microscopic next to the TTFT SLO).
        self._block = max(1, int(block))
        self._cv = threading.Condition()
        #: queued admissions: (prompt, rid, gen, max_new, temp, deadline_s)
        self._admits: deque = deque()
        #: cancel requests: (rid, gen, status)
        self._cancels: list[tuple[int, int, int]] = []
        self._tombstones: deque = deque(maxlen=_TOMBSTONE_CAP)
        #: rid -> {gen, seq, remaining, expires} for requests in the batch
        self._live: dict[int, dict] = {}
        self._stop = False
        self.stats = {"steps": 0, "tokens": 0, "frames": 0, "parks": 0,
                      "expired": 0, "cancelled": 0}
        self._thread = threading.Thread(
            target=self._run, name=f"ham-decode-loop{name}", daemon=True
        )
        self._thread.start()

    # -- handler-side entry points (worker event-loop thread) --------------

    def enqueue_admit(self, prompt: np.ndarray, rid: int, gen: int,
                      max_new_tokens: int, temperature: float,
                      deadline_s: float) -> None:
        with self._cv:
            if self._stop:
                from repro.core.errors import OffloadError

                raise OffloadError("decode loop is stopped on this worker")
            self._admits.append((prompt, rid, gen, max_new_tokens,
                                 temperature, deadline_s))
            self._cv.notify()

    def cancel(self, rid: int, gen: int, status: int) -> None:
        with self._cv:
            self._cancels.append((rid, gen, status))
            self._cv.notify()

    def stop(self, join: bool = True) -> None:
        with self._cv:
            self._stop = True
            self._cv.notify()
        if join and self._thread is not threading.current_thread():
            self._thread.join(timeout=5.0)

    # -- loop internals (decode thread only) --------------------------------

    def _idle(self) -> bool:
        return (not self._admits and not self._cancels
                and all(r is None for r in self._eng.slot_req))

    def _stream_call(self, f2f, rid: int, gen: int, seq: int, token: int,
                     status: int):
        return f2f(
            "_serve/stream", int(self._rt.node_id), int(rid), int(gen),
            int(seq), int(token), int(status),
            len(self._eng.free_slots()), registry=self._registry,
        )

    def _stream_block_call(self, f2f, rid: int, gen: int, seq0: int,
                           toks: list, status: int):
        from repro.serve.handlers import STREAM_BLOCK_MAX

        buf = np.zeros(STREAM_BLOCK_MAX, np.int32)
        buf[: len(toks)] = toks
        return f2f(
            "_serve/stream_block", int(self._rt.node_id), int(rid),
            int(gen), int(seq0), len(toks), buf, int(status),
            len(self._eng.free_slots()), registry=self._registry,
        )

    def _finish(self, f2f, rid: int, status: int, calls: list) -> None:
        """A request leaves the running batch without emitting: free its
        slot now (the next step simply doesn't include it) and ack the
        departure downstream."""
        live = self._live.pop(rid)
        self._eng.evict(rid)
        self._tombstones.append((rid, live["gen"]))
        calls.append(self._stream_call(f2f, rid, live["gen"], live["seq"],
                                       -1, status))

    def _run(self) -> None:
        from repro.core.closure import f2f

        eng = self._eng
        while True:
            with self._cv:
                while not self._stop and self._idle():
                    self.stats["parks"] += 1
                    self._cv.wait()
                if self._stop:
                    return
                cancels, self._cancels = self._cancels, []
                admits = []
                free = len(eng.free_slots())
                while self._admits and len(admits) < free:
                    admits.append(self._admits.popleft())
            calls: list = []
            now = time.monotonic()
            # 1. cancels and expiries leave the batch BEFORE this step
            for rid, gen, status in cancels:
                live = self._live.get(rid)
                if live is not None and live["gen"] == gen:
                    self.stats["cancelled"] += 1
                    self._finish(f2f, rid, status, calls)
                else:
                    # never seen (admit still in flight or already gone):
                    # tombstone the generation and ack unconditionally so
                    # the host-side cancel cannot hang
                    self._tombstones.append((rid, gen))
                    calls.append(self._stream_call(f2f, rid, gen, 0, -1,
                                                   status))
            for rid in [r for r, lv in self._live.items()
                        if lv["expires"] is not None
                        and now >= lv["expires"]]:
                from repro.core.flags import STREAM_EXPIRED

                self.stats["expired"] += 1
                self._finish(f2f, rid, STREAM_EXPIRED, calls)
            # 2. admissions into freed slots (prefill runs HERE, on the
            # worker, overlapping other replicas' decode steps)
            for i, (prompt, rid, gen, max_new, temp,
                    deadline_s) in enumerate(admits):
                if (rid, gen) in self._tombstones:
                    calls.append(self._stream_call(f2f, rid, gen, 0, -1,
                                                   STREAM_CANCELLED))
                    continue
                from repro.serve.engine import Request

                free_now = eng.free_slots()
                if not free_now:  # slots re-counted: defer the rest
                    with self._cv:
                        self._admits.extendleft(reversed(admits[i:]))
                    break
                slot = free_now[0]
                eng.admit(Request(prompt=prompt, max_new_tokens=max_new,
                                  temperature=temp, rid=rid), slot)
                first = int(eng.outputs[rid][0])
                live = {
                    "gen": gen, "seq": 1, "remaining": max_new - 1,
                    "expires": now + deadline_s if deadline_s > 0 else None,
                }
                if max_new <= 1:
                    # single-token lease: the prefill's argmax IS the whole
                    # request — free the slot without a decode step
                    eng.evict(rid)
                    self._tombstones.append((rid, gen))
                    status = STREAM_DONE
                else:
                    self._live[rid] = live
                    status = STREAM_TOKEN
                self.stats["tokens"] += 1
                calls.append(self._stream_call(f2f, rid, gen, 0, first,
                                               status))
            # 3. one fused block of batched decode steps ([] when empty):
            # per-dispatch overhead amortised over the whole block
            emitted = eng.step_many(self._block)
            if emitted:
                self.stats["steps"] += 1
            # group each request's tokens (emitted is step-major, so the
            # per-request order is already ascending) and ship ONE
            # _serve/stream_block segment per request per block
            by_rid: dict[int, list[int]] = {}
            for rid, tok in emitted:
                by_rid.setdefault(rid, []).append(int(tok))
            from repro.serve.handlers import STREAM_BLOCK_MAX

            for rid, toks in by_rid.items():
                live = self._live.get(rid)
                if live is None:
                    continue  # evicted mid-iteration
                live["remaining"] -= len(toks)
                done = live["remaining"] <= 0
                self.stats["tokens"] += len(toks)
                for i in range(0, len(toks), STREAM_BLOCK_MAX):
                    chunk = toks[i : i + STREAM_BLOCK_MAX]
                    last = i + len(chunk) >= len(toks)
                    status = STREAM_DONE if (done and last) else STREAM_TOKEN
                    calls.append(self._stream_block_call(
                        f2f, rid, live["gen"], live["seq"], chunk, status))
                    live["seq"] += len(chunk)
                if done:
                    self._live.pop(rid, None)
                    self._tombstones.append((rid, live["gen"]))
            if calls:
                self._flush(calls)

    def _flush(self, calls: list) -> None:
        """Ship this iteration's stream calls as fused oneways: msg_id 0
        segments in FLAG_FUSED frames (one frame per FUSE_MAX_SEGMENTS)."""
        from repro.offload.runtime import FUSE_MAX_SEGMENTS

        try:
            if len(calls) == 1:
                self._rt.send_oneway(self._host, calls[0])
            else:
                for i in range(0, len(calls), FUSE_MAX_SEGMENTS):
                    self._rt._send_fused_request(
                        self._host,
                        [(fn, 0) for fn in calls[i : i + FUSE_MAX_SEGMENTS]],
                    )
            self.stats["frames"] += 1
        except Exception:  # noqa: BLE001 — transport died under the loop
            # (worker killed mid-send): the host transcript re-derives the
            # tokens on a survivor; stop arrives via the replica teardown
            time.sleep(0.001)
