"""Serving engine: continuous batching over a HAM device handler table.

This is where the paper's mechanism lands on the accelerator (DESIGN.md §2).
All per-step behaviours — greedy decode, temperature sampling, and a
``noop`` padding step (straggler/bubble filler) — are **branches of one
compiled ``lax.switch`` table** sharing a payload spec::

    payload = {cache, tokens (B,1), pos (B,), rng, temp}

Step *selection* is therefore an integer key fed as device data: no
re-trace, no executable swap, no host round-trip per behaviour change —
HAM's O(1) key dispatch, compiled.  Slots admit new requests by writing a
prefilled prompt cache into the batch cache (continuous batching).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.device_table import DeviceHandlerTable
from repro.core.future import Future


@dataclasses.dataclass
class Request:
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0     # 0 => greedy
    rid: int = -1


def build_serve_table(model, params, *, sharder=None, window=None):
    """Device handler table over decode-step behaviours."""
    table = DeviceHandlerTable()

    def _next_from_logits(logits, payload, sample: bool):
        rng, sub = jax.random.split(payload["rng"])
        greedy = jnp.argmax(logits[:, -1, :], axis=-1)
        if sample:
            temp = jnp.maximum(payload["temp"], 1e-4)
            draw = jax.random.categorical(sub, logits[:, -1, :] / temp, axis=-1)
            nxt = jnp.where(payload["temp"] > 0, draw, greedy)
        else:
            nxt = greedy
        return nxt.astype(jnp.int32)[:, None], rng

    def decode_greedy(payload):
        logits, cache = model.decode_step(
            params, payload["cache"],
            {"tokens": payload["tokens"], "pos": payload["pos"]},
            sharder=sharder,
        )
        nxt, rng = _next_from_logits(logits, payload, sample=False)
        return {"cache": cache, "tokens": nxt, "pos": payload["pos"] + 1,
                "rng": rng, "temp": payload["temp"]}

    def decode_sample(payload):
        logits, cache = model.decode_step(
            params, payload["cache"],
            {"tokens": payload["tokens"], "pos": payload["pos"]},
            sharder=sharder,
        )
        nxt, rng = _next_from_logits(payload=payload, logits=logits, sample=True)
        return {"cache": cache, "tokens": nxt, "pos": payload["pos"] + 1,
                "rng": rng, "temp": payload["temp"]}

    def noop(payload):
        # bubble/straggler filler: burns a step slot without touching state
        return dict(payload)

    table.register("serve/decode_greedy", decode_greedy)
    table.register("serve/decode_sample", decode_sample)
    table.register("serve/noop", noop)
    table.seal()
    return table


class ServingEngine:
    """Continuous-batching loop on top of the compiled dispatch table."""

    def __init__(self, model, params, *, num_slots: int, max_len: int,
                 sharder=None, seed: int = 0, donate: bool = True):
        self.model = model
        self.params = params
        self.B = num_slots
        self.max_len = max_len
        self.table = build_serve_table(model, params, sharder=sharder)
        cache = model.init_cache(num_slots, max_len)
        self.payload = {
            "cache": cache,
            "tokens": jnp.zeros((num_slots, 1), jnp.int32),
            "pos": jnp.zeros((num_slots,), jnp.int32),
            "rng": jax.random.PRNGKey(seed),
            "temp": jnp.zeros((), jnp.float32),
        }
        spec = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), self.payload
        )
        self.dispatch = self.table.build(spec, donate_payload=donate)
        self.key_greedy = self.table.key_of("serve/decode_greedy")
        self.key_sample = self.table.key_of("serve/decode_sample")
        self.key_noop = self.table.key_of("serve/noop")
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, sharder=sharder)
        )
        # slot bookkeeping (host side)
        self.slot_req: list[Request | None] = [None] * num_slots
        self.slot_remaining = np.zeros(num_slots, np.int64)
        self.outputs: dict[int, list[int]] = {}
        self.steps_dispatched = 0

    # -- slot admission ----------------------------------------------------------

    def _insert_cache(self, prompt_cache, slot: int) -> None:
        """Write a single-sequence prompt cache into the batch cache at
        ``slot``.  Each leaf's batch axis is the axis where the prompt leaf
        has extent 1 and the full cache has ``num_slots``; prompt caches
        shorter than max_len (KV) land at offset 0 via dynamic_update_slice.
        """

        def ins(full, part):
            part = part.astype(full.dtype)
            batch_axis = None
            for a in range(full.ndim):
                if part.shape[a] == 1 and full.shape[a] == self.B:
                    batch_axis = a
                    break
            if batch_axis is None:  # B == 1 or already matching: overwrite
                batch_axis = 0 if full.shape == part.shape else None
            starts = [0] * full.ndim
            if batch_axis is not None:
                starts[batch_axis] = slot
            return jax.lax.dynamic_update_slice(full, part, tuple(starts))

        self.payload["cache"] = jax.tree_util.tree_map(
            ins, self.payload["cache"], prompt_cache
        )

    def admit(self, req: Request, slot: int) -> None:
        prompt = np.asarray(req.prompt, np.int32)[None, :]  # (1, S)
        batch = {"tokens": jnp.asarray(prompt)}
        logits, prompt_cache = self._prefill(self.params, batch)
        self._insert_cache(prompt_cache, slot)
        first = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        self.payload["tokens"] = self.payload["tokens"].at[slot, 0].set(first[0])
        self.payload["pos"] = self.payload["pos"].at[slot].set(prompt.shape[1])
        self.slot_req[slot] = req
        self.slot_remaining[slot] = req.max_new_tokens - 1
        self.outputs[req.rid] = [int(first[0])]

    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    # -- stepping ------------------------------------------------------------------

    def step(self, key: int | None = None) -> list[tuple[int, int]]:
        """One batched decode step through the device dispatch table.

        Returns the ``(rid, token)`` pairs emitted this step (empty for a
        noop step) — the unit a pool driver streams back per completion.
        """
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if key is None:
            if not active:
                key = self.key_noop
            elif any(r is not None and r.temperature > 0 for r in self.slot_req):
                key = self.key_sample
            else:
                key = self.key_greedy
        temps = max((r.temperature for r in self.slot_req if r is not None),
                    default=0.0)
        self.payload["temp"] = jnp.asarray(temps, jnp.float32)
        self.payload = self.dispatch(jnp.asarray(key, jnp.int32), self.payload)
        self.steps_dispatched += 1
        if key == self.key_noop:
            return []
        toks = np.asarray(self.payload["tokens"][:, 0])
        emitted: list[tuple[int, int]] = []
        for slot in active:
            req = self.slot_req[slot]
            tok = int(toks[slot])
            emitted.append((req.rid, tok))
            self.outputs[req.rid].append(tok)
            self.slot_remaining[slot] -= 1
            if self.slot_remaining[slot] <= 0:
                self.slot_req[slot] = None
        return emitted

    def run(self, requests: list[Request]) -> dict[int, list[int]]:
        """Serve a request list to completion with continuous batching."""
        for i, r in enumerate(requests):
            if r.rid < 0:
                r.rid = i
        pending = list(requests)
        while pending or any(r is not None for r in self.slot_req):
            for slot in self.free_slots():
                if not pending:
                    break
                self.admit(pending.pop(0), slot)
            self.step()
        return self.outputs


# --------------------------------------------------------------------------
# cluster serving: continuous batching driven through the worker pool
# --------------------------------------------------------------------------

# the control handlers and their replica map live in repro.serve.handlers
# (a jax-free module, cheap for fresh-interpreter workers to re-import);
# re-exported here for callers that predate the split
from repro.serve.handlers import (  # noqa: E402,F401
    _NODE_ENGINES,
    register_serve_handlers,
)


class ClusterServingEngine:
    """Continuous batching sharded across a worker pool.

    One :class:`ServingEngine` replica per pool worker (thread workers —
    the replicas share the process and its jax devices); the host drives
    them through a :class:`~repro.cluster.scheduler.Scheduler` with one
    pipelined step call in flight per active worker, so decode steps for
    different request slots overlap across workers (compiled jax steps
    release the GIL).  Admissions are async too: a prefill on worker A
    overlaps decode on worker B.

    Request routing goes through the scheduler's :class:`SessionRouter`:
    each request is a session keyed ``serve/<rid>``, placed once by
    rendezvous hash over the workers *with a free slot* at admission time,
    then pinned — every subsequent call for that request lands on the
    worker holding its KV cache, and an unrelated pool resize cannot move
    it (the stickiness contract in ``repro.cluster.sessions``).  The
    engine's slot accounting stays its own (the router knows placement,
    not capacity).

    **Serving elasticity** (ROADMAP): engine replicas follow pool
    membership, not construction — ``on_join``/``on_restart`` build a
    replica for the newcomer, ``on_leave``/``on_death`` retire it (a
    drained removal drops the replica only after the node's in-flight
    steps finish), so serving survives ``pool.add_node()`` /
    ``pool.remove_node()`` mid-run and newly added capacity takes
    admissions immediately.

    **Session recovery**: the host is the system of record for every
    admitted request (prompt + every emitted token), which makes a
    worker's KV state *reconstructible*: when a worker dies mid-decode,
    :meth:`run` re-admits its requests on a survivor with the
    concatenated ``prompt + tokens-so-far`` as the new prefill — the
    session re-places (its old pin died), decode continues exactly where
    it stopped, and no emitted token is lost.  A completed request ends
    its session through ``Scheduler.end_session`` (which also releases
    any directory-tracked buffers bound to it).
    """

    def __init__(self, model, params, *, num_workers: int = 2,
                 slots_per_worker: int = 2, max_len: int, seed: int = 0,
                 registry=None):
        from repro.cluster.pool import ClusterPool, register_cluster_handlers
        from repro.cluster.scheduler import Scheduler
        from repro.core.registry import HandlerRegistry
        from repro.offload.runtime import register_internal_handlers

        if registry is None:
            registry = HandlerRegistry()
            register_internal_handlers(registry)
            register_cluster_handlers(registry)
            register_serve_handlers(registry)
            registry.init()
        self.registry = registry
        self.slots_per_worker = slots_per_worker
        self._model, self._params = model, params
        self._max_len, self._seed = max_len, seed
        self.pool = ClusterPool.local(num_workers, registry=registry)
        self.sched = Scheduler(self.pool, policy="least_outstanding",
                               max_inflight=slots_per_worker + 2)
        self._engine_keys: dict[int, int] = {}  # node -> id(runtime)
        for node in self.pool.worker_nodes:
            self._add_replica(node)
        # serving elasticity: replicas track membership from here on
        self.pool.on_join(self._add_replica)
        self.pool.on_restart(self._add_replica)
        self.pool.on_death(self._drop_replica)
        self.pool.on_leave(self._on_leave)

    # -- replica lifecycle (elasticity contract in the class docs) ---------

    def _add_replica(self, node: int) -> None:
        rt = self.pool.domain._inproc.get(node)
        if rt is None:
            return  # non-local worker modes build engines worker-side
        self._drop_replica(node)  # a restarted node gets a fresh engine
        _NODE_ENGINES[id(rt)] = ServingEngine(
            self._model, self._params, num_slots=self.slots_per_worker,
            max_len=self._max_len, seed=self._seed + node,
        )
        self._engine_keys[node] = id(rt)

    def _drop_replica(self, node: int) -> None:
        key = self._engine_keys.pop(node, None)
        if key is not None:
            _NODE_ENGINES.pop(key, None)

    def _on_leave(self, node: int):
        # retire the replica only AFTER the scheduler's drain waiter let the
        # node's in-flight steps finish (waiters run in subscription order;
        # the scheduler subscribed first)
        def waiter(timeout: float | None = None) -> None:
            self._drop_replica(node)

        return waiter

    def serving_nodes(self) -> list[int]:
        """Live workers that currently hold an engine replica."""
        live = set(self.sched.live_nodes())
        return sorted(n for n in self._engine_keys if n in live)

    def run(self, requests: list[Request],
            timeout: float = 300.0) -> dict[int, list[int]]:
        """Serve ``requests`` to completion, pipelining across workers;
        survives pool resizes and worker deaths mid-run (class docs).
        ``timeout`` bounds the whole drive loop."""
        import queue as _queue
        import time

        from repro.core.closure import f2f
        from repro.core.errors import OffloadError

        for i, r in enumerate(requests):
            if r.rid < 0:
                r.rid = i
        pending = list(requests)
        outputs: dict[int, list[int]] = {}
        budget = {r.rid: r.max_new_tokens for r in requests}
        temp = {r.rid: r.temperature for r in requests}
        prompt0 = {r.rid: np.asarray(r.prompt, np.int32) for r in requests}
        placed: dict[int, int] = {}  # rid -> node currently decoding it
        # per-node occupancy: `active` is ground truth as of the last reply
        # from that node; `queued` counts admits submitted but unconfirmed
        active: dict[int, int] = {}
        queued: dict[int, int] = {}
        stepping: dict[int, bool] = {}
        inflight: dict[Future, tuple[str, int, int | None]] = {}
        # one persistent completion queue for the whole drive: every
        # submitted future pushes itself here exactly once when done
        done_q: _queue.SimpleQueue = _queue.SimpleQueue()
        deadline = time.monotonic() + timeout
        reg = self.registry

        def track(fut: Future, kind: str, node: int,
                  rid: int | None = None) -> None:
            inflight[fut] = (kind, node, rid)
            fut.add_done_callback(done_q.put)

        def requeue(rid: int) -> None:
            """Continuation admit: prefill of prompt + tokens-so-far picks
            up decode exactly where the dead worker stopped."""
            done_toks = outputs.get(rid, [])
            remaining = budget[rid] - len(done_toks)
            if remaining <= 0:
                return  # finished just before the crash
            pending.append(Request(
                prompt=np.concatenate(
                    [prompt0[rid], np.asarray(done_toks, np.int32)]
                ),
                max_new_tokens=remaining,
                temperature=temp[rid],
                rid=rid,
            ))

        def recover_node(node: int) -> None:
            """A serving node died: its replica's KV is gone, but the host
            holds prompt + every emitted token — re-queue its requests as
            continuation admits on a survivor."""
            active[node] = 0
            queued[node] = 0
            stepping[node] = False
            for rid in [r for r, n in placed.items() if n == node]:
                placed.pop(rid, None)
                requeue(rid)

        while pending or inflight or any(active.values()):
            nodes = self.serving_nodes()
            # death sweep: a victim with NO call in flight produces no
            # failed future (its last step reply may have been processed
            # before the monitor marked it dead) — reap by state, not only
            # by exception, or its requests would be orphaned silently
            busy = set(placed.values()) \
                | {n for n, a in active.items() if a} \
                | {n for n, q in queued.items() if q}
            for node in busy - set(nodes):
                if not (self.pool.is_alive(node)
                        and node in self._engine_keys):
                    recover_node(node)
            # admission: place each request's session once (rendezvous hash
            # over workers with a free slot), then submit THROUGH the router
            # so the admit sticks to the placement.  A request whose live
            # pin is full waits for a slot THERE (KV must not split across
            # workers) but must not block admission of the requests behind
            # it — scan past it to the first admissible request instead
            while pending and nodes:
                free = [
                    n for n in nodes
                    if active.get(n, 0) + queued.get(n, 0)
                    < self.slots_per_worker
                ]
                if not free:
                    break
                admit_idx = None
                node = None
                for idx, req in enumerate(pending):
                    placed_node = self.sched.sessions.route(
                        f"serve/{req.rid}", eligible=free
                    )
                    if placed_node is not None and placed_node in free:
                        admit_idx, node = idx, placed_node
                        break
                if admit_idx is None:
                    break  # every pending request waits on a full pin
                req = pending.pop(admit_idx)
                queued[node] = queued.get(node, 0) + 1
                track(self.sched.submit(
                    f2f("_serve/admit", np.asarray(req.prompt, np.int32),
                        int(req.rid), int(req.max_new_tokens),
                        float(req.temperature), registry=reg),
                    session=f"serve/{req.rid}",
                ), "admit", node, req.rid)
            for node in nodes:
                if (active.get(node, 0) or queued.get(node, 0)) \
                        and not stepping.get(node, False):
                    stepping[node] = True
                    track(self.sched.submit(
                        f2f("_serve/step", registry=reg), node=node,
                    ), "step", node)
            if not inflight:
                if pending and not self.serving_nodes():
                    raise OffloadError(
                        "no live serving workers remain for "
                        f"{len(pending)} pending requests"
                    )
                if not pending:
                    break
                time.sleep(0.02)  # pinned worker full: wait for a slot
                continue
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TimeoutError(
                    f"cluster serve exceeded {timeout}s with "
                    f"{len(inflight)} calls in flight"
                )
            try:
                done = done_q.get(timeout=remaining)
            except _queue.Empty:
                raise TimeoutError(
                    f"cluster serve exceeded {timeout}s with "
                    f"{len(inflight)} calls in flight"
                ) from None
            kind, node, rid = inflight.pop(done)
            try:
                result = done.get(0)
            except Exception:
                # a dead/removed worker fails its in-flight calls; anything
                # else (slot bug, handler error) must surface.  Liveness is
                # checked at the pool (marked dead before futures fail), not
                # via serving_nodes(): the replica-drop callback may still
                # be a few callbacks behind the future rejection.
                if self.pool.is_alive(node) and node in self._engine_keys:
                    raise
                recover_node(node)
                if kind == "admit" and rid is not None and rid not in placed:
                    # the admit itself died in flight: its request is in no
                    # placed map — re-queue it explicitly
                    requeue(rid)
                continue
            if kind == "admit":
                rid, first = result
                queued[node] = queued.get(node, 0) - 1
                active[node] = active.get(node, 0) + 1
                placed[rid] = node
                # a recovery re-admit continues an existing transcript
                outputs.setdefault(rid, []).append(first)
                if len(outputs[rid]) >= budget[rid]:
                    placed.pop(rid, None)
            else:
                stepping[node] = False
                emitted, free = result
                active[node] = self.slots_per_worker - free
                for rid, tok in emitted:
                    # the slot-remaining accounting emits one trailing token
                    # for a single-token (re-)admission — cap the transcript
                    # at its budget so a continuation cannot over-emit
                    if len(outputs[rid]) < budget[rid]:
                        outputs[rid].append(tok)
                    if len(outputs[rid]) >= budget[rid]:
                        placed.pop(rid, None)
        for r in requests:  # sessions end with their requests
            self.sched.end_session(f"serve/{r.rid}")
        return outputs

    def close(self) -> None:
        for key in list(self._engine_keys.values()):
            _NODE_ENGINES.pop(key, None)
        self._engine_keys.clear()
        self.pool.close()
