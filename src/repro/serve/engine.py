"""Serving engine: continuous batching over a HAM device handler table.

This is where the paper's mechanism lands on the accelerator (DESIGN.md §2).
All per-step behaviours — greedy decode, temperature sampling, and a
``noop`` padding step (straggler/bubble filler) — are **branches of one
compiled ``lax.switch`` table** sharing a payload spec::

    payload = {cache, tokens (B,1), pos (B,), rng, temp}

Step *selection* is therefore an integer key fed as device data: no
re-trace, no executable swap, no host round-trip per behaviour change —
HAM's O(1) key dispatch, compiled.  Slots admit new requests by writing a
prefilled prompt cache into the batch cache (continuous batching).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.device_table import DeviceHandlerTable


@dataclasses.dataclass
class Request:
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0     # 0 => greedy
    rid: int = -1


def build_serve_table(model, params, *, sharder=None, window=None):
    """Device handler table over decode-step behaviours."""
    table = DeviceHandlerTable()

    def _next_from_logits(logits, payload, sample: bool):
        rng, sub = jax.random.split(payload["rng"])
        greedy = jnp.argmax(logits[:, -1, :], axis=-1)
        if sample:
            temp = jnp.maximum(payload["temp"], 1e-4)
            draw = jax.random.categorical(sub, logits[:, -1, :] / temp, axis=-1)
            nxt = jnp.where(payload["temp"] > 0, draw, greedy)
        else:
            nxt = greedy
        return nxt.astype(jnp.int32)[:, None], rng

    def decode_greedy(payload):
        logits, cache = model.decode_step(
            params, payload["cache"],
            {"tokens": payload["tokens"], "pos": payload["pos"]},
            sharder=sharder,
        )
        nxt, rng = _next_from_logits(logits, payload, sample=False)
        return {"cache": cache, "tokens": nxt, "pos": payload["pos"] + 1,
                "rng": rng, "temp": payload["temp"]}

    def decode_sample(payload):
        logits, cache = model.decode_step(
            params, payload["cache"],
            {"tokens": payload["tokens"], "pos": payload["pos"]},
            sharder=sharder,
        )
        nxt, rng = _next_from_logits(payload=payload, logits=logits, sample=True)
        return {"cache": cache, "tokens": nxt, "pos": payload["pos"] + 1,
                "rng": rng, "temp": payload["temp"]}

    def noop(payload):
        # bubble/straggler filler: burns a step slot without touching state
        return dict(payload)

    table.register("serve/decode_greedy", decode_greedy)
    table.register("serve/decode_sample", decode_sample)
    table.register("serve/noop", noop)
    table.seal()
    return table


class ServingEngine:
    """Continuous-batching loop on top of the compiled dispatch table."""

    def __init__(self, model, params, *, num_slots: int, max_len: int,
                 sharder=None, seed: int = 0, donate: bool = True):
        self.model = model
        self.params = params
        self.B = num_slots
        self.max_len = max_len
        self.table = build_serve_table(model, params, sharder=sharder)
        cache = model.init_cache(num_slots, max_len)
        self.payload = {
            "cache": cache,
            "tokens": jnp.zeros((num_slots, 1), jnp.int32),
            "pos": jnp.zeros((num_slots,), jnp.int32),
            "rng": jax.random.PRNGKey(seed),
            "temp": jnp.zeros((), jnp.float32),
        }
        spec = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), self.payload
        )
        self.dispatch = self.table.build(spec, donate_payload=donate)
        self.key_greedy = self.table.key_of("serve/decode_greedy")
        self.key_sample = self.table.key_of("serve/decode_sample")
        self.key_noop = self.table.key_of("serve/noop")
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, sharder=sharder)
        )
        # slot bookkeeping (host side)
        self.slot_req: list[Request | None] = [None] * num_slots
        self.slot_remaining = np.zeros(num_slots, np.int64)
        self.outputs: dict[int, list[int]] = {}
        self.steps_dispatched = 0

    # -- slot admission ----------------------------------------------------------

    def _insert_cache(self, prompt_cache, slot: int) -> None:
        """Write a single-sequence prompt cache into the batch cache at
        ``slot``.  Each leaf's batch axis is the axis where the prompt leaf
        has extent 1 and the full cache has ``num_slots``; prompt caches
        shorter than max_len (KV) land at offset 0 via dynamic_update_slice.
        """

        def ins(full, part):
            part = part.astype(full.dtype)
            batch_axis = None
            for a in range(full.ndim):
                if part.shape[a] == 1 and full.shape[a] == self.B:
                    batch_axis = a
                    break
            if batch_axis is None:  # B == 1 or already matching: overwrite
                batch_axis = 0 if full.shape == part.shape else None
            starts = [0] * full.ndim
            if batch_axis is not None:
                starts[batch_axis] = slot
            return jax.lax.dynamic_update_slice(full, part, tuple(starts))

        self.payload["cache"] = jax.tree_util.tree_map(
            ins, self.payload["cache"], prompt_cache
        )

    def admit(self, req: Request, slot: int) -> None:
        prompt = np.asarray(req.prompt, np.int32)[None, :]  # (1, S)
        batch = {"tokens": jnp.asarray(prompt)}
        logits, prompt_cache = self._prefill(self.params, batch)
        self._insert_cache(prompt_cache, slot)
        first = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        self.payload["tokens"] = self.payload["tokens"].at[slot, 0].set(first[0])
        self.payload["pos"] = self.payload["pos"].at[slot].set(prompt.shape[1])
        self.slot_req[slot] = req
        self.slot_remaining[slot] = req.max_new_tokens - 1
        self.outputs[req.rid] = [int(first[0])]

    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    # -- stepping ------------------------------------------------------------------

    def step(self, key: int | None = None) -> None:
        """One batched decode step through the device dispatch table."""
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if key is None:
            if not active:
                key = self.key_noop
            elif any(r is not None and r.temperature > 0 for r in self.slot_req):
                key = self.key_sample
            else:
                key = self.key_greedy
        temps = max((r.temperature for r in self.slot_req if r is not None),
                    default=0.0)
        self.payload["temp"] = jnp.asarray(temps, jnp.float32)
        self.payload = self.dispatch(jnp.asarray(key, jnp.int32), self.payload)
        self.steps_dispatched += 1
        if key == self.key_noop:
            return
        toks = np.asarray(self.payload["tokens"][:, 0])
        for slot in active:
            req = self.slot_req[slot]
            self.outputs[req.rid].append(int(toks[slot]))
            self.slot_remaining[slot] -= 1
            if self.slot_remaining[slot] <= 0:
                self.slot_req[slot] = None

    def run(self, requests: list[Request]) -> dict[int, list[int]]:
        """Serve a request list to completion with continuous batching."""
        for i, r in enumerate(requests):
            if r.rid < 0:
                r.rid = i
        pending = list(requests)
        while pending or any(r is not None for r in self.slot_req):
            for slot in self.free_slots():
                if not pending:
                    break
                self.admit(pending.pop(0), slot)
            self.step()
        return self.outputs
