"""Serving engine: continuous batching over a HAM device handler table.

This is where the paper's mechanism lands on the accelerator (DESIGN.md §2).
All per-step behaviours — greedy decode, temperature sampling, and a
``noop`` padding step (straggler/bubble filler) — are **branches of one
compiled ``lax.switch`` table** sharing a payload spec::

    payload = {cache, tokens (B,1), pos (B,), rng, temp}

Step *selection* is therefore an integer key fed as device data: no
re-trace, no executable swap, no host round-trip per behaviour change —
HAM's O(1) key dispatch, compiled.  Slots admit new requests by writing a
prefilled prompt cache into the batch cache (continuous batching).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.device_table import DeviceHandlerTable
from repro.core.future import Future


@dataclasses.dataclass
class Request:
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0     # 0 => greedy
    rid: int = -1
    #: seconds of decode budget from admission (None => no deadline).  An
    #: expired request leaves the running batch at the next step, frees its
    #: slot and ends its session (docs/failure-model.md: abandoned requests)
    deadline: float | None = None


def build_serve_table(model, params, *, sharder=None, window=None):
    """Device handler table over decode-step behaviours."""
    table = DeviceHandlerTable()

    def _next_from_logits(logits, payload, sample: bool):
        rng, sub = jax.random.split(payload["rng"])
        greedy = jnp.argmax(logits[:, -1, :], axis=-1)
        if sample:
            temp = jnp.maximum(payload["temp"], 1e-4)
            draw = jax.random.categorical(sub, logits[:, -1, :] / temp, axis=-1)
            nxt = jnp.where(payload["temp"] > 0, draw, greedy)
        else:
            nxt = greedy
        return nxt.astype(jnp.int32)[:, None], rng

    def decode_greedy(payload):
        logits, cache = model.decode_step(
            params, payload["cache"],
            {"tokens": payload["tokens"], "pos": payload["pos"]},
            sharder=sharder,
        )
        nxt, rng = _next_from_logits(logits, payload, sample=False)
        return {"cache": cache, "tokens": nxt, "pos": payload["pos"] + 1,
                "rng": rng, "temp": payload["temp"]}

    def decode_sample(payload):
        logits, cache = model.decode_step(
            params, payload["cache"],
            {"tokens": payload["tokens"], "pos": payload["pos"]},
            sharder=sharder,
        )
        nxt, rng = _next_from_logits(payload=payload, logits=logits, sample=True)
        return {"cache": cache, "tokens": nxt, "pos": payload["pos"] + 1,
                "rng": rng, "temp": payload["temp"]}

    def noop(payload):
        # bubble/straggler filler: burns a step slot without touching state
        return dict(payload)

    table.register("serve/decode_greedy", decode_greedy)
    table.register("serve/decode_sample", decode_sample)
    table.register("serve/noop", noop)
    table.seal()
    return table


class ServingEngine:
    """Continuous-batching loop on top of the compiled dispatch table."""

    def __init__(self, model, params, *, num_slots: int, max_len: int,
                 sharder=None, seed: int = 0, donate: bool = True):
        self.model = model
        self.params = params
        self.B = num_slots
        self.max_len = max_len
        self.table = build_serve_table(model, params, sharder=sharder)
        cache = model.init_cache(num_slots, max_len)
        self.payload = {
            "cache": cache,
            "tokens": jnp.zeros((num_slots, 1), jnp.int32),
            "pos": jnp.zeros((num_slots,), jnp.int32),
            "rng": jax.random.PRNGKey(seed),
            "temp": jnp.zeros((), jnp.float32),
        }
        spec = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), self.payload
        )
        self.dispatch = self.table.build(spec, donate_payload=donate)
        # un-jitted dispatch, scanned by step_many (fused multi-step blocks)
        self._dispatch_raw = self.table.build(spec, jit=False)
        self._multi_fns: dict[int, Any] = {}
        self.key_greedy = self.table.key_of("serve/decode_greedy")
        self.key_sample = self.table.key_of("serve/decode_sample")
        self.key_noop = self.table.key_of("serve/noop")
        self._admit_fused = self._build_admit_fused(sharder)
        # slot bookkeeping (host side)
        self.slot_req: list[Request | None] = [None] * num_slots
        self.slot_remaining = np.zeros(num_slots, np.int64)
        self.outputs: dict[int, list[int]] = {}
        self.steps_dispatched = 0

    # -- slot admission ----------------------------------------------------------

    def _build_admit_fused(self, sharder):
        """Compile the whole admission — prefill, batch-cache insert, slot
        token/pos writes, first-token argmax — into ONE dispatch.  The
        eager path pays a separate op dispatch per cache leaf (a dozen
        ``dynamic_update_slice`` launches); fused, an admit costs one
        executable call, which is what keeps TTFT flat under load.  The
        slot index rides as device data (traced scalar), so one compile
        covers every slot; prompt *length* is a shape, so each distinct
        length compiles once (same as the bare prefill jit)."""
        model, B = self.model, self.B

        def ins(full, part, slot):
            part = part.astype(full.dtype)
            batch_axis = None
            for a in range(full.ndim):
                if part.shape[a] == 1 and full.shape[a] == B:
                    batch_axis = a
                    break
            if batch_axis is None:
                batch_axis = 0 if full.shape == part.shape else None
            starts: list = [0] * full.ndim
            if batch_axis is not None:
                starts[batch_axis] = slot
            return jax.lax.dynamic_update_slice(full, part, tuple(starts))

        def admit_fused(params, cache, tokens, pos, prompt, slot):
            logits, pcache = model.prefill(
                params, {"tokens": prompt}, sharder=sharder
            )
            cache = jax.tree_util.tree_map(
                lambda f, p: ins(f, p, slot), cache, pcache
            )
            first = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            tokens = jax.lax.dynamic_update_slice(tokens, first[:, None],
                                                  (slot, 0))
            pos = jax.lax.dynamic_update_slice(
                pos, jnp.full((1,), prompt.shape[1], jnp.int32), (slot,)
            )
            return cache, tokens, pos, first[0]

        return jax.jit(admit_fused, donate_argnums=(1, 2, 3))

    def admit(self, req: Request, slot: int) -> None:
        prompt = np.asarray(req.prompt, np.int32)[None, :]  # (1, S)
        cache, tokens, pos, first = self._admit_fused(
            self.params, self.payload["cache"], self.payload["tokens"],
            self.payload["pos"], jnp.asarray(prompt),
            jnp.asarray(slot, jnp.int32),
        )
        self.payload["cache"] = cache
        self.payload["tokens"] = tokens
        self.payload["pos"] = pos
        self.slot_req[slot] = req
        self.slot_remaining[slot] = req.max_new_tokens - 1
        self.outputs[req.rid] = [int(first)]

    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def evict(self, rid: int) -> bool:
        """Free the slot decoding ``rid`` without emitting — the
        cancel/deadline departure path: the request simply isn't part of
        the next step's active set (its stale cache lane is overwritten by
        the next admission)."""
        for slot, r in enumerate(self.slot_req):
            if r is not None and r.rid == rid:
                self.slot_req[slot] = None
                self.slot_remaining[slot] = 0
                return True
        return False

    # -- stepping ------------------------------------------------------------------

    def step(self, key: int | None = None) -> list[tuple[int, int]]:
        """One batched decode step through the device dispatch table.

        Returns the ``(rid, token)`` pairs emitted this step (empty for a
        noop step) — the unit a pool driver streams back per completion.

        Early-out: with every slot idle and no explicit ``key``, the call
        returns immediately WITHOUT dispatching — a fully empty batch must
        not burn a padded noop decode (the worker loop parks on its
        doorbell instead; an explicit ``key=`` still dispatches, which is
        what the noop-preservation test exercises).
        """
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if key is None and not active:
            return []
        if key is None:
            if any(r is not None and r.temperature > 0 for r in self.slot_req):
                key = self.key_sample
            else:
                key = self.key_greedy
        temps = max((r.temperature for r in self.slot_req if r is not None),
                    default=0.0)
        self.payload["temp"] = jnp.asarray(temps, jnp.float32)
        self.payload = self.dispatch(jnp.asarray(key, jnp.int32), self.payload)
        self.steps_dispatched += 1
        if key == self.key_noop:
            return []
        toks = np.asarray(self.payload["tokens"][:, 0])
        emitted: list[tuple[int, int]] = []
        for slot in active:
            req = self.slot_req[slot]
            tok = int(toks[slot])
            emitted.append((req.rid, tok))
            self.outputs[req.rid].append(tok)
            self.slot_remaining[slot] -= 1
            if self.slot_remaining[slot] <= 0:
                self.slot_req[slot] = None
        return emitted

    def _multi_dispatch(self, k: int):
        raw = self._dispatch_raw

        def multi(key, payload):
            def body(p, _):
                p2 = raw(key, p)
                return p2, p2["tokens"][:, 0]

            return jax.lax.scan(body, payload, None, length=k)

        return jax.jit(multi, donate_argnums=(1,))

    def step_many(self, k: int) -> list[tuple[int, int]]:
        """Up to ``k`` decode steps fused into ONE device dispatch: a
        ``lax.scan`` over the same compiled handler table, returning the
        stacked per-step tokens in a single host transfer.

        This is the worker-driven loop's amortisation lever: the per-step
        Python/dispatch overhead that dominates a tiny decode step is paid
        once per *block* instead of once per token.  A lockstep driver
        cannot use it — it must observe every step over an RPC round trip.

        Semantics match ``k`` sequential :meth:`step` calls for greedy
        decode: slot lanes are independent, so a slot whose budget ends
        mid-block simply has its surplus lane tokens dropped host-side
        (the lane keeps computing on stale state, exactly like any freed
        lane does between admissions).  Sampling falls back to single
        steps — a fused block would advance the shared rng stream past
        what the lockstep drive consumes, breaking mode comparability.
        """
        if k <= 1:
            return self.step()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return []
        if any(self.slot_req[s].temperature > 0 for s in active):
            out: list[tuple[int, int]] = []
            for _ in range(k):
                out.extend(self.step())
                if all(r is None for r in self.slot_req):
                    break
            return out
        fn = self._multi_fns.get(k)
        if fn is None:
            fn = self._multi_fns[k] = self._multi_dispatch(k)
        self.payload["temp"] = jnp.asarray(0.0, jnp.float32)
        self.payload, toks = fn(
            jnp.asarray(self.key_greedy, jnp.int32), self.payload
        )
        self.steps_dispatched += k
        toks_np = np.asarray(toks)  # (k, B)
        emitted: list[tuple[int, int]] = []
        for i in range(k):
            for slot in active:
                req = self.slot_req[slot]
                if req is None:
                    continue  # budget reached earlier in this block
                tok = int(toks_np[i, slot])
                emitted.append((req.rid, tok))
                self.outputs[req.rid].append(tok)
                self.slot_remaining[slot] -= 1
                if self.slot_remaining[slot] <= 0:
                    self.slot_req[slot] = None
        return emitted

    def run(self, requests: list[Request]) -> dict[int, list[int]]:
        """Serve a request list to completion with continuous batching."""
        for i, r in enumerate(requests):
            if r.rid < 0:
                r.rid = i
        pending = list(requests)
        while pending or any(r is not None for r in self.slot_req):
            for slot in self.free_slots():
                if not pending:
                    break
                self.admit(pending.pop(0), slot)
            self.step()
        return self.outputs


# --------------------------------------------------------------------------
# cluster serving: continuous batching driven through the worker pool
# --------------------------------------------------------------------------

# the control handlers and their replica map live in repro.serve.handlers
# (a jax-free module, cheap for fresh-interpreter workers to re-import);
# re-exported here for callers that predate the split
from repro.serve.handlers import (  # noqa: E402,F401
    _NODE_ENGINES,
    _NODE_LOOPS,
    _STREAM_BLOCK_SINKS,
    _STREAM_SINKS,
    MAX_PROMPT,
    pad_prompt,
    register_serve_handlers,
)


class ClusterServingEngine:
    """Continuous batching sharded across a worker pool.

    One :class:`ServingEngine` replica per pool worker (thread workers —
    the replicas share the process and its jax devices).  Two drive modes:

    **Worker-driven** (default, the production path — docs/serving.md):
    each replica gets a :class:`~repro.serve.stream.WorkerDecodeLoop` that
    self-steps its continuous batch; the host's per-request involvement is
    ONE ``_serve/admit_stream`` slot-lease call (FLAG_STATIC), after which
    tokens stream back as fused ``_serve/stream`` oneways.  The host loop
    reduces to admission control — per-worker slot accounting plus a
    bounded admission queue that sheds with :class:`OffloadError` on
    overflow — and completion bookkeeping through the
    :class:`~repro.cluster.sessions.SessionRouter`.  Host RPCs per emitted
    token drop from ~1 (lockstep) to ``1/max_new_tokens``.

    **Lockstep** (``worker_driven=False``): the host drives every replica
    with one pipelined ``_serve/step`` call in flight per active worker —
    kept behind the flag as the benchmark's comparison leg; both modes
    produce token-identical output on the same prompts/seed (greedy decode
    is deterministic and slot-isolated).

    Request routing goes through the scheduler's :class:`SessionRouter`:
    each request is a session keyed ``serve/<rid>``, placed once by
    rendezvous hash over the workers *with a free slot* at admission time,
    then pinned — every subsequent call for that request lands on the
    worker holding its KV cache, and an unrelated pool resize cannot move
    it (the stickiness contract in ``repro.cluster.sessions``).  The
    engine's slot accounting stays its own (the router knows placement,
    not capacity).

    **Serving elasticity** (ROADMAP): engine replicas follow pool
    membership, not construction — ``on_join``/``on_restart`` build a
    replica for the newcomer, ``on_leave``/``on_death`` retire it (a
    drained removal drops the replica only after the node's in-flight
    steps finish), so serving survives ``pool.add_node()`` /
    ``pool.remove_node()`` mid-run and newly added capacity takes
    admissions immediately.

    **Session recovery**: the host is the system of record for every
    admitted request (prompt + every emitted token), which makes a
    worker's KV state *reconstructible*: when a worker dies mid-decode,
    :meth:`run` re-admits its requests on a survivor with the
    concatenated ``prompt + tokens-so-far`` as the new prefill — the
    session re-places (its old pin died), decode continues exactly where
    it stopped, and no emitted token is lost.  A completed request ends
    its session through ``Scheduler.end_session`` (which also releases
    any directory-tracked buffers bound to it).
    """

    def __init__(self, model, params, *, num_workers: int = 2,
                 slots_per_worker: int = 2, max_len: int, seed: int = 0,
                 registry=None, worker_driven: bool = True,
                 admission_limit: int | None = None, decode_block: int = 16):
        import threading

        from repro.cluster.pool import ClusterPool, register_cluster_handlers
        from repro.cluster.scheduler import Scheduler
        from repro.core.registry import HandlerRegistry
        from repro.offload.runtime import register_internal_handlers

        if registry is None:
            registry = HandlerRegistry()
            register_internal_handlers(registry)
            register_cluster_handlers(registry)
            register_serve_handlers(registry)
            registry.init()
        self.registry = registry
        self.slots_per_worker = slots_per_worker
        self.worker_driven = bool(worker_driven)
        #: bounded admission queue (worker-driven mode): submit_request
        #: sheds with OffloadError past this depth; None => unbounded
        self.admission_limit = admission_limit
        #: decode steps each worker loop fuses per iteration (step_many)
        self.decode_block = max(1, int(decode_block))
        self._model, self._params = model, params
        self._max_len, self._seed = max_len, seed
        self.pool = ClusterPool.local(num_workers, registry=registry)
        self.sched = Scheduler(self.pool, policy="least_outstanding",
                               max_inflight=slots_per_worker + 2)
        self._engine_keys: dict[int, int] = {}  # node -> id(runtime)
        # -- worker-driven host state (all guarded by _wd) ------------------
        self._wd = threading.Condition()
        self._pending: list[Request] = []       # admission queue (FIFO)
        self._transcripts: dict[int, list[int]] = {}
        self._events: dict[int, dict] = {}      # rid -> timing/seq record
        self._gen: dict[int, int] = {}          # rid -> stream generation
        self._budget: dict[int, int] = {}
        self._temp: dict[int, float] = {}
        self._prompt0: dict[int, Any] = {}
        self._expires: dict[int, float | None] = {}   # absolute monotonic
        self._placed: dict[int, int] = {}       # rid -> node decoding it
        self._admitting: dict[int, int] = {}    # rid -> node, admit in flight
        self._active: dict[int, int] = {}       # node -> occupied slots
        self._queued: dict[int, int] = {}       # node -> unconfirmed admits
        self._done: dict[int, int] = {}         # rid -> final stream status
        self._cancel_req: dict[int, int] = {}   # rid -> requested status
        self._errors: dict[int, Exception] = {}
        self._end_q: list[int] = []             # sessions to end (pump-side)
        self._next_rid = 0
        self.shed = 0                           # admission-overflow count
        self._pump: threading.Thread | None = None
        self._pump_stop = False
        if self.worker_driven:
            _STREAM_SINKS[id(self.pool.host)] = self._on_stream
            _STREAM_BLOCK_SINKS[id(self.pool.host)] = self._on_stream_block
        for node in self.pool.worker_nodes:
            self._add_replica(node)
        # serving elasticity: replicas track membership from here on
        self.pool.on_join(self._add_replica)
        self.pool.on_restart(self._add_replica)
        self.pool.on_death(self._on_death)
        self.pool.on_leave(self._on_leave)

    # -- replica lifecycle (elasticity contract in the class docs) ---------

    def _add_replica(self, node: int) -> None:
        rt = self.pool.domain._inproc.get(node)
        if rt is None:
            return  # non-local worker modes build engines worker-side
        self._drop_replica(node)  # a restarted node gets a fresh engine
        eng = ServingEngine(
            self._model, self._params, num_slots=self.slots_per_worker,
            max_len=self._max_len, seed=self._seed + node,
        )
        _NODE_ENGINES[id(rt)] = eng
        if self.worker_driven:
            from repro.serve.stream import WorkerDecodeLoop

            _NODE_LOOPS[id(rt)] = WorkerDecodeLoop(
                rt, eng, host_node=self.pool.domain.host_node,
                registry=self.registry, name=f"-{node}",
                block=self.decode_block,
            )
        self._engine_keys[node] = id(rt)
        with self._wd:
            self._wd.notify_all()  # fresh capacity for the admission pump

    def _drop_replica(self, node: int) -> None:
        key = self._engine_keys.pop(node, None)
        if key is not None:
            loop = _NODE_LOOPS.pop(key, None)
            if loop is not None:
                loop.stop(join=False)
            _NODE_ENGINES.pop(key, None)

    def _on_death(self, node: int) -> None:
        self._drop_replica(node)
        if self.worker_driven:
            self._recover_node(node)

    def _on_leave(self, node: int):
        # retire the replica only AFTER the scheduler's drain waiter let the
        # node's in-flight steps finish (waiters run in subscription order;
        # the scheduler subscribed first)
        def waiter(timeout: float | None = None) -> None:
            self._drop_replica(node)
            if self.worker_driven:
                # drained removal mid-decode: its requests repin elsewhere
                self._recover_node(node)

        return waiter

    def serving_nodes(self) -> list[int]:
        """Live workers that currently hold an engine replica."""
        live = set(self.sched.live_nodes())
        return sorted(n for n in self._engine_keys if n in live)

    # -- worker-driven mode: admission control + stream bookkeeping ---------

    def _on_stream(self, node: int, rid: int, gen: int, seq: int,
                   token: int, status: int, free_slots: int) -> None:
        """Token sink — runs on the host event-loop thread per fused
        segment; must stay cheap and never block.  Session teardown is
        deferred to the pump thread via ``_end_q``."""
        import time

        now = time.monotonic()
        with self._wd:
            # ground-truth occupancy from the worker's own slot count
            # (queued-but-unapplied admits are still in _queued)
            self._active[node] = self.slots_per_worker - int(free_slots)
            self._apply_stream_locked(node, rid, gen, seq, token, status, now)
            self._wd.notify_all()

    def _on_stream_block(self, node: int, rid: int, gen: int, seq0: int,
                         tokens, status: int, free_slots: int) -> None:
        """Block sink: a whole fused decode block's tokens for one request
        under ONE lock acquisition — ``status`` applies to the last token,
        the earlier ones are implicitly STREAM_TOKEN."""
        import time

        from repro.core.flags import STREAM_TOKEN

        now = time.monotonic()
        with self._wd:
            self._active[node] = self.slots_per_worker - int(free_slots)
            last = len(tokens) - 1
            for i, tok in enumerate(tokens):
                st = status if i == last else STREAM_TOKEN
                self._apply_stream_locked(node, rid, gen, seq0 + i,
                                          int(tok), st, now)
            self._wd.notify_all()

    def _apply_stream_locked(self, node: int, rid: int, gen: int, seq: int,
                             token: int, status: int, now: float) -> None:
        from repro.core.flags import STREAM_DONE, STREAM_TOKEN

        if self._gen.get(rid) != gen or rid in self._done:
            return  # stale generation (pre-recovery straggler) or late
        # placement ground truth: the node actually streaming wins over
        # the admit-time pick (a session can re-place mid-admit if the
        # picked worker died between route and send)
        self._placed[rid] = node
        ev = self._events.setdefault(rid, {})
        if status in (STREAM_TOKEN, STREAM_DONE) and token >= 0:
            t = self._transcripts.setdefault(rid, [])
            if len(t) < self._budget.get(rid, 1 << 30):
                t.append(int(token))
                ev.setdefault("t_first", now)
                ev.setdefault("token_ts", []).append(now)
            # fused-oneway ordering contract: seq counts emissions
            # within this generation — any gap/reorder trips this flag
            expected = len(t) - 1 - ev.get("seq_base", 0)
            if seq != expected:
                ev["seq_ok"] = False
        if status == STREAM_DONE or (
            status == STREAM_TOKEN
            and len(self._transcripts.get(rid, ()))
            >= self._budget.get(rid, 1 << 30)
        ):
            self._finalize_locked(rid, STREAM_DONE, now)
        elif status not in (STREAM_TOKEN, STREAM_DONE):
            self._finalize_locked(rid, status, now)

    def _finalize_locked(self, rid: int, status: int, now: float) -> None:
        self._done[rid] = status
        self._placed.pop(rid, None)
        self._admitting.pop(rid, None)
        self._cancel_req.pop(rid, None)
        self._events.setdefault(rid, {}).setdefault("t_done", now)
        self._end_q.append(rid)

    def _recover_node(self, node: int) -> None:
        """A serving node left mid-decode (death or drained removal): its
        replica's KV is gone, but the host holds prompt + every emitted
        token — bump each of its requests' stream generation (stragglers
        from the old loop are dropped by gen mismatch) and re-queue them as
        continuation admits; their sessions repin on a survivor."""
        with self._wd:
            self._active[node] = 0
            self._queued[node] = 0
            for rid in [r for r, n in self._placed.items() if n == node]:
                self._placed.pop(rid, None)
                self._requeue_locked(rid)
            for rid in [r for r, n in self._admitting.items() if n == node]:
                self._admitting.pop(rid, None)
                self._requeue_locked(rid)
            self._wd.notify_all()

    def _requeue_locked(self, rid: int) -> None:
        import time

        from repro.core.flags import STREAM_DONE, STREAM_EXPIRED

        if rid in self._done:
            return
        now = time.monotonic()
        if rid in self._cancel_req:
            self._finalize_locked(rid, self._cancel_req[rid], now)
            return
        done_toks = self._transcripts.get(rid, [])
        remaining = self._budget[rid] - len(done_toks)
        if remaining <= 0:
            self._finalize_locked(rid, STREAM_DONE, now)
            return
        expires = self._expires.get(rid)
        if expires is not None and now >= expires:
            self._finalize_locked(rid, STREAM_EXPIRED, now)
            return
        self._gen[rid] += 1
        ev = self._events.setdefault(rid, {})
        ev["repins"] = ev.get("repins", 0) + 1
        ev["seq_base"] = len(done_toks)
        # continuation admit: prefill of prompt + tokens-so-far picks up
        # decode exactly where the departed worker stopped
        self._pending.insert(0, Request(
            prompt=np.concatenate(
                [np.asarray(self._prompt0[rid], np.int32),
                 np.asarray(done_toks, np.int32)]
            ),
            max_new_tokens=remaining,
            temperature=self._temp[rid],
            rid=rid,
        ))

    def _ensure_pump(self) -> None:
        import threading

        with self._wd:
            if self._pump is not None or self._pump_stop:
                return
            self._pump = threading.Thread(
                target=self._pump_loop, name="ham-serve-admit", daemon=True
            )
            self._pump.start()

    def _pump_loop(self) -> None:
        """Admission pump: places each pending request's session once
        (rendezvous hash over workers with a free slot), leases the slot
        with ONE ``_serve/admit_stream`` submit through the router, and
        retires completed sessions.  This thread is the only caller of
        ``sched.submit``/``end_session`` in worker-driven mode — the event
        loop's sink never blocks on scheduler locks."""
        import time

        from repro.core.flags import STREAM_EXPIRED

        while True:
            with self._wd:
                while not self._pump_stop and not self._pending \
                        and not self._end_q:
                    self._wd.wait(0.05)
                if self._pump_stop:
                    return
                ended, self._end_q = self._end_q, []
                batch = self._collect_admits_locked()
            for rid in ended:
                self.sched.end_session(f"serve/{rid}")
            for req, node, gen in batch:
                self._send_admit(req, node, gen)
            if not batch and not ended:
                time.sleep(0.002)  # pending but nowhere admissible yet
                # host-side deadline sweep for queue-stuck requests
                with self._wd:
                    now = time.monotonic()
                    for i in range(len(self._pending) - 1, -1, -1):
                        rid = self._pending[i].rid
                        exp = self._expires.get(rid)
                        if exp is not None and now >= exp:
                            del self._pending[i]
                            self._finalize_locked(rid, STREAM_EXPIRED, now)
                            self._wd.notify_all()

    def _collect_admits_locked(self) -> list:
        """Match pending requests to workers with lease capacity (the
        lockstep admission scan, minus the per-step traffic): session pins
        win; fresh placements go rendezvous-hash over workers with a free
        slot.  A request whose pinned worker is full must not block the
        queue behind it."""
        batch = []
        nodes = self.serving_nodes()
        if not nodes:
            return batch
        while self._pending:
            free = [
                n for n in nodes
                if self._active.get(n, 0) + self._queued.get(n, 0)
                < self.slots_per_worker
            ]
            if not free:
                break
            pick = None
            for idx, req in enumerate(self._pending):
                node = self.sched.sessions.route(
                    f"serve/{req.rid}", eligible=free
                )
                if node is not None and node in free:
                    pick = (idx, node)
                    break
            if pick is None:
                break  # every pending request waits on a full pin
            idx, node = pick
            req = self._pending.pop(idx)
            self._queued[node] = self._queued.get(node, 0) + 1
            self._admitting[req.rid] = node
            batch.append((req, node, self._gen[req.rid]))
        return batch

    def _send_admit(self, req: Request, node: int, gen: int) -> None:
        import time

        from repro.core.closure import f2f

        prompt = np.asarray(req.prompt, np.int32)
        expires = self._expires.get(req.rid)
        deadline_s = 0.0
        if expires is not None:
            deadline_s = max(expires - time.monotonic(), 1e-3)
        try:
            fut = self.sched.submit(
                f2f("_serve/admit_stream", pad_prompt(prompt),
                    int(prompt.shape[0]), int(req.rid), int(gen),
                    int(req.max_new_tokens), float(req.temperature),
                    float(deadline_s), registry=self.registry),
                session=f"serve/{req.rid}",
            )
        except Exception as e:  # noqa: BLE001 — no live workers / backpressure
            self._admit_failed(req.rid, node, e)
            return
        fut.add_done_callback(
            lambda f, rid=req.rid, n=node, g=gen: self._on_admit_done(
                f, rid, n, g)
        )

    def _on_admit_done(self, fut, rid: int, node: int, gen: int) -> None:
        import time

        try:
            fut.get(0)
        except Exception as e:  # noqa: BLE001 — classified below
            self._admit_failed(rid, node, e)
            return
        with self._wd:
            self._queued[node] = max(0, self._queued.get(node, 0) - 1)
            if self._admitting.pop(rid, None) is not None \
                    and rid not in self._done and self._gen.get(rid) == gen:
                self._placed[rid] = node
                self._events.setdefault(rid, {}).setdefault(
                    "t_admit", time.monotonic())
            self._wd.notify_all()

    def _admit_failed(self, rid: int, node: int, exc: Exception) -> None:
        """Lease call failed: a dead/draining worker re-queues the request
        (its session re-places); a failure on a healthy worker is a real
        error and fails the request diagnosably."""
        with self._wd:
            self._queued[node] = max(0, self._queued.get(node, 0) - 1)
            if self._admitting.pop(rid, None) is None or rid in self._done:
                self._wd.notify_all()
                return
            if self.pool.is_alive(node) and node in self._engine_keys:
                import time

                self._errors[rid] = exc
                self._finalize_locked(rid, -1, time.monotonic())
            else:
                self._requeue_locked(rid)
            self._wd.notify_all()

    # -- worker-driven public API -------------------------------------------

    def submit_request(self, req: Request, *, shed: bool = True) -> int:
        """Admit one request into the serving system (worker-driven mode).

        Non-blocking: returns the request id immediately; tokens accumulate
        in the host transcript as the worker streams them.  With ``shed=``
        True (the open-loop default), raises :class:`OffloadError` when the
        admission queue is at ``admission_limit`` — shed-on-overflow is the
        back-pressure contract of the open-loop harness.
        """
        import time

        from repro.core.errors import OffloadError

        if not self.worker_driven:
            raise OffloadError(
                "submit_request requires worker_driven=True "
                "(lockstep mode only supports run())"
            )
        self._ensure_pump()
        with self._wd:
            if req.rid < 0:
                req.rid = self._next_rid
            rid = req.rid
            self._next_rid = max(self._next_rid, rid + 1)
            if rid in self._budget and rid not in self._done:
                raise OffloadError(f"request {rid} is already in flight")
            if shed and self.admission_limit is not None \
                    and len(self._pending) >= self.admission_limit:
                self.shed += 1
                raise OffloadError(
                    f"admission queue full ({self.admission_limit}); "
                    f"request {rid} shed"
                )
            prompt = np.asarray(req.prompt, np.int32)
            if prompt.shape[0] + req.max_new_tokens > MAX_PROMPT:
                raise OffloadError(
                    f"prompt+budget {prompt.shape[0] + req.max_new_tokens} "
                    f"exceeds the serve wire bound MAX_PROMPT={MAX_PROMPT}"
                )
            now = time.monotonic()
            # rid reuse after completion (back-to-back run() calls): reset
            self._done.pop(rid, None)
            self._errors.pop(rid, None)
            self._transcripts[rid] = []
            self._events[rid] = {"t_submit": now}
            self._gen[rid] = self._gen.get(rid, -1) + 1
            self._budget[rid] = int(req.max_new_tokens)
            self._temp[rid] = float(req.temperature)
            self._prompt0[rid] = prompt
            self._expires[rid] = (
                now + req.deadline if req.deadline is not None else None
            )
            self._pending.append(req)
            self._wd.notify_all()
            return rid

    def cancel(self, rid: int, *, status: int | None = None) -> bool:
        """Cancel a request: it leaves the running batch at the worker's
        next step, frees its slot, and its session ends.  Returns False
        when the request already finished."""
        import time

        from repro.core.closure import f2f
        from repro.core.errors import OffloadError
        from repro.core.flags import STREAM_CANCELLED

        status = STREAM_CANCELLED if status is None else int(status)
        with self._wd:
            if rid not in self._budget:
                raise OffloadError(f"unknown request {rid}")
            if rid in self._done:
                return False
            for i, q in enumerate(self._pending):
                if q.rid == rid:  # still queued host-side: shed locally
                    del self._pending[i]
                    self._finalize_locked(rid, status, time.monotonic())
                    self._wd.notify_all()
                    return True
            self._cancel_req[rid] = status
            gen = self._gen[rid]
        try:
            self.sched.oneway(
                f2f("_serve/cancel", int(rid), int(gen), int(status),
                    registry=self.registry),
                session=f"serve/{rid}",
            )
        except Exception:  # noqa: BLE001 — worker died: recovery finalizes
            pass
        return True

    def wait(self, rids=None, timeout: float | None = 300.0) -> None:
        """Block until every request in ``rids`` (default: all submitted)
        reached a terminal state; raises the first recorded per-request
        error, TimeoutError past ``timeout``, or OffloadError when the
        pool can no longer serve the remainder."""
        import time

        from repro.core.errors import OffloadError

        deadline = (
            None if timeout is None else time.monotonic() + timeout
        )
        with self._wd:
            target = set(self._budget) if rids is None else set(rids)
            while not target <= self._done.keys():
                waiting = target - self._done.keys()
                if not self.serving_nodes() and not self.pool.worker_nodes:
                    raise OffloadError(
                        f"no live serving workers remain for {len(waiting)} "
                        "unfinished requests"
                    )
                remaining = (
                    None if deadline is None
                    else deadline - time.monotonic()
                )
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(
                        f"cluster serve exceeded {timeout}s with "
                        f"{len(waiting)} requests unfinished"
                    )
                self._wd.wait(
                    0.1 if remaining is None else min(0.1, remaining)
                )
            for rid in sorted(target & self._errors.keys()):
                raise self._errors[rid]

    def _run_worker_driven(self, requests: list[Request],
                           timeout: float) -> dict[int, list[int]]:
        rids = [self.submit_request(r, shed=False) for r in requests]
        self.wait(rids, timeout=timeout)
        with self._wd:
            out = {rid: list(self._transcripts.get(rid, ())) for rid in rids}
        for rid in rids:  # idempotent with the pump's session teardown
            self.sched.end_session(f"serve/{rid}")
        return out

    def run(self, requests: list[Request],
            timeout: float = 300.0) -> dict[int, list[int]]:
        """Serve ``requests`` to completion; survives pool resizes and
        worker deaths mid-run (class docs).  ``timeout`` bounds the whole
        drive.  Worker-driven by default; ``worker_driven=False`` at
        construction selects the lockstep drive loop."""
        for i, r in enumerate(requests):
            if r.rid < 0:
                r.rid = i
        if self.worker_driven:
            return self._run_worker_driven(requests, timeout)
        return self._run_lockstep(requests, timeout)

    def _run_lockstep(self, requests: list[Request],
                      timeout: float = 300.0) -> dict[int, list[int]]:
        """Host-lockstep drive loop: one pipelined ``_serve/step`` call in
        flight per active worker (the benchmark's comparison leg)."""
        import queue as _queue
        import time

        from repro.core.closure import f2f
        from repro.core.errors import OffloadError

        for i, r in enumerate(requests):
            if r.rid < 0:
                r.rid = i
        pending = list(requests)
        outputs: dict[int, list[int]] = {}
        budget = {r.rid: r.max_new_tokens for r in requests}
        temp = {r.rid: r.temperature for r in requests}
        prompt0 = {r.rid: np.asarray(r.prompt, np.int32) for r in requests}
        placed: dict[int, int] = {}  # rid -> node currently decoding it
        # per-node occupancy: `active` is ground truth as of the last reply
        # from that node; `queued` counts admits submitted but unconfirmed
        active: dict[int, int] = {}
        queued: dict[int, int] = {}
        stepping: dict[int, bool] = {}
        inflight: dict[Future, tuple[str, int, int | None]] = {}
        # one persistent completion queue for the whole drive: every
        # submitted future pushes itself here exactly once when done
        done_q: _queue.SimpleQueue = _queue.SimpleQueue()
        deadline = time.monotonic() + timeout
        reg = self.registry

        def track(fut: Future, kind: str, node: int,
                  rid: int | None = None) -> None:
            inflight[fut] = (kind, node, rid)
            fut.add_done_callback(done_q.put)

        def requeue(rid: int) -> None:
            """Continuation admit: prefill of prompt + tokens-so-far picks
            up decode exactly where the dead worker stopped."""
            done_toks = outputs.get(rid, [])
            remaining = budget[rid] - len(done_toks)
            if remaining <= 0:
                return  # finished just before the crash
            pending.append(Request(
                prompt=np.concatenate(
                    [prompt0[rid], np.asarray(done_toks, np.int32)]
                ),
                max_new_tokens=remaining,
                temperature=temp[rid],
                rid=rid,
            ))

        def recover_node(node: int) -> None:
            """A serving node died: its replica's KV is gone, but the host
            holds prompt + every emitted token — re-queue its requests as
            continuation admits on a survivor."""
            active[node] = 0
            queued[node] = 0
            stepping[node] = False
            for rid in [r for r, n in placed.items() if n == node]:
                placed.pop(rid, None)
                requeue(rid)

        while pending or inflight or any(active.values()):
            nodes = self.serving_nodes()
            # death sweep: a victim with NO call in flight produces no
            # failed future (its last step reply may have been processed
            # before the monitor marked it dead) — reap by state, not only
            # by exception, or its requests would be orphaned silently
            busy = set(placed.values()) \
                | {n for n, a in active.items() if a} \
                | {n for n, q in queued.items() if q}
            for node in busy - set(nodes):
                if not (self.pool.is_alive(node)
                        and node in self._engine_keys):
                    recover_node(node)
            # admission: place each request's session once (rendezvous hash
            # over workers with a free slot), then submit THROUGH the router
            # so the admit sticks to the placement.  A request whose live
            # pin is full waits for a slot THERE (KV must not split across
            # workers) but must not block admission of the requests behind
            # it — scan past it to the first admissible request instead
            while pending and nodes:
                free = [
                    n for n in nodes
                    if active.get(n, 0) + queued.get(n, 0)
                    < self.slots_per_worker
                ]
                if not free:
                    break
                admit_idx = None
                node = None
                for idx, req in enumerate(pending):
                    placed_node = self.sched.sessions.route(
                        f"serve/{req.rid}", eligible=free
                    )
                    if placed_node is not None and placed_node in free:
                        admit_idx, node = idx, placed_node
                        break
                if admit_idx is None:
                    break  # every pending request waits on a full pin
                req = pending.pop(admit_idx)
                queued[node] = queued.get(node, 0) + 1
                track(self.sched.submit(
                    f2f("_serve/admit", np.asarray(req.prompt, np.int32),
                        int(req.rid), int(req.max_new_tokens),
                        float(req.temperature), registry=reg),
                    session=f"serve/{req.rid}",
                ), "admit", node, req.rid)
            for node in nodes:
                if (active.get(node, 0) or queued.get(node, 0)) \
                        and not stepping.get(node, False):
                    stepping[node] = True
                    track(self.sched.submit(
                        f2f("_serve/step", registry=reg), node=node,
                    ), "step", node)
            if not inflight:
                if pending and not self.serving_nodes():
                    raise OffloadError(
                        "no live serving workers remain for "
                        f"{len(pending)} pending requests"
                    )
                if not pending:
                    break
                time.sleep(0.02)  # pinned worker full: wait for a slot
                continue
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TimeoutError(
                    f"cluster serve exceeded {timeout}s with "
                    f"{len(inflight)} calls in flight"
                )
            try:
                done = done_q.get(timeout=remaining)
            except _queue.Empty:
                raise TimeoutError(
                    f"cluster serve exceeded {timeout}s with "
                    f"{len(inflight)} calls in flight"
                ) from None
            kind, node, rid = inflight.pop(done)
            try:
                result = done.get(0)
            except Exception:
                # a dead/removed worker fails its in-flight calls; anything
                # else (slot bug, handler error) must surface.  Liveness is
                # checked at the pool (marked dead before futures fail), not
                # via serving_nodes(): the replica-drop callback may still
                # be a few callbacks behind the future rejection.
                if self.pool.is_alive(node) and node in self._engine_keys:
                    raise
                recover_node(node)
                if kind == "admit" and rid is not None and rid not in placed:
                    # the admit itself died in flight: its request is in no
                    # placed map — re-queue it explicitly
                    requeue(rid)
                continue
            if kind == "admit":
                rid, first = result
                queued[node] = queued.get(node, 0) - 1
                active[node] = active.get(node, 0) + 1
                placed[rid] = node
                # a recovery re-admit continues an existing transcript
                outputs.setdefault(rid, []).append(first)
                if len(outputs[rid]) >= budget[rid]:
                    placed.pop(rid, None)
            else:
                stepping[node] = False
                emitted, free = result
                active[node] = self.slots_per_worker - free
                for rid, tok in emitted:
                    # the slot-remaining accounting emits one trailing token
                    # for a single-token (re-)admission — cap the transcript
                    # at its budget so a continuation cannot over-emit
                    if len(outputs[rid]) < budget[rid]:
                        outputs[rid].append(tok)
                    if len(outputs[rid]) >= budget[rid]:
                        placed.pop(rid, None)
        for r in requests:  # sessions end with their requests
            self.sched.end_session(f"serve/{r.rid}")
        return outputs

    def close(self) -> None:
        with self._wd:
            self._pump_stop = True
            self._wd.notify_all()
        if self._pump is not None:
            self._pump.join(timeout=5.0)
            self._pump = None
        _STREAM_SINKS.pop(id(self.pool.host), None)
        _STREAM_BLOCK_SINKS.pop(id(self.pool.host), None)
        for key in list(self._engine_keys.values()):
            loop = _NODE_LOOPS.pop(key, None)
            if loop is not None:
                loop.stop()
            _NODE_ENGINES.pop(key, None)
        self._engine_keys.clear()
        self.pool.close()
