"""Whisper-style encoder-decoder (audio backbone).

The conv/log-mel frontend is a STUB per the assignment: ``input_specs()``
provides precomputed encoder frame embeddings (B, frames, d_model).  The
transformer backbone is faithful: LayerNorm blocks, non-causal encoder
self-attention with sinusoidal positions, decoder with causal self-attention
+ cross-attention + GELU MLPs.  Deviation (DESIGN.md §5): decoder positions
use RoPE instead of a learned table so the 32k/500k stress cells need no
position-table resizing.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.config import ModelConfig


def _spec(cfg: ModelConfig) -> L.AttnParamsSpec:
    return L.AttnParamsSpec(cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                            cfg.resolved_head_dim, cfg.qkv_bias)


def sinusoids(length: int, channels: int):
    log_timescale = np.log(10000) / (channels // 2 - 1)
    inv = np.exp(-log_timescale * np.arange(channels // 2))
    t = np.arange(length)[:, None] * inv[None, :]
    return jnp.asarray(
        np.concatenate([np.sin(t), np.cos(t)], axis=1), jnp.float32
    )


def enc_layer_init(key, cfg):
    k1, k2 = jax.random.split(key)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "ln_attn": L.layernorm_init(cfg.d_model, dt),
        "attn": L.attention_init(k1, _spec(cfg), dt),
        "ln_mlp": L.layernorm_init(cfg.d_model, dt),
        "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, "gelu", dt),
    }


def dec_layer_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "ln_self": L.layernorm_init(cfg.d_model, dt),
        "self_attn": L.attention_init(k1, _spec(cfg), dt),
        "ln_cross": L.layernorm_init(cfg.d_model, dt),
        "cross_attn": L.attention_init(k2, _spec(cfg), dt),
        "ln_mlp": L.layernorm_init(cfg.d_model, dt),
        "mlp": L.mlp_init(k3, cfg.d_model, cfg.d_ff, "gelu", dt),
    }


def whisper_init(key, cfg: ModelConfig):
    enc_n = cfg.encdec.encoder_layers
    keys = jax.random.split(key, enc_n + cfg.num_layers + 4)
    dt = jnp.dtype(cfg.param_dtype)
    enc = [enc_layer_init(keys[i], cfg) for i in range(enc_n)]
    dec = [dec_layer_init(keys[enc_n + i], cfg) for i in range(cfg.num_layers)]
    stack = lambda bs: jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *bs)
    return {
        "embed": L.embedding_init(keys[-1], cfg.vocab_size, cfg.d_model, dt),
        "enc_layers": stack(enc),
        "enc_norm": L.layernorm_init(cfg.d_model, dt),
        "dec_layers": stack(dec),
        "dec_norm": L.layernorm_init(cfg.d_model, dt),
        "head": {"w": jax.random.normal(keys[-2], (cfg.d_model, cfg.vocab_size), dt)
                 * (1.0 / cfg.d_model**0.5)},
    }


def encode(p, frames, cfg: ModelConfig, *, sharder=None):
    dt = jnp.dtype(cfg.dtype)
    F = frames.shape[1]
    x = frames.astype(dt) + sinusoids(F, cfg.d_model).astype(dt)[None]
    if sharder is not None:
        x = sharder.act_btd(x)
    positions = jnp.arange(F, dtype=jnp.int32)

    def body(x, lp):
        h = L.layernorm(lp["ln_attn"], x, cfg.norm_eps)
        a, _ = L.attention_apply(lp["attn"], h, spec=_spec(cfg), dtype=dt,
                                 rope_theta=None, positions=positions,
                                 causal=False, sharder=sharder)
        x = x + a
        h = L.layernorm(lp["ln_mlp"], x, cfg.norm_eps)
        x = x + L.mlp_apply(lp["mlp"], h, "gelu", dt, sharder=sharder)
        return x, None

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, p["enc_layers"])
    return L.layernorm(p["enc_norm"], x, cfg.norm_eps)


def _dec_layer(lp, x, enc_out, cfg, *, positions, dt, sharder,
               self_cache=None, cache_pos=None, cross_cache=None,
               return_cache=False):
    h = L.layernorm(lp["ln_self"], x, cfg.norm_eps)
    a, new_self = L.attention_apply(
        lp["self_attn"], h, spec=_spec(cfg), dtype=dt,
        rope_theta=cfg.rope_theta, positions=positions, causal=True,
        cache=self_cache, cache_pos=cache_pos, sharder=sharder,
        attn_chunk=cfg.attn_chunk,
    )
    x = x + a
    h = L.layernorm(lp["ln_cross"], x, cfg.norm_eps)
    if cross_cache is not None:
        a, new_cross = L.attention_apply(
            lp["cross_attn"], h, spec=_spec(cfg), dtype=dt, rope_theta=None,
            positions=positions, cache=cross_cache, static_cache=True,
            sharder=sharder,
        )
    else:
        enc_positions = jnp.arange(enc_out.shape[1], dtype=jnp.int32)
        a, new_cross = L.attention_apply(
            lp["cross_attn"], h, spec=_spec(cfg), dtype=dt, rope_theta=None,
            positions=enc_positions, causal=False, x_kv=enc_out,
            sharder=sharder,
        )
    x = x + a
    h = L.layernorm(lp["ln_mlp"], x, cfg.norm_eps)
    x = x + L.mlp_apply(lp["mlp"], h, "gelu", dt, sharder=sharder)
    caches = (new_self, new_cross) if return_cache else None
    return x, caches


def whisper_forward(p, batch, cfg: ModelConfig, *, sharder=None,
                    return_cache=False):
    """batch: {frames (B,F,d), tokens (B,S)}; returns (logits, cache, 0)."""
    dt = jnp.dtype(cfg.dtype)
    enc_out = encode(p, batch["frames"], cfg, sharder=sharder)
    x = L.embed(p["embed"], batch["tokens"], dt)
    if sharder is not None:
        x = sharder.act_btd(x)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(x, lp):
        x, caches = _dec_layer(lp, x, enc_out, cfg, positions=positions,
                               dt=dt, sharder=sharder,
                               return_cache=return_cache)
        return x, caches

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    x, caches = jax.lax.scan(body, x, p["dec_layers"])
    x = L.layernorm(p["dec_norm"], x, cfg.norm_eps)
    logits = L.unembed(p["head"], x, dt)
    if sharder is not None:
        logits = sharder.logits(logits)
    return logits, caches, jnp.zeros((), jnp.float32)


def whisper_init_cache(cfg: ModelConfig, batch: int, max_len: int, **_):
    hk, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    Lr = cfg.num_layers
    F = cfg.encdec.encoder_frames
    return {
        "self": {"k": jnp.zeros((Lr, batch, max_len, hk, hd), dt),
                 "v": jnp.zeros((Lr, batch, max_len, hk, hd), dt)},
        "cross": {"k": jnp.zeros((Lr, batch, F, hk, hd), dt),
                  "v": jnp.zeros((Lr, batch, F, hk, hd), dt)},
    }


def whisper_decode_step(p, cache, batch, cfg: ModelConfig, *, sharder=None):
    """batch: {tokens (B,1), pos scalar}.  Cross K/V precomputed (prefill)."""
    dt = jnp.dtype(cfg.dtype)
    x = L.embed(p["embed"], batch["tokens"], dt)
    pos = batch["pos"]
    if pos.ndim == 0:
        positions = pos[None].astype(jnp.int32)
    else:
        positions = pos[:, None].astype(jnp.int32)

    def body(x, layer_in):
        lp, self_c, cross_c = layer_in
        x, (new_self, _) = _dec_layer(
            lp, x, None, cfg, positions=positions, dt=dt, sharder=sharder,
            self_cache=self_c, cache_pos=pos, cross_cache=cross_c,
            return_cache=True,
        )
        return x, new_self

    x, new_self = jax.lax.scan(
        body, x, (p["dec_layers"], cache["self"], cache["cross"])
    )
    x = L.layernorm(p["dec_norm"], x, cfg.norm_eps)
    logits = L.unembed(p["head"], x, dt)
    if sharder is not None:
        logits = sharder.logits(logits)
    return logits, {"self": new_self, "cross": cache["cross"]}


def whisper_param_rules(cfg: ModelConfig):
    ln = {"scale": [None, None], "bias": [None, None]}
    attn = {
        "wq": [None, ["fsdp"], "model", None],
        "wk": [None, ["fsdp"], "model", None],
        "wv": [None, ["fsdp"], "model", None],
        "wo": [None, "model", None, ["fsdp"]],
    }
    mlp = {"w_up": [None, ["fsdp"], "model"], "w_down": [None, "model", ["fsdp"]]}
    return {
        "embed": {"table": [["fsdp"], "model"]},
        "enc_layers": {"ln_attn": ln, "attn": attn, "ln_mlp": ln, "mlp": mlp},
        "enc_norm": {"scale": [None], "bias": [None]},
        "dec_layers": {
            "ln_self": ln, "self_attn": attn,
            "ln_cross": ln, "cross_attn": attn,
            "ln_mlp": ln, "mlp": mlp,
        },
        "dec_norm": {"scale": [None], "bias": [None]},
        "head": {"w": [["fsdp"], "model"]},
    }
