"""Parameter / FLOP accounting for the roofline analysis.

MODEL_FLOPS convention (EXPERIMENTS.md §Roofline):
* train cells:            6 · N_active · tokens   (fwd 2ND + bwd 4ND)
* prefill/decode cells:   2 · N_active · tokens
Attention's quadratic term is intentionally *not* in MODEL_FLOPS — the
HLO_FLOPs / MODEL_FLOPS ratio then exposes attention + remat + routing
overhead, which is what the assignment asks the ratio to catch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.lru_cache(maxsize=64)
def _shapes_for(cfg):
    from repro.models.api import build_model

    model = build_model(cfg)
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def count_params(cfg) -> int:
    shapes = _shapes_for(cfg)
    return int(sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(shapes)))


def count_active_params(cfg) -> int:
    """Active parameters per token (MoE: routed experts scaled by top_k/E;
    Zamba2: the shared attention block is applied L/attn_every times, so it
    counts once per application... it is one weight set used repeatedly —
    counted once, like weight tying)."""
    total = count_params(cfg)
    if cfg.moe is None:
        return total
    shapes = _shapes_for(cfg)
    routed = 0
    moe_tree = shapes["layers"].get("moe") if isinstance(shapes, dict) else None
    if moe_tree is not None:
        for name in ("w_gate", "w_up", "w_down"):
            routed += int(np.prod(moe_tree[name].shape))
    frac = cfg.moe.top_k / cfg.moe.num_experts
    return int(total - routed * (1.0 - frac))


def model_flops(cfg, cell) -> float:
    n = count_active_params(cfg)
    if cell.kind == "train":
        tokens = cell.seq_len * cell.global_batch
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = cell.seq_len * cell.global_batch
        return 2.0 * n * tokens
    # decode: one token per sequence per step
    return 2.0 * n * cell.global_batch
