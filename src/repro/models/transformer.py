"""Decoder-only transformer LM (dense / MoE / VLM backbone).

Layer-stacked parameters (leading ``num_layers`` dim) consumed by
``jax.lax.scan`` — keeps the HLO size O(1) in depth, which matters both for
pod-scale compile times and for this container's CPU compiles of 126-layer
models.  Remat policy wraps the scan body.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.moe import expert_specs, moe_apply, moe_init


def _attn_spec(cfg: ModelConfig) -> L.AttnParamsSpec:
    return L.AttnParamsSpec(
        d_model=cfg.d_model,
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim,
        qkv_bias=cfg.qkv_bias,
    )


def layer_init(key, cfg: ModelConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "ln_attn": L.rmsnorm_init(cfg.d_model, dt),
        "attn": L.attention_init(k1, _attn_spec(cfg), dt),
        "ln_mlp": L.rmsnorm_init(cfg.d_model, dt),
    }
    if cfg.moe is not None:
        p["moe"] = moe_init(k2, cfg.d_model, cfg.moe, dt)
    else:
        p["mlp"] = L.mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.mlp, dt)
    return p


def layer_apply(p, x, cfg: ModelConfig, *, positions, sharder=None,
                cache=None, cache_pos=None, causal=True, window=None):
    """Pre-norm block: x + attn(ln(x)); x + mlp(ln(x)).  Returns
    (x, new_cache, aux)."""
    dt = jnp.dtype(cfg.dtype)
    h = L.rmsnorm(p["ln_attn"], x, cfg.norm_eps)
    attn_out, new_cache = L.attention_apply(
        p["attn"], h, spec=_attn_spec(cfg), dtype=dt,
        rope_theta=cfg.rope_theta, positions=positions, causal=causal,
        window=window, cache=cache, cache_pos=cache_pos, sharder=sharder,
        attn_chunk=cfg.attn_chunk, causal_skip=cfg.attn_causal_skip,
    )
    x = x + attn_out
    h = L.rmsnorm(p["ln_mlp"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        mlp_out, aux = moe_apply(p["moe"], h, cfg.moe, dt, sharder=sharder)
    else:
        mlp_out = L.mlp_apply(p["mlp"], h, cfg.mlp, dt, sharder=sharder)
    x = x + mlp_out
    if sharder is not None:
        x = sharder.act_btd(x)
    return x, new_cache, aux


# --------------------------------------------------------------------------
# full model
# --------------------------------------------------------------------------


def lm_init(key, cfg: ModelConfig):
    keys = jax.random.split(key, cfg.num_layers + 3)
    dt = jnp.dtype(cfg.param_dtype)
    stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs),
        *[layer_init(keys[i], cfg) for i in range(cfg.num_layers)],
    )
    p = {
        "embed": L.embedding_init(keys[-1], cfg.vocab_size, cfg.d_model, dt),
        "layers": stacked,
        "final_norm": L.rmsnorm_init(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        p["head"] = {
            "w": jax.random.normal(keys[-2], (cfg.d_model, cfg.vocab_size), dt)
            * (1.0 / cfg.d_model**0.5)
        }
    if cfg.vlm is not None:
        p["patch_proj"] = L.dense_init(keys[-3], cfg.d_model, cfg.d_model, dt)
    return p


def _remat_wrap(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    raise ValueError(f"unknown remat policy {cfg.remat!r}")


def _embed_inputs(p, batch, cfg: ModelConfig, dt, sharder):
    """tokens (+ patch_embeds for VLM) -> (B, S, d) embeddings."""
    x = L.embed(p["embed"], batch["tokens"], dt)
    if cfg.vlm is not None:
        patches = L.dense(p["patch_proj"], batch["patch_embeds"].astype(dt), dt)
        x = jnp.concatenate([patches, x], axis=1)  # vision prefix
    if sharder is not None:
        x = sharder.act_btd(x)
    return x


def lm_forward(p, batch, cfg: ModelConfig, *, sharder=None, window=None,
               return_cache=False):
    """Train/prefill forward: full-sequence causal attention.

    Returns (logits, caches, aux_mean).  ``caches`` are stacked (L, ...)
    when return_cache (prefill), else None.
    """
    dt = jnp.dtype(cfg.dtype)
    x = _embed_inputs(p, batch, cfg, dt, sharder)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(carry, layer_p):
        x, aux = carry
        x, cache, a = layer_apply(
            layer_p, x, cfg, positions=positions, sharder=sharder, window=window
        )
        out = cache if return_cache else None
        return (x, aux + a), out

    if cfg.scan_layers and cfg.remat_group > 1 and not return_cache:
        # grouped remat: only every g-th layer boundary is saved; the inner
        # scan recomputes through the group on the backward pass.  Cuts the
        # saved-activation footprint by g× (needed for the 340B/405B cells).
        g = cfg.remat_group
        assert cfg.num_layers % g == 0, "remat_group must divide num_layers"
        grouped = jax.tree_util.tree_map(
            lambda a: a.reshape((cfg.num_layers // g, g) + a.shape[1:]),
            p["layers"],
        )

        def inner(carry, layer_p):
            out, _ = body(carry, layer_p)  # body unwrapped: one remat level
            return out, None

        def group_body(carry, group_p):
            carry, _ = jax.lax.scan(inner, carry, group_p)
            return carry, None

        group_body = _remat_wrap(group_body, cfg)
        (x, aux), caches = jax.lax.scan(
            group_body, (x, jnp.zeros((), jnp.float32)), grouped
        )
    elif cfg.scan_layers:
        body = _remat_wrap(body, cfg)
        (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                        p["layers"])
    else:
        body = _remat_wrap(body, cfg)
        aux = jnp.zeros((), jnp.float32)
        caches_list = []
        for i in range(cfg.num_layers):
            layer_p = jax.tree_util.tree_map(lambda q, i=i: q[i], p["layers"])
            (x, aux), c = body((x, aux), layer_p)
            caches_list.append(c)
        caches = (
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *caches_list)
            if return_cache else None
        )

    x = L.rmsnorm(p["final_norm"], x, cfg.norm_eps)
    head = p["head"] if "head" in p else {"w": p["embed"]["table"].T}
    logits = L.unembed(head, x, dt)
    if sharder is not None:
        logits = sharder.logits(logits)
    return logits, caches, aux / cfg.num_layers


def lm_init_cache(cfg: ModelConfig, batch_size: int, max_len: int, *,
                  window=None):
    S = min(max_len, window) if window is not None else max_len
    hk, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    shape = (cfg.num_layers, batch_size, S, hk, hd)
    dt = jnp.dtype(cfg.dtype)
    if cfg.kv_quant:
        sshape = (cfg.num_layers, batch_size, S, hk, 1)
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(sshape, jnp.float32),
            "v_scale": jnp.zeros(sshape, jnp.float32),
        }
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def lm_decode_step(p, cache, batch, cfg: ModelConfig, *, sharder=None,
                   window=None):
    """One decode step: ``batch = {tokens: (B, 1), pos: scalar int32}``.
    Returns (logits (B, 1, V), new_cache)."""
    dt = jnp.dtype(cfg.dtype)
    x = L.embed(p["embed"], batch["tokens"], dt)
    if sharder is not None:
        x = sharder.act_btd(x)
    pos = batch["pos"]
    if pos.ndim == 0:
        positions = pos[None].astype(jnp.int32)         # (t=1,) synchronous
    else:
        positions = pos[:, None].astype(jnp.int32)      # (B, t=1) per-slot

    def body(carry, layer_in):
        x, aux = carry
        layer_p, cache_l = layer_in
        x, new_cache_l, a = layer_apply(
            layer_p, x, cfg, positions=positions, sharder=sharder,
            cache=cache_l, cache_pos=pos, window=window,
        )
        return (x, aux + a), new_cache_l

    if cfg.scan_layers:
        (x, _), new_cache = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), (p["layers"], cache)
        )
    else:
        outs = []
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.num_layers):
            sel = lambda q, i=i: q[i]  # bind i: late-binding closure pitfall
            (x, aux), c = body(
                (x, aux),
                (jax.tree_util.tree_map(sel, p["layers"]),
                 jax.tree_util.tree_map(sel, cache)),
            )
            outs.append(c)
        new_cache = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)

    x = L.rmsnorm(p["final_norm"], x, cfg.norm_eps)
    head = p["head"] if "head" in p else {"w": p["embed"]["table"].T}
    logits = L.unembed(head, x, dt)
    if sharder is not None:
        logits = sharder.logits(logits)
    return logits, new_cache


def lm_loss(p, batch, cfg: ModelConfig, *, sharder=None, aux_weight=0.01):
    logits, _, aux = lm_forward(p, batch, cfg, sharder=sharder)
    labels = batch["labels"]
    if cfg.vlm is not None:
        # vision prefix carries no labels
        pad = jnp.full(
            (labels.shape[0], cfg.vlm.num_patches), -100, labels.dtype
        )
        labels = jnp.concatenate([pad, labels], axis=1)
    loss = L.cross_entropy(logits, labels)
    return loss + aux_weight * aux, {"ce": loss, "aux": aux}


# --------------------------------------------------------------------------
# sharding rules for the param tree (mirrors lm_init's structure)
# --------------------------------------------------------------------------


def lm_param_rules(cfg: ModelConfig):
    """Rules pytree (same structure as params) for Sharder.spec.

    Leading dim of every stacked layer leaf is the layer dim (never
    sharded); weights shard output-column over "model" and, under FSDP,
    input-row over the data axes.
    """
    attn = {
        "wq": [None, ["fsdp"], "model", None],
        "wk": [None, ["fsdp"], "model", None],
        "wv": [None, ["fsdp"], "model", None],
        "wo": [None, "model", None, ["fsdp"]],
    }
    if cfg.qkv_bias:
        attn.update({
            "bq": [None, "model", None],
            "bk": [None, "model", None],
            "bv": [None, "model", None],
        })
    layer = {
        "ln_attn": {"scale": [None, None]},
        "ln_mlp": {"scale": [None, None]},
        "attn": attn,
    }
    if cfg.moe is not None:
        moe_rules = {
            k: [None] + v for k, v in expert_specs(None, cfg.moe).items()
        }
        if cfg.moe.num_shared_experts:
            moe_rules["shared"] = {
                "w_gate": [None, ["fsdp"], "model"],
                "w_up": [None, ["fsdp"], "model"],
                "w_down": [None, "model", ["fsdp"]],
                "gate": [None, None, None],
            }
        layer["moe"] = moe_rules
    else:
        mlp = {
            "w_up": [None, ["fsdp"], "model"],
            "w_down": [None, "model", ["fsdp"]],
        }
        if cfg.mlp == "swiglu":
            mlp["w_gate"] = [None, ["fsdp"], "model"]
        layer["mlp"] = mlp
    rules = {
        "embed": {"table": [["fsdp"], "model"]},
        "layers": layer,
        "final_norm": {"scale": [None]},
    }
    if not cfg.tie_embeddings:
        rules["head"] = {"w": [["fsdp"], "model"]}
    if cfg.vlm is not None:
        rules["patch_proj"] = {"w": [["fsdp"], "model"]}
    return rules


def lm_cache_rules(cfg: ModelConfig | None = None, model_axis_size: int = 16):
    """KV-cache sharding: heads over the model axis when they divide it
    (zamba 32, olmoe/qwen2moe 16); otherwise the cache *sequence* dim is
    sharded (flash-decode-style partial softmax — GSPMD reduces the tiny
    (B,H,t) statistics across shards).  kv=8/20 archs take the seq path."""
    if cfg is not None and cfg.num_kv_heads % model_axis_size == 0:
        rule = [None, "batch", None, "model", None]
    else:
        rule = [None, "batch", "model", None, None]
    rules = {"k": list(rule), "v": list(rule)}
    if cfg is not None and cfg.kv_quant:
        srule = rule[:-1] + [None]
        rules["k_scale"] = list(srule)
        rules["v_scale"] = list(srule)
    return rules
