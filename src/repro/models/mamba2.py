"""Mamba2 blocks via the State-Space Dual (SSD) chunked algorithm.

Per head: scalar decay λ_t = exp(A·Δ_t) (A < 0), state h ∈ R^{N×P}:

    h_t = λ_t h_{t-1} + Δ_t · (B_t ⊗ x_t)          (B_t ∈ R^N, x_t ∈ R^P)
    y_t = C_t · h_t + D · x_t                       (contract over N)

Chunked (L_t = Σ log λ within chunk):  intra-chunk is a masked matmul
S(t,s) = (C_t·B_s)·exp(L_t−L_s)·Δ_s for s ≤ t (the quadratic "attention-like"
branch the Pallas ``mamba2_ssd`` kernel tiles), inter-chunk is a short scan
carrying h.  B/C are shared across head groups (G groups).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.xlstm import causal_conv, causal_conv_init, causal_conv_step


def ssd_chunked(x, dt, A, Bm, Cm, D, state=None, *, chunk: int):
    """x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,G,N); D: (H,).
    Returns (y (B,S,H,P), h_final (B,H,N,P))."""
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = H // G
    nc = S // chunk
    assert S % chunk == 0

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    loglam = (A.astype(jnp.float32)[None, None, :] * dtf)  # (B,S,H) negative
    # reshape into chunks: (B,H,nc,L,...)
    def c4(a, last):  # (B,S,H,last) -> (B,H,nc,chunk,last)
        return a.reshape(Bsz, nc, chunk, H, last).transpose(0, 3, 1, 2, 4)

    xc = c4(xf, P)
    dtc = dtf.reshape(Bsz, nc, chunk, H).transpose(0, 3, 1, 2)
    llc = loglam.reshape(Bsz, nc, chunk, H).transpose(0, 3, 1, 2)
    Bc = Bm.astype(jnp.float32).reshape(Bsz, nc, chunk, G, N).transpose(0, 3, 1, 2, 4)
    Cc = Cm.astype(jnp.float32).reshape(Bsz, nc, chunk, G, N).transpose(0, 3, 1, 2, 4)

    Lc = jnp.cumsum(llc, axis=-1)  # (B,H,nc,chunk)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    if state is None:
        h0 = jnp.zeros((Bsz, H, N, P), jnp.float32)
    else:
        h0 = state.astype(jnp.float32)

    def body(h, xs):
        xi, dti, Li, Bi, Ci = xs      # xi (B,H,L,P), dti/Li (B,H,L), Bi/Ci (B,G,L,N)
        # expand groups to heads
        Bh = jnp.repeat(Bi, hpg, axis=1)   # (B,H,L,N)
        Ch = jnp.repeat(Ci, hpg, axis=1)
        # intra-chunk
        cb = jnp.einsum("bhtn,bhsn->bhts", Ch, Bh)
        decay = jnp.exp(Li[..., :, None] - Li[..., None, :])   # (B,H,t,s)
        Smat = jnp.where(tri, cb * decay * dti[..., None, :], 0.0)
        y = jnp.einsum("bhts,bhsp->bhtp", Smat, xi)
        # inter-chunk
        y = y + jnp.exp(Li)[..., None] * jnp.einsum("bhtn,bhnp->bhtp", Ch, h)
        # state update
        LL = Li[..., -1:]                                      # (B,H,1)
        w = jnp.exp(LL - Li) * dti                             # (B,H,L)
        h_new = jnp.exp(LL)[..., None] * h + jnp.einsum(
            "bhs,bhsn,bhsp->bhnp", w, Bh, xi
        )
        return h_new, y

    xs = (
        xc.transpose(2, 0, 1, 3, 4), dtc.transpose(2, 0, 1, 3),
        Lc.transpose(2, 0, 1, 3), Bc.transpose(2, 0, 1, 3, 4),
        Cc.transpose(2, 0, 1, 3, 4),
    )
    h_fin, ys = jax.lax.scan(body, h0, xs)
    y = ys.transpose(1, 0, 3, 2, 4).reshape(Bsz, S, H, P)
    y = y + xf * D.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), h_fin


def ssd_step(x, dt, A, Bm, Cm, D, state):
    """One decode step. x: (B,1,H,P); Bm/Cm: (B,1,G,N); state (B,H,N,P)."""
    Bsz, _, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = H // G
    xf = x[:, 0].astype(jnp.float32)
    dtf = dt[:, 0].astype(jnp.float32)
    lam = jnp.exp(A.astype(jnp.float32)[None, :] * dtf)       # (B,H)
    Bh = jnp.repeat(Bm[:, 0].astype(jnp.float32), hpg, axis=1)  # (B,H,N)
    Ch = jnp.repeat(Cm[:, 0].astype(jnp.float32), hpg, axis=1)
    h = state.astype(jnp.float32)
    h_new = lam[..., None, None] * h + (dtf[..., None, None]
                                        * Bh[..., :, None] * xf[..., None, :])
    y = jnp.einsum("bhn,bhnp->bhp", Ch, h_new) + xf * D.astype(jnp.float32)[None, :, None]
    return y[:, None].astype(x.dtype), h_new


def ssd_recurrent(x, dt, A, Bm, Cm, D, state=None):
    """Oracle: stepwise recurrence (tests compare chunked against this)."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[3]
    if state is None:
        state = jnp.zeros((Bsz, H, N, P), jnp.float32)

    def body(h, xs_t):
        xt, dtt, Bt, Ct = xs_t
        y, h = ssd_step(xt[:, None], dtt[:, None], A,
                        Bt[:, None], Ct[:, None], D, h)
        return h, y[:, 0]

    xs = tuple(a.transpose(1, 0, *range(2, a.ndim)) for a in (x, dt, Bm, Cm))
    h, ys = jax.lax.scan(body, state, xs)
    return ys.transpose(1, 0, 2, 3), h


# --------------------------------------------------------------------------
# Mamba2 block
# --------------------------------------------------------------------------


def mamba2_dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    H = di // s.head_dim
    return di, H, s.num_groups, s.state_dim, s.head_dim


def mamba2_block_init(key, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    di, H, G, N, P = mamba2_dims(cfg)
    ks = jax.random.split(key, 5)
    dt_ = jnp.dtype(cfg.param_dtype)
    conv_ch = di + 2 * G * N
    return {
        "ln": L.rmsnorm_init(d, dt_),
        # in_proj emits [z, x, B, C, dt]
        "w_in": jax.random.normal(ks[0], (d, 2 * di + 2 * G * N + H), dt_)
        * (1.0 / np.sqrt(d)),
        "conv": causal_conv_init(ks[1], s.conv_width, conv_ch, dt_),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (H,), jnp.float32,
                                       np.log(1e-3), np.log(1e-1)))
        )),
        "D": jnp.ones((H,), jnp.float32),
        "out_norm": L.rmsnorm_init(di, dt_),
        "w_out": jax.random.normal(ks[3], (di, d), dt_) * (1.0 / np.sqrt(di)),
    }


def mamba2_block_apply(p, x, cfg: ModelConfig, *, state=None, sharder=None,
                       decode=False):
    """state = (h (B,H,N,P) fp32, conv_state (B,w-1,conv_ch))."""
    s = cfg.ssm
    dt_ = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    di, H, G, N, P = mamba2_dims(cfg)
    B_, S, _ = x.shape

    hin = L.rmsnorm(p["ln"], x, cfg.norm_eps)
    proj = hin @ p["w_in"].astype(dt_)
    z, xs_, Bm, Cm, dt_pre = jnp.split(
        proj, [di, 2 * di, 2 * di + G * N, 2 * di + 2 * G * N], axis=-1
    )
    if sharder is not None:
        z = sharder.constrain(z, ["batch", None, "model"])
        xs_ = sharder.constrain(xs_, ["batch", None, "model"])
    conv_in = jnp.concatenate([xs_, Bm, Cm], axis=-1)

    if decode:
        h0, conv_state = state
        conv_out, conv_state = causal_conv_step(p["conv"], conv_in, conv_state, dt_)
    else:
        if state is not None:
            h0, conv_state = state
        else:
            h0 = None
        conv_out = causal_conv(p["conv"], conv_in, dt_)
    conv_out = jax.nn.silu(conv_out)
    xc = conv_out[..., :di].reshape(B_, S, H, P)
    Bc = conv_out[..., di : di + G * N].reshape(B_, S, G, N)
    Cc = conv_out[..., di + G * N :].reshape(B_, S, G, N)
    dt_v = jax.nn.softplus(dt_pre.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])

    if decode:
        y, h_new = ssd_step(xc, dt_v, A, Bc, Cc, p["D"], h0)
    else:
        chunk = min(s.chunk_size, S)
        while S % chunk:
            chunk -= 1
        y, h_new = ssd_chunked(xc, dt_v, A, Bc, Cc, p["D"], h0, chunk=chunk)

    yflat = y.reshape(B_, S, di)
    yflat = L.rmsnorm(p["out_norm"], yflat, cfg.norm_eps) * jax.nn.silu(z)
    out = yflat @ p["w_out"].astype(dt_)
    if sharder is not None:
        out = sharder.act_btd(out)
    if decode:
        new_state = (h_new, conv_state)
    else:
        w = s.conv_width
        tail = conv_in[:, -(w - 1):, :]
        pad = jnp.zeros((B_, max(0, w - 1 - S), conv_in.shape[-1]), dt_)
        new_state = (h_new, jnp.concatenate([pad, tail], axis=1))
    return x + out, new_state


def mamba2_state_init(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    di, H, G, N, P = mamba2_dims(cfg)
    conv_ch = di + 2 * G * N
    return (
        jnp.zeros((batch, H, N, P), jnp.float32),
        jnp.zeros((batch, s.conv_width - 1, conv_ch), jnp.dtype(cfg.dtype)),
    )


def mamba2_param_rules(prefix_dims: int = 1):
    """Rules for one (possibly stacked) mamba2 block; ``prefix_dims`` layer
    dims lead each leaf."""
    pre = [None] * prefix_dims
    return {
        "ln": {"scale": pre + [None]},
        "w_in": pre + [["fsdp"], "model"],
        "conv": {"w": pre + [None, "model"]},
        "A_log": pre + [None],
        "dt_bias": pre + [None],
        "D": pre + [None],
        "out_norm": {"scale": pre + [None]},
        "w_out": pre + ["model", ["fsdp"]],
    }
