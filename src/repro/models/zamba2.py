"""Zamba2: Mamba2 backbone with a weight-shared attention block.

``cfg.num_layers`` Mamba2 blocks; after every ``cfg.ssm.attn_every`` of
them, ONE shared transformer block (full attention + SwiGLU MLP, weights
reused across all applications) refines the stream — Zamba2's core trick
(a fraction of attention's parameters at most of its quality).  For the
``long_500k`` cell the shared block runs sliding-window attention
(``cfg.ssm.attn_window``) over a ring-buffer cache; this windowing is a
documented deviation (DESIGN.md §5) that keeps the hybrid sub-quadratic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.mamba2 import (
    mamba2_block_apply,
    mamba2_block_init,
    mamba2_param_rules,
    mamba2_state_init,
)
from repro.models.transformer import layer_apply, layer_init, lm_param_rules


def _group_counts(cfg: ModelConfig):
    per = cfg.ssm.attn_every
    assert cfg.num_layers % per == 0
    return cfg.num_layers // per, per


def zamba2_init(key, cfg: ModelConfig):
    G, per = _group_counts(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, cfg.num_layers + 3)
    blocks = [mamba2_block_init(keys[i], cfg) for i in range(cfg.num_layers)]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)
    # reshape leading L into (G, per)
    stacked = jax.tree_util.tree_map(
        lambda a: a.reshape((G, per) + a.shape[1:]), stacked
    )
    return {
        "embed": L.embedding_init(keys[-1], cfg.vocab_size, cfg.d_model, dt),
        "mamba": stacked,
        "shared_attn": layer_init(keys[-2], cfg),  # ONE copy, applied G times
        "final_norm": L.rmsnorm_init(cfg.d_model, dt),
        "head": {"w": jax.random.normal(keys[-3], (cfg.d_model, cfg.vocab_size), dt)
                 * (1.0 / cfg.d_model**0.5)},
    }


def zamba2_forward(p, batch, cfg: ModelConfig, *, sharder=None,
                   return_cache=False, window=None):
    dt = jnp.dtype(cfg.dtype)
    x = L.embed(p["embed"], batch["tokens"], dt)
    if sharder is not None:
        x = sharder.act_btd(x)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    win = window if window is not None else cfg.ssm.attn_window

    def m_body(x, layer_p):
        x, st = mamba2_block_apply(layer_p, x, cfg, sharder=sharder)
        return x, st if return_cache else None

    mb = jax.checkpoint(m_body) if cfg.remat != "none" else m_body

    def group_body(x, group_p):
        x, mst = jax.lax.scan(mb, x, group_p)
        x, kv, _ = layer_apply(p["shared_attn"], x, cfg, positions=positions,
                               sharder=sharder, window=win)
        return x, (mst, kv if return_cache else None)

    x, states = jax.lax.scan(group_body, x, p["mamba"])
    x = L.rmsnorm(p["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(p["head"], x, dt)
    if sharder is not None:
        logits = sharder.logits(logits)
    return logits, (states if return_cache else None), jnp.zeros((), jnp.float32)


def zamba2_init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                      window=None):
    G, per = _group_counts(cfg)
    win = window if window is not None else cfg.ssm.attn_window
    S = min(max_len, win) if win is not None else max_len
    hk, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    mst = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (G, per) + a.shape).copy(),
        mamba2_state_init(cfg, batch),
    )
    kv = {
        "k": jnp.zeros((G, batch, S, hk, hd), dt),
        "v": jnp.zeros((G, batch, S, hk, hd), dt),
    }
    return {"mamba": mst, "attn_kv": kv}


def zamba2_decode_step(p, cache, batch, cfg: ModelConfig, *, sharder=None,
                       window=None):
    dt = jnp.dtype(cfg.dtype)
    x = L.embed(p["embed"], batch["tokens"], dt)
    pos = batch["pos"]
    if pos.ndim == 0:
        positions = pos[None].astype(jnp.int32)
    else:
        positions = pos[:, None].astype(jnp.int32)
    win = window if window is not None else cfg.ssm.attn_window

    def m_body(x, layer_in):
        layer_p, st = layer_in
        x, st = mamba2_block_apply(layer_p, x, cfg, state=st, decode=True,
                                   sharder=sharder)
        return x, st

    def group_body(x, group_in):
        mp, mst, kv = group_in
        x, mst = jax.lax.scan(m_body, x, (mp, mst))
        x, kv_new, _ = layer_apply(p["shared_attn"], x, cfg,
                                   positions=positions, sharder=sharder,
                                   cache=kv, cache_pos=pos, window=win)
        return x, (mst, kv_new)

    x, (mst, kv) = jax.lax.scan(
        group_body, x, (p["mamba"], cache["mamba"], cache["attn_kv"])
    )
    x = L.rmsnorm(p["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(p["head"], x, dt)
    if sharder is not None:
        logits = sharder.logits(logits)
    return logits, {"mamba": mst, "attn_kv": kv}


def zamba2_param_rules(cfg: ModelConfig):
    shared = lm_param_rules(cfg)["layers"]
    # shared_attn is unstacked: drop the leading layer dim of each rule
    def drop_lead(r):
        return r[1:] if isinstance(r, list) and len(r) and r[0] is None else r
    shared = jax.tree_util.tree_map(
        drop_lead, shared, is_leaf=lambda x: isinstance(x, list)
    )
    return {
        "embed": {"table": [["fsdp"], "model"]},
        "mamba": mamba2_param_rules(prefix_dims=2),
        "shared_attn": shared,
        "final_norm": {"scale": [None]},
        "head": {"w": [["fsdp"], "model"]},
    }
